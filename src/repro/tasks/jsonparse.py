"""JSON structural parsing as a vectorized JAX kernel (paper §IV-B).

The paper parses the json.org "widget" example (~600 bytes) with RapidJSON —
a ~1.1 µs task. The vector-unit translation is simdjson's stage-1: classify
bytes, resolve in-string spans with a parallel prefix-XOR over unescaped
quotes, extract structural characters, and validate nesting depth with a
prefix-sum — all associative-scan work, which is exactly what a TPU VPU (or
this CPU backend) executes well.

`parse_structural` returns (structural mask, depth array, ok flag); the
pytest oracle is Python's json module on the same bytes.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

# The json.org example document the paper uses (its "widget" sample).
WIDGET_JSON = json.dumps({
    "widget": {
        "debug": "on",
        "window": {
            "title": "Sample Konfabulator Widget",
            "name": "main_window", "width": 500, "height": 500},
        "image": {
            "src": "Images/Sun.png", "name": "sun1",
            "hOffset": 250, "vOffset": 250, "alignment": "center"},
        "text": {
            "data": "Click Here", "size": 36, "style": "bold",
            "name": "text1", "hOffset": 250, "vOffset": 100,
            "alignment": "center",
            "onMouseUp": "sun1.opacity = (sun1.opacity / 100) * 90;"},
    }
})


def to_bytes(doc: str) -> jax.Array:
    return jnp.asarray(np.frombuffer(doc.encode("utf-8"), np.uint8))


@jax.jit
def parse_structural(buf: jax.Array):
    """buf: uint8[n] -> (structural bool[n], depth int32[n], ok bool)."""
    bs = buf
    quote = bs == ord('"')
    backslash = bs == ord("\\")

    # escaped[i]: odd run of backslashes immediately before i.
    def esc_step(carry, is_bs):
        run = jnp.where(is_bs, carry + 1, 0)
        return run, carry % 2 == 1

    _, escaped = jax.lax.scan(esc_step, jnp.int32(0), backslash)
    real_quote = quote & ~escaped

    # in-string mask: prefix XOR (cumsum mod 2) of real quotes, exclusive.
    qcum = jnp.cumsum(real_quote.astype(jnp.int32))
    in_string = ((qcum - real_quote.astype(jnp.int32)) % 2) == 1

    structural_chars = (
        (bs == ord("{")) | (bs == ord("}")) |
        (bs == ord("[")) | (bs == ord("]")) |
        (bs == ord(":")) | (bs == ord(","))
    )
    structural = (structural_chars & ~in_string) | real_quote

    opens = ((bs == ord("{")) | (bs == ord("["))) & ~in_string
    closes = ((bs == ord("}")) | (bs == ord("]"))) & ~in_string
    depth = jnp.cumsum(opens.astype(jnp.int32) - closes.astype(jnp.int32))

    balanced = depth[-1] == 0
    non_negative = jnp.all(depth >= 0)
    quotes_closed = (qcum[-1] % 2) == 0
    ok = balanced & non_negative & quotes_closed
    return structural, depth, ok


def oracle_counts(doc: str) -> dict:
    """Reference structural statistics computed with Python's json + a
    character walk (test oracle)."""
    json.loads(doc)  # raises if invalid
    in_str = False
    esc = False
    structural = 0
    max_depth = 0
    depth = 0
    for ch in doc:
        if in_str:
            if esc:
                esc = False
            elif ch == "\\":
                esc = True
            elif ch == '"':
                in_str = False
                structural += 1
            continue
        if ch == '"':
            in_str = True
            structural += 1
        elif ch in "{}[]:,":
            structural += 1
            if ch in "{[":
                depth += 1
                max_depth = max(max_depth, depth)
            elif ch in "}]":
                depth -= 1
    return {"structural": structural, "max_depth": max_depth}
