"""The paper's fine-grained benchmark tasks (graph kernels + JSON parsing),
implemented as microsecond-scale JAX kernels."""

from repro.tasks import graph, jsonparse  # noqa: F401
