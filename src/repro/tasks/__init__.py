"""The paper's fine-grained benchmark tasks (graph kernels + JSON parsing)
and the structured tasking façade every workload targets.

``repro.tasks.api`` is the public tasking surface (TaskScope / TaskHandle /
parallel_for / map_reduce / TaskGraph); raw ``Scheduler.submit()/wait()``
in ``repro.core.schedulers`` is the substrate SPI beneath it.
"""

from repro.tasks import graph, jsonparse  # noqa: F401
from repro.tasks.api import (TaskCancelledError, TaskGraph,  # noqa: F401
                             TaskGroupError, TaskHandle, TaskScope,
                             map_reduce, parallel_for)
from repro.tasks.graph import gap_task_graph, run_wavefronts  # noqa: F401
