"""The paper's fine-grained benchmark tasks (graph kernels + JSON parsing),
implemented as microsecond-scale JAX kernels."""

from repro.tasks import graph, jsonparse  # noqa: F401
from repro.tasks.graph import gap_task_graph, run_wavefronts  # noqa: F401
