"""Structured tasking façade over ``repro.core.schedulers``.

The paper's thesis is that fine-grained task parallelism pays off only when
*expressing* a task is nearly free (§VI: Relic's submit is a ring push).
The raw ``Scheduler`` contract from ``repro.core.schedulers`` keeps that
cost profile but pushes real ergonomics onto every caller: results come
back only through caller-managed shared state, and of N task errors only
the first survives ``wait()``. This module is the high-level layer the
FastFlow line of work (Aldinucci et al., 2009) argues such runtimes need —
a small structured-concurrency surface that every in-repo consumer (and
every future workload) targets, leaving raw ``submit()``/``wait()`` as the
substrate SPI.

The surface:

  * :class:`TaskScope` — context manager bound to a substrate (registry
    name or ``Scheduler`` instance). Scope exit is the barrier. Task
    errors are aggregated per scope and re-raised together (a
    :class:`TaskGroupError` when more than one task failed) instead of
    the SPI's first-error-wins.
  * ``scope.submit(fn, *args) -> TaskHandle`` — a lightweight future with
    ``result()`` / ``exception()`` / ``done()``.
  * :func:`parallel_for` — worksharing loop tasking (Maroñas et al., 2020)
    with explicit ``grain`` chunking; the calling thread runs the final
    chunk itself (the paper's producer-participates pattern, §VI).
  * :func:`map_reduce` — ``parallel_for`` with per-chunk local reduction
    and a deterministic chunk-order combine on the calling thread.
  * :class:`TaskGraph` — dependency-graph builder (``graph.task(name, fn,
    deps=...)``) that executes in topological wavefronts over a scope and
    hands results back through handles — no shared results dict, no lock.

Grain-size guidance (paper §IV: task bodies of 0.4–6.4 µs): pick ``grain``
so one *chunk* amounts to at least a few microseconds of work — at Python
submit overheads, per-index tasks only make sense when the body itself is
µs-scale (a JAX dispatch, a NumPy kernel, file I/O).
"""

from __future__ import annotations

import math
import threading
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple, Union

from repro.core.schedulers import (USAGE_ERRORS, Scheduler,
                                   SchedulerUsageError, make_scheduler)

__all__ = [
    "TaskScope",
    "TaskHandle",
    "TaskGraph",
    "TaskGroupError",
    "TaskCancelledError",
    "parallel_for",
    "map_reduce",
]


class TaskGroupError(RuntimeError):
    """Every task exception from one scope window, re-raised together.

    Python 3.10-compatible stand-in for ``ExceptionGroup``: the individual
    exceptions (in task-completion order) are on ``.exceptions``.
    """

    def __init__(self, exceptions: Iterable[BaseException]):
        self.exceptions: Tuple[BaseException, ...] = tuple(exceptions)
        kinds = ", ".join(type(e).__name__ for e in self.exceptions)
        super().__init__(f"{len(self.exceptions)} tasks failed ({kinds})")


class TaskCancelledError(RuntimeError):
    """The task never ran (an upstream dependency failed)."""


class TaskHandle:
    """Lightweight future for one submitted task.

    Completion is signalled by the thread that ran the task, so
    ``result()`` blocks without involving the scheduler barrier — safe to
    call from the owning thread at any point, before or after the scope's
    barrier. A handle whose task failed re-raises that task's exception;
    the scope-level aggregate still fires at the next barrier regardless
    of which handles were inspected.

    Allocation-slim by design: completion is a plain flag write, and the
    ``threading.Event`` (a Condition + Lock allocation, the dominant cost
    of the PR 2 handle) is created lazily on the first *blocking* wait.
    The common fire-and-barrier pattern — submit, ``barrier()``, then read
    results — never allocates one.
    """

    __slots__ = ("label", "_done", "_event", "_result", "_error")

    # Shared creation lock for the lazy event: taken only on the slow
    # (blocking-wait) path, so it costs the hot path nothing.
    _event_init_lock = threading.Lock()

    def __init__(self, label: Optional[str] = None):
        self.label = label
        self._done = False
        self._event: Optional[threading.Event] = None
        self._result: Any = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        """True once the task has finished (successfully or not)."""
        return self._done

    def _wait(self, timeout: Optional[float] = None) -> bool:
        """Block until finished (lazily materializing the event); returns
        False only on timeout."""
        if self._done:
            return True
        ev = self._event
        if ev is None:
            with TaskHandle._event_init_lock:
                ev = self._event
                if ev is None:
                    ev = threading.Event()
                    self._event = ev
            if self._done:
                # The finisher may have completed between the flag check
                # and the event install, missing the fresh event: make the
                # event agree with the flag so later waiters pass too.
                ev.set()
                return True
        return ev.wait(timeout)

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until completion; return the value or re-raise the task's
        exception. ``timeout`` (seconds) raises ``TimeoutError``."""
        if not self._wait(timeout):
            raise TimeoutError(f"task {self.label!r} still pending")
        if self._error is not None:
            raise self._error
        return self._result

    def exception(self, timeout: Optional[float] = None) -> Optional[BaseException]:
        """Block until completion; return the exception (or None)."""
        if not self._wait(timeout):
            raise TimeoutError(f"task {self.label!r} still pending")
        return self._error

    def __repr__(self) -> str:
        state = ("error" if self._error is not None else
                 "done" if self._done else "pending")
        return f"TaskHandle({self.label!r}, {state})"

    # -- internal (written by the thread that runs the task) ---------------
    def _finish(self, result: Any, error: Optional[BaseException]) -> None:
        self._result = result
        self._error = error
        self._done = True        # the flag is the completion publication
        ev = self._event
        if ev is not None:       # only waiters pay for event signalling
            ev.set()

    def _reset(self) -> None:
        self._done = False
        self._event = None
        self._result = None
        self._error = None


class TaskScope:
    """Structured-concurrency window over one scheduling substrate.

    ::

        with TaskScope("relic") as scope:          # or "spin"/"condvar"/...
            h = scope.submit(fn, x)                # -> TaskHandle
            parallel_for(scope, n, body, grain=g)  # worksharing loop
            ...                                    # main thread's own share
        # scope exit == barrier: everything completed, errors raised here

    ``scheduler`` is a registry name (the scope instantiates, starts and
    closes the substrate) or a ``Scheduler`` instance — started instances
    are *borrowed* (the scope barriers on them but never closes them, so a
    long-lived substrate can host many scopes), not-yet-started instances
    are adopted (started now, closed with the scope).

    Error model: the task wrapper captures every task exception, so the
    substrate's first-error-wins ``wait()`` never fires for scope tasks.
    ``barrier()`` (and scope exit) re-raises a single failure as itself
    and multiple failures as :class:`TaskGroupError` listing all of them.
    If the ``with`` body itself raises, in-flight tasks are still drained
    but the body's exception wins; task errors stay observable on
    ``scope.errors`` until the next ``barrier()``.

    A scope is also usable without ``with`` (e.g. a long-lived member of
    ``CheckpointManager``): call ``barrier()`` per window and ``close()``
    at end of life. ``submit``/``barrier`` are owning-thread-only and
    tasks must not submit, mirroring the SPI (paper §VI-A).
    """

    def __init__(self, scheduler: Union[str, Scheduler] = "relic",
                 **scheduler_kwargs: Any):
        if isinstance(scheduler, str):
            self._sched: Scheduler = make_scheduler(scheduler, **scheduler_kwargs)
            self._sched.start()
            self._owns = True
        else:
            if scheduler_kwargs:
                raise TypeError(
                    "scheduler kwargs only apply when constructing by name; "
                    f"got an instance plus {sorted(scheduler_kwargs)}")
            self._sched = scheduler
            try:
                self._sched.start()
                self._owns = True           # adopted: we started it
            except USAGE_ERRORS:
                self._owns = False          # borrowed: already running
        self.substrate: str = getattr(self._sched, "name", type(self._sched).__name__)
        # The substrate's advertised concurrent-worker count (optional SPI
        # property, default 1): worksharing constructs derive their default
        # grain from it — producer + workers shares, the paper's
        # producer-participates shape generalized past the SMT pair.
        self.workers: int = getattr(self._sched, "workers", 1)
        # Feature-detect the batch SPI once: registry substrates all have it
        # (natively or via the base-class fallback), but a borrowed
        # third-party Scheduler may predate submit_many.
        self._submit_many = getattr(self._sched, "submit_many", None)
        self._errors: List[BaseException] = []
        self._err_lock = threading.Lock()
        self._closed = False

    # -- introspection -----------------------------------------------------
    @property
    def scheduler(self) -> Scheduler:
        """The underlying substrate (the low-level SPI escape hatch)."""
        return self._sched

    @property
    def stats(self):
        return self._sched.stats

    @property
    def errors(self) -> Tuple[BaseException, ...]:
        """Task errors captured since the last ``barrier()`` (unraised)."""
        with self._err_lock:
            return tuple(self._errors)

    # -- submission --------------------------------------------------------
    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> TaskHandle:
        """Enqueue ``fn(*args, **kwargs)`` on the substrate; returns a
        :class:`TaskHandle` that completes when the task does."""
        handle = TaskHandle(label=getattr(fn, "__name__", None))
        self._submit_into(handle, fn, args, kwargs)
        return handle

    def _submit_into(self, handle: TaskHandle, fn: Callable[..., Any],
                     args: tuple, kwargs: dict) -> None:
        if self._closed:
            raise SchedulerUsageError("submit() on a closed TaskScope")
        self._sched.submit(self._run_into, handle, fn, args, kwargs)

    def _submit_raw_many(self, tasks: List[tuple]) -> None:
        """Push pre-packed ``(fn, args, kwargs)`` tasks through the batch
        SPI (worksharing constructs own their error capture and join, so
        no handles and no per-task wrapper are involved)."""
        if self._closed:
            raise SchedulerUsageError("submit on a closed TaskScope")
        if self._submit_many is not None:
            self._submit_many(tasks)
        else:  # borrowed pre-submit_many substrate: equivalent loop
            for fn, args, kwargs in tasks:
                self._sched.submit(fn, *args, **kwargs)

    def _run_into(self, handle: TaskHandle, fn: Callable[..., Any],
                  args: tuple, kwargs: dict) -> None:
        # Runs on a worker (or, for producer-participates, the owning
        # thread). Exceptions are captured for the scope aggregate, so the
        # substrate's single-error channel stays empty.
        try:
            out = fn(*args, **kwargs)
        except BaseException as e:
            with self._err_lock:
                self._errors.append(e)
            handle._finish(None, e)
        else:
            handle._finish(out, None)

    def run_inline(self, fn: Callable[..., Any], *args: Any,
                   **kwargs: Any) -> TaskHandle:
        """Run ``fn`` on the calling thread under the scope's error
        aggregation (the producer-participates half of a wavefront)."""
        if self._closed:
            raise SchedulerUsageError("run_inline() on a closed TaskScope")
        handle = TaskHandle(label=getattr(fn, "__name__", None))
        self._run_into(handle, fn, args, kwargs)
        return handle

    # -- synchronization ---------------------------------------------------
    def barrier(self) -> None:
        """Block until every task submitted so far has completed, then
        re-raise captured task errors (one directly, several as
        :class:`TaskGroupError`) and clear them. The scope stays usable."""
        self._sched.wait()
        self._raise_errors()

    def _raise_errors(self) -> None:
        with self._err_lock:
            errs, self._errors = self._errors, []
        if len(errs) == 1:
            raise errs[0]
        if errs:
            raise TaskGroupError(errs)

    def _drain(self) -> None:
        """Wait for in-flight tasks without raising (body-exception path)."""
        try:
            self._sched.wait()
        except BaseException:
            pass  # body error wins; task errors remain on scope.errors

    def _wait_handles(self, handles: List[TaskHandle]) -> None:
        """Join exactly these tasks and raise only *their* errors (removed
        from the scope aggregate so they don't re-raise at the barrier).
        Errors from unrelated scope tasks stay queued for ``barrier()`` —
        this is how worksharing constructs avoid misattributing a failed
        sibling to the loop."""
        if not all(h._done for h in handles):
            # Advisory hints must never deadlock a join (same rule as the
            # SPI's wait()): un-park a sleeping worker before blocking.
            self._sched.wake_up_hint()
        for h in handles:
            h._wait()
        errs = [h._error for h in handles if h._error is not None]
        if not errs:
            return
        with self._err_lock:
            for e in errs:
                try:
                    self._errors.remove(e)   # identity: default __eq__
                except ValueError:
                    pass                     # already consumed by a barrier
        if len(errs) == 1:
            raise errs[0]
        raise TaskGroupError(errs)

    # -- hints (paper §VI-B, advisory) -------------------------------------
    def sleep_hint(self) -> None:
        self._sched.sleep_hint()

    def wake_up_hint(self) -> None:
        self._sched.wake_up_hint()

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Idempotent; closes the substrate only if this scope owns it."""
        if self._closed:
            return
        self._closed = True
        if self._owns:
            self._sched.close()

    def __enter__(self) -> "TaskScope":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        try:
            if exc_type is None:
                self.barrier()
            else:
                self._drain()
        finally:
            self.close()


# ------------------------------------------------------------- worksharing

class _ChunkJoin:
    """Single countdown latch shared by every chunk of one worksharing loop
    (the worksharing-task join of Maroñas et al., 2020): one allocation per
    *loop* instead of one ``TaskHandle`` + ``Event`` per chunk. Chunk errors
    collect here, in completion order, and never enter the scope aggregate —
    the loop raises its own errors and a sibling's never misattribute."""

    __slots__ = ("_remaining", "_lock", "_event", "errors")

    def __init__(self, count: int):
        self._remaining = count
        self._lock = threading.Lock()
        self._event = threading.Event()
        self.errors: List[BaseException] = []

    def finish(self, error: Optional[BaseException] = None) -> None:
        with self._lock:
            if error is not None:
                self.errors.append(error)
            self._remaining -= 1
            done = self._remaining <= 0
        if done:
            self._event.set()

    def pending(self) -> bool:
        return self._remaining > 0

    def wait(self) -> None:
        self._event.wait()

    def raise_errors(self) -> None:
        errs = self.errors
        if len(errs) == 1:
            raise errs[0]
        if errs:
            raise TaskGroupError(errs)


def _chunk_ranges(n: int, grain: int) -> List[Tuple[int, int]]:
    return [(lo, min(lo + grain, n)) for lo in range(0, n, grain)]


def _resolve_grain(n: int, grain: Optional[int], workers: int = 1) -> int:
    if grain is None:
        # Default: one near-equal share per execution context — the
        # substrate's advertised workers plus the producer itself (the
        # paper's producer-participates shape, §VI, generalized past the
        # SMT pair: workers=1 keeps the historical split-in-two; a 4-lane
        # pool splits in five; serial's workers=0 runs the loop inline).
        # Explicit grain is the knob the grain-sweep benchmark turns
        # (benchmarks/run.py --only grain).
        return max(1, math.ceil(n / (max(workers, 0) + 1)))
    if grain <= 0:
        raise ValueError(f"grain must be positive, got {grain}")
    return grain


def parallel_for(scope: TaskScope, n: int, body: Callable[[int], Any],
                 *, grain: Optional[int] = None) -> None:
    """Worksharing loop: run ``body(i)`` for ``i in range(n)`` over the
    scope's substrate, chunked by ``grain`` indices per task.

    All chunks but the last go down in one ``submit_many`` burst; the
    calling thread runs the final chunk itself (producer-participates,
    paper §VI), then joins the loop on a single shared countdown latch —
    on return every index has run, and body exceptions (only the loop's,
    never an unrelated sibling task's) are raised under the scope's
    aggregation rules. With ``n <= grain`` the whole loop runs inline on
    the caller (zero submissions, zero allocations); ``n == 0`` is a pure
    no-op.
    """
    if n < 0:
        raise ValueError(f"n must be non-negative, got {n}")
    if n == 0:
        return
    ranges = _chunk_ranges(n, _resolve_grain(n, grain, scope.workers))
    if len(ranges) == 1:
        if scope._closed:
            raise SchedulerUsageError("parallel_for() on a closed TaskScope")
        for i in range(n):
            body(i)
        return

    join = _ChunkJoin(len(ranges))

    def run_chunk(lo: int, hi: int) -> None:
        try:
            for i in range(lo, hi):
                body(i)
        except BaseException as e:
            join.finish(e)
        else:
            join.finish()

    scope._submit_raw_many([(run_chunk, (lo, hi), {})
                            for lo, hi in ranges[:-1]])
    run_chunk(*ranges[-1])
    if join.pending():
        # Advisory hints must never deadlock a join (the SPI wait() rule).
        scope._sched.wake_up_hint()
    join.wait()
    join.raise_errors()


_MISSING = object()


def map_reduce(scope: TaskScope, n: int, map_fn: Callable[[int], Any],
               reduce_fn: Callable[[Any, Any], Any], *,
               init: Any = _MISSING, grain: Optional[int] = None) -> Any:
    """Chunked map + reduce: each chunk folds ``map_fn`` over its indices
    with ``reduce_fn`` locally (the caller runs the final chunk), then the
    partials are combined on the calling thread in chunk order — so the
    result is deterministic for any associative ``reduce_fn``, on every
    substrate. ``init`` seeds the combine (required when ``n == 0``)."""
    if n < 0:
        raise ValueError(f"n must be non-negative, got {n}")
    if n == 0:
        if init is _MISSING:
            raise ValueError("map_reduce over an empty range requires init")
        return init
    ranges = _chunk_ranges(n, _resolve_grain(n, grain, scope.workers))
    partials: List[Any] = [None] * len(ranges)  # one slot per chunk: no lock
    join = _ChunkJoin(len(ranges))

    def run_chunk(ci: int, lo: int, hi: int) -> None:
        try:
            acc = map_fn(lo)
            for i in range(lo + 1, hi):
                acc = reduce_fn(acc, map_fn(i))
            partials[ci] = acc
        except BaseException as e:
            join.finish(e)
        else:
            join.finish()

    if len(ranges) > 1:
        scope._submit_raw_many([(run_chunk, (ci, lo, hi), {})
                                for ci, (lo, hi) in enumerate(ranges[:-1])])
    elif scope._closed:
        raise SchedulerUsageError("map_reduce() on a closed TaskScope")
    run_chunk(len(ranges) - 1, *ranges[-1])
    if join.pending():
        scope._sched.wake_up_hint()   # never let an advisory hint deadlock
    join.wait()
    join.raise_errors()
    acc = init
    for p in partials:
        acc = p if acc is _MISSING else reduce_fn(acc, p)
    return acc


# --------------------------------------------------------------- TaskGraph

class _Node:
    __slots__ = ("name", "fn", "deps", "handle")

    def __init__(self, name: str, fn: Callable[..., Any],
                 deps: Tuple[str, ...]):
        self.name = name
        self.fn = fn
        self.deps = deps
        self.handle = TaskHandle(label=name)


class TaskGraph:
    """Dependency-graph builder executed in topological wavefronts.

    ::

        g = TaskGraph()
        a = g.task("a", load)
        b = g.task("b", transform, deps=("a",))     # names or handles
        c = g.task("c", combine, deps=(a, b))
        results = g.run("relic")                    # {"a": ..., "b": ...}
        b.result()                                  # or through the handle

    ``task()`` returns the node's :class:`TaskHandle`; each task function
    receives its dependencies' results positionally, in ``deps`` order.
    Dependencies must already be in the graph when a task is added, so a
    ``TaskGraph`` is acyclic by construction (the legacy dict-of-tuples
    front door, ``repro.tasks.graph.run_wavefronts``, topo-sorts and
    reports cycles before building one of these).

    ``run()`` accepts a :class:`TaskScope` (reused, left open), a registry
    name, or a ``Scheduler`` instance (a scope is created around it for
    the duration). Within a wavefront, all tasks but one are submitted and
    the calling thread runs the last itself; wavefronts are separated by
    joining exactly that wavefront's handles (never a full scope barrier,
    so a borrowed scope's unrelated sibling errors are not misattributed
    to the graph). On failure the aggregate error propagates and every
    never-run task's handle completes with :class:`TaskCancelledError`.
    A graph may be ``run()`` repeatedly (handles are reset per run); runs
    are not reentrant.
    """

    def __init__(self) -> None:
        self._nodes: Dict[str, _Node] = {}

    def task(self, name: str, fn: Callable[..., Any],
             deps: Iterable[Union[str, TaskHandle]] = ()) -> TaskHandle:
        """Add ``name`` running ``fn(*dep_results)``; returns its handle."""
        if name in self._nodes:
            raise ValueError(f"duplicate task {name!r}")
        dep_names: List[str] = []
        for d in deps:
            dep = d.label if isinstance(d, TaskHandle) else d
            if dep not in self._nodes:
                raise ValueError(f"task {name!r} depends on unknown {dep!r}")
            if isinstance(d, TaskHandle) and self._nodes[dep].handle is not d:
                raise ValueError(
                    f"task {name!r}: dependency handle {dep!r} does not "
                    "belong to this graph")
            dep_names.append(dep)
        node = _Node(name, fn, tuple(dep_names))
        self._nodes[name] = node
        return node.handle

    def __len__(self) -> int:
        return len(self._nodes)

    def __contains__(self, name: str) -> bool:
        return name in self._nodes

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(self._nodes)

    def handle(self, name: str) -> TaskHandle:
        return self._nodes[name].handle

    def run(self, scope: Union[TaskScope, str, Scheduler] = "relic",
            streaming: bool = False,
            **scope_kwargs: Any) -> Dict[str, Any]:
        """Execute the graph; returns ``{name: result}``.

        ``streaming=False`` (the baseline) runs barriered wavefronts:
        stage N+1 starts only after *all* of stage N joined.
        ``streaming=True`` runs the dataflow executor: each task is
        submitted the moment its own dependencies complete, so items flow
        through ready stages while unrelated upstream tasks are still
        producing — no wavefront barrier on the critical path. Results,
        error aggregation and cancellation semantics are identical
        (pinned by ``tests/test_stream.py``); only the join structure
        differs, which is what the ``stream`` benchmark section A/Bs.
        """
        runner = self._run_streaming if streaming else self._run
        if isinstance(scope, TaskScope):
            if scope_kwargs:
                raise TypeError("scope kwargs only apply when run() builds "
                                "the TaskScope itself")
            return runner(scope)
        with TaskScope(scope, **scope_kwargs) as s:
            return runner(s)

    def as_stream(self, scope: Union[TaskScope, str, Scheduler] = "relic",
                  **scope_kwargs: Any) -> Dict[str, Any]:
        """Alias for ``run(scope, streaming=True)``."""
        return self.run(scope, streaming=True, **scope_kwargs)

    def _run(self, scope: TaskScope) -> Dict[str, Any]:
        for node in self._nodes.values():
            node.handle._reset()
        remaining = dict(self._nodes)
        done: set = set()
        try:
            while remaining:
                wave = [node for node in remaining.values()
                        if all(d in done for d in node.deps)]
                # acyclic by construction => every round makes progress
                for node in wave[:-1]:
                    args = tuple(self._nodes[d].handle.result()
                                 for d in node.deps)
                    scope._submit_into(node.handle, node.fn, args, {})
                last = wave[-1]
                args = tuple(self._nodes[d].handle.result() for d in last.deps)
                scope._run_into(last.handle, last.fn, args, {})
                # Join only this wavefront's own handles (not a full scope
                # barrier): on a borrowed long-lived scope, a barrier would
                # raise — and clear — errors from unrelated sibling tasks,
                # misattributing them to the graph (the same fix
                # parallel_for has).
                scope._wait_handles([node.handle for node in wave])
                for node in wave:
                    done.add(node.name)
                    del remaining[node.name]
        finally:
            for node in remaining.values():
                if not node.handle.done():
                    node.handle._finish(None, TaskCancelledError(
                        f"task {node.name!r} never ran (an upstream "
                        f"dependency failed)"))
        return {name: node.handle.result() for name, node in self._nodes.items()}

    def _run_streaming(self, scope: TaskScope) -> Dict[str, Any]:
        """Dataflow execution: submit each task the moment its own deps
        complete (no wavefront barrier). The calling thread still
        participates — of each newly-ready set it runs one task inline
        (producer-participates, paper §VI) — and between submissions it
        sweeps in-flight handles with the scheduler-free ``_done`` flag,
        pausing on the shared spin cadence. Failure joins exactly the
        graph's own in-flight handles (never a scope barrier), so
        borrowed-scope sibling errors are not misattributed; never-run
        tasks cancel with :class:`TaskCancelledError` like the wavefront
        path."""
        for node in self._nodes.values():
            node.handle._reset()
        waiting = dict(self._nodes)
        inflight: List[_Node] = []
        done: set = set()
        woke = False
        try:
            while waiting or inflight:
                progress = False
                still: List[_Node] = []
                finished: List[_Node] = []
                for node in inflight:
                    (finished if node.handle._done else still).append(node)
                if finished:
                    progress = True
                    inflight = still
                    if any(n.handle._error is not None for n in finished):
                        # Join the graph's whole in-flight set and raise
                        # only its errors (pulled from the scope aggregate
                        # like the wavefront path's per-wave join).
                        scope._wait_handles(
                            [n.handle for n in finished]
                            + [n.handle for n in still])
                    for node in finished:
                        done.add(node.name)
                ready = [node for node in waiting.values()
                         if all(d in done for d in node.deps)]
                if ready:
                    progress = True
                    for node in ready:
                        del waiting[node.name]
                    for node in ready[:-1]:
                        args = tuple(self._nodes[d].handle.result()
                                     for d in node.deps)
                        scope._submit_into(node.handle, node.fn, args, {})
                        inflight.append(node)
                    # Producer-participates: the caller runs one ready task
                    # itself instead of going straight to a poll loop.
                    last = ready[-1]
                    args = tuple(self._nodes[d].handle.result()
                                 for d in last.deps)
                    scope._run_into(last.handle, last.fn, args, {})
                    if last.handle._error is not None:
                        scope._wait_handles(
                            [last.handle] + [n.handle for n in inflight])
                    done.add(last.name)
                if progress:
                    woke = False
                    continue
                # Nothing newly done, nothing ready: in-flight tasks hold
                # the frontier (acyclic => inflight is non-empty here).
                # Un-park a sleeping worker once (advisory hints must never
                # deadlock a join), then *block* on the oldest in-flight
                # handle rather than spin-polling: handles finish FIFO
                # within a lane, and Event.wait hands the GIL to the
                # workers — on few-core hosts a polling driver starves the
                # very tasks it is waiting for. The short timeout re-sweeps
                # the whole frontier so an out-of-order completion on
                # another lane is picked up promptly too.
                if not woke:
                    scope.wake_up_hint()
                    woke = True
                inflight[0].handle._wait(0.0005)
        finally:
            for node in waiting.values():
                if not node.handle.done():
                    node.handle._finish(None, TaskCancelledError(
                        f"task {node.name!r} never ran (an upstream "
                        f"dependency failed)"))
        return {name: node.handle.result() for name, node in self._nodes.items()}
