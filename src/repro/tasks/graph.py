"""The paper's fine-grained graph kernels (§IV-A), in JAX.

GAP-style kernels on the paper's input: a generated Kronecker graph with 32
nodes and ~157 undirected edges (degree 4 => scale 5, edgefactor ~4.9). At
n=32 a dense adjacency matrix is the right representation on vector units —
every kernel becomes a handful of matvecs/matmuls, which is both the fastest
JAX realization and microsecond-granularity work, matching the paper's
0.4–6.4 µs task sizes.

CC uses the label-propagation fixpoint (the linear-algebra twin of
Shiloach-Vishkin's hook+compress, chosen by the paper for fine-grained
inputs); SSSP is dense Bellman-Ford (min-plus matvec) rather than
delta-stepping — equivalent output, vector-friendly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.float32(1e9)


def kronecker_graph(scale: int = 5, edge_factor: int = 16, seed: int = 10):
    # defaults reproduce the paper's input: 32 nodes, 157 undirected edges
    """Graph500-style Kronecker generator (A,B,C = .57,.19,.19), dedup'd,
    no self-loops. Returns (dense adjacency f32 [n,n], edge weights [n,n])."""
    n = 2 ** scale
    m = edge_factor * n
    rng = np.random.default_rng(seed)
    a, b, c = 0.57, 0.19, 0.19
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for bit in range(scale):
        r1 = rng.random(m)
        r2 = rng.random(m)
        src_bit = r1 > a + b
        dst_bit = (r1 > a + b) & (r2 > c / (c + 0.05)) | \
                  (r1 <= a + b) & (r2 > a / (a + b))
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    mask = src != dst
    src, dst = src[mask], dst[mask]
    adj = np.zeros((n, n), np.float32)
    adj[src, dst] = 1.0
    adj[dst, src] = 1.0
    wrng = np.random.default_rng(seed + 1)
    w = wrng.integers(1, 8, size=(n, n)).astype(np.float32)
    w = np.where(adj > 0, np.maximum(w, w.T), np.float32(1e9))
    np.fill_diagonal(w, 0.0)
    return jnp.asarray(adj), jnp.asarray(w)


def n_edges(adj: jax.Array) -> int:
    return int(np.asarray(adj).sum() / 2)


# ---------------------------------------------------------------------------
# Kernels. Each is (adj[, w], args) -> array, designed to jit cleanly.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("max_iter",))
def bfs(adj: jax.Array, source: int = 0, max_iter: int = 32) -> jax.Array:
    """Level array (distance in hops; -1 unreachable)."""
    n = adj.shape[0]
    dist = jnp.full((n,), -1, jnp.int32).at[source].set(0)
    frontier = jnp.zeros((n,), jnp.float32).at[source].set(1.0)

    def body(carry):
        dist, frontier, level = carry
        nxt = (adj.T @ frontier > 0) & (dist < 0)
        dist = jnp.where(nxt, level + 1, dist)
        return dist, nxt.astype(jnp.float32), level + 1

    def cond(carry):
        _, frontier, level = carry
        return (frontier.sum() > 0) & (level < max_iter)

    dist, _, _ = jax.lax.while_loop(cond, body, (dist, frontier, jnp.int32(0)))
    return dist


@jax.jit
def connected_components(adj: jax.Array) -> jax.Array:
    """Min-label propagation to fixpoint (Shiloach-Vishkin-style)."""
    n = adj.shape[0]
    big = jnp.float32(n + 1)
    labels = jnp.arange(n, dtype=jnp.float32)
    conn = adj + jnp.eye(n)

    def body(carry):
        labels, _ = carry
        # min over neighbors (masked min-plus with 0/1 adjacency)
        cand = jnp.min(jnp.where(conn > 0, labels[None, :], big), axis=1)
        changed = jnp.any(cand < labels)
        return jnp.minimum(labels, cand), changed

    labels, _ = jax.lax.while_loop(lambda c: c[1], body,
                                   (labels, jnp.bool_(True)))
    return labels.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("iters",))
def pagerank(adj: jax.Array, iters: int = 20, d: float = 0.85) -> jax.Array:
    n = adj.shape[0]
    deg = jnp.maximum(adj.sum(axis=1), 1.0)
    p = jnp.full((n,), 1.0 / n, jnp.float32)

    def body(_, p):
        spread = adj.T @ (p / deg)
        return (1 - d) / n + d * spread

    return jax.lax.fori_loop(0, iters, body, p)


@functools.partial(jax.jit, static_argnames=("max_iter",))
def sssp(w: jax.Array, source: int = 0, max_iter: int = 32) -> jax.Array:
    """Bellman-Ford min-plus relaxation to fixpoint."""
    n = w.shape[0]
    dist = jnp.full((n,), INF).at[source].set(0.0)

    def body(carry):
        dist, _, it = carry
        cand = jnp.min(dist[:, None] + w, axis=0)
        new = jnp.minimum(dist, cand)
        return new, jnp.any(new < dist) & (it < max_iter), it + 1

    dist, _, _ = jax.lax.while_loop(
        lambda c: c[1], body, (dist, jnp.bool_(True), jnp.int32(0)))
    return dist


@jax.jit
def triangle_count(adj: jax.Array) -> jax.Array:
    """#triangles = trace(A^3) / 6 — computed as sum(A * A@A) / 6."""
    return jnp.sum(adj * (adj @ adj)) / 6.0


@functools.partial(jax.jit, static_argnames=("source", "max_iter"))
def betweenness_centrality(adj: jax.Array, source: int = 0,
                           max_iter: int = 32) -> jax.Array:
    """Single-source Brandes: forward BFS with path counts, backward
    dependency accumulation (dense matvecs per level)."""
    n = adj.shape[0]
    dist = jnp.full((n,), -1, jnp.int32).at[source].set(0)
    sigma = jnp.zeros((n,), jnp.float32).at[source].set(1.0)

    def fwd(carry):
        dist, sigma, frontier, level = carry
        contrib = adj.T @ (sigma * frontier)
        nxt = (adj.T @ frontier.astype(jnp.float32) > 0) & (dist < 0)
        sigma = jnp.where(nxt, contrib, sigma)
        dist = jnp.where(nxt, level + 1, dist)
        return dist, sigma, nxt.astype(jnp.float32), level + 1

    def fwd_cond(carry):
        _, _, frontier, level = carry
        return (frontier.sum() > 0) & (level < max_iter)

    frontier0 = jnp.zeros((n,), jnp.float32).at[source].set(1.0)
    dist, sigma, _, max_level = jax.lax.while_loop(
        fwd_cond, fwd, (dist, sigma, frontier0, jnp.int32(0)))

    delta = jnp.zeros((n,), jnp.float32)

    def bwd(i, delta):
        level = max_level - i  # descend levels
        on_next = (dist == level).astype(jnp.float32)
        coeff = jnp.where(sigma > 0, (1.0 + delta) / jnp.maximum(sigma, 1e-9),
                          0.0) * on_next
        contrib = (adj @ coeff) * sigma
        on_this = (dist == level - 1).astype(jnp.float32)
        return delta + contrib * on_this

    delta = jax.lax.fori_loop(0, max_level, bwd, delta)
    return delta.at[source].set(0.0)


# ---------------------------------------------------------------------------
# Dependency-aware wavefront execution: GAP kernels over the tasking façade.
# ---------------------------------------------------------------------------

def run_wavefronts(tasks, scheduler):
    """Legacy dict-of-tuples front door for wavefront execution.

    ``tasks`` maps name -> ``(fn, deps)``; ``fn`` receives its
    dependencies' results positionally (in ``deps`` order). This shim
    validates the dict (``ValueError`` on unknown dependencies or cycles,
    as always), topo-sorts it into a :class:`repro.tasks.api.TaskGraph`,
    and executes it over ``scheduler`` through a borrowed
    :class:`repro.tasks.api.TaskScope` — new code should build the
    ``TaskGraph`` directly (see ``gap_task_graph``). The scheduler must
    already be started; it is left running (callers own its lifecycle).
    Returns ``{name: result}``.
    """
    from repro.tasks.api import TaskGraph, TaskScope

    for name, (_, deps) in tasks.items():
        for d in deps:
            if d not in tasks:
                raise ValueError(f"task {name!r} depends on unknown {d!r}")

    g = TaskGraph()
    pending = dict(tasks)
    while pending:
        ready = [n for n, (_, deps) in pending.items()
                 if all(d in g for d in deps)]
        if not ready:
            raise ValueError(f"dependency cycle among {sorted(pending)}")
        for n in ready:
            fn, deps = pending.pop(n)
            g.task(n, fn, deps=tuple(deps))

    from repro.core.schedulers import SchedulerUsageError
    if not getattr(scheduler, "_started", True):
        # Wrapping in a TaskScope would silently adopt (then close) an
        # unstarted scheduler; the documented contract is loud instead.
        raise SchedulerUsageError(
            "run_wavefronts() requires a started scheduler "
            "(callers own its lifecycle)")
    scope = TaskScope(scheduler)  # started instance => borrowed, not closed
    try:
        return g.run(scope)
    finally:
        scope.close()


def gap_task_graph(adj: jax.Array, w: jax.Array, source: int = 0):
    """The paper's GAP kernel suite as a :class:`repro.tasks.api.TaskGraph`.

    Wave 1 runs the five independent kernels; wave 2 runs betweenness
    centrality (reusing nothing device-side, but gated on ``bfs`` so the
    graph actually exercises dependencies) and a ``summary`` reduction over
    every kernel's output. Each task blocks on its device result so the
    scheduler measures real completion, not async dispatch. Run it with
    ``gap_task_graph(adj, w).run(scope_or_substrate)``.
    """
    from repro.tasks.api import TaskGraph

    def done(x):
        return jax.block_until_ready(x)

    g = TaskGraph()
    g.task("bfs", lambda: done(bfs(adj, source)))
    g.task("cc", lambda: done(connected_components(adj)))
    g.task("pagerank", lambda: done(pagerank(adj)))
    g.task("sssp", lambda: done(sssp(w, source)))
    g.task("tc", lambda: done(triangle_count(adj)))
    g.task("bc", lambda _bfs: done(betweenness_centrality(adj, source)),
           deps=("bfs",))
    g.task(
        "summary",
        lambda b, c, pr, d, t, bc_: {
            "reached": int((np.asarray(b) >= 0).sum()),
            "components": int(len(np.unique(np.asarray(c)))),
            "pr_mass": float(np.asarray(pr).sum()),
            "finite_paths": int((np.asarray(d) < 1e8).sum()),
            "triangles": float(t),
            "max_bc": float(np.asarray(bc_).max()),
        },
        deps=("bfs", "cc", "pagerank", "sssp", "tc", "bc"),
    )
    return g
