"""Elastic restart: restore a checkpoint onto a different mesh.

Losing a pod (or growing one) changes the mesh, but checkpoints store
*global* arrays, so elastic restart is: rebuild the model on the surviving
mesh, derive that mesh's shardings from the same partition rules, and restore
with those shardings. This module packages that flow and a standalone
`reshard_state` for live state (no disk round-trip).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax

from repro import sharding as shd
from repro.checkpoint.manager import CheckpointManager


def reshard_state(state, new_mesh) -> Any:
    """Re-place live state onto a new mesh per the global partition rules."""
    shardings = shd.named_shardings(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state),
        new_mesh,
    )
    return jax.tree.map(jax.device_put, state, shardings)


def elastic_restore(mgr: CheckpointManager, template, new_mesh,
                    step=None) -> Tuple[Any, int]:
    """Restore the latest checkpoint sharded for `new_mesh` (which may have a
    different shape than the mesh that wrote it)."""
    shardings = shd.named_shardings(template, new_mesh)
    return mgr.restore(template, step=step, shardings=shardings)
