from repro.checkpoint.manager import (CheckpointCorruptError,  # noqa: F401
                                      CheckpointManager)
from repro.checkpoint.reshard import elastic_restore, reshard_state  # noqa: F401
