from repro.checkpoint.manager import CheckpointManager  # noqa: F401
from repro.checkpoint.reshard import elastic_restore, reshard_state  # noqa: F401
