"""Fault-tolerant checkpointing.

Design (1000+ node posture, see docs/schedulers.md for the substrate layer):
  * atomic: write into ``step_<n>.tmp`` then ``os.replace`` to ``step_<n>``;
    a manifest is the last file written, so a partially-written checkpoint is
    never restorable.
  * asynchronous: serialization to host memory happens on the main thread
    (cheap `jax.device_get`), then the save flows through a two-stage
    streaming pipeline (`repro.stream`): a **serialize** stage writes the
    tmp dir, a **publish** stage atomically renames and GCs — so
    back-to-back `save()` calls overlap (save N+1 serializes while save N
    publishes) instead of serializing behind a lock, and training
    continues while bytes hit disk (`wake_up_hint` before the save
    window, `sleep_hint` after). This is a production use of the paper's
    API, not a demo.
  * retention: keep the newest ``keep`` checkpoints.
  * restore: latest valid manifest wins; arrays are `device_put` with the
    *current* mesh's shardings, so restoring onto a different topology
    (elastic restart after losing a pod) is the same code path — see
    `repro.checkpoint.reshard`.
  * multi-host: each host writes `shard-<h>` subdirs of its addressable
    shards (single-process here, noted in the manifest).
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.core.schedulers import Scheduler
from repro.stream import Pipeline, Stage, StreamFailure
from repro.tasks.api import TaskGroupError

MANIFEST = "manifest.json"


def _flat(tree) -> dict[str, Any]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        flat[key] = leaf
    return flat


def _unflat_into(template, flat: dict):
    def fill(kp, leaf):
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        return flat[key]

    return jax.tree_util.tree_map_with_path(fill, template)


class CheckpointManager:
    """``scheduler`` selects the host-overlap substrate for async saves: a
    ``repro.core.schedulers`` registry name or a not-yet-started
    ``Scheduler`` instance (default: the paper's Relic runtime).

    Async saves flow through a 2-stage :class:`repro.stream.Pipeline`
    (serialize → publish). A registry name hosts each stage on its own
    assistant, so consecutive saves overlap; an instance substrate fuses
    both stages onto its single worker; ``"serial"`` (or ``async_=False``)
    writes synchronously on the caller. Each in-flight save serializes
    into a *sequence-unique* tmp dir (``step_<n>.tmp-<seq>``), so two
    overlapped saves of the same step never collide; the publish stage is
    the single FIFO owner of rename + GC, preserving the atomicity
    invariant (manifest last, ``os.replace`` to the final name) without
    the old ``_write_lock`` — one owner per resource instead of one lock
    around all of them.
    """

    def __init__(self, directory: str | Path, keep: int = 3,
                 async_: bool = True, scheduler: "str | Scheduler" = "relic"):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_ = async_
        self._seq = 0          # distinguishes overlapped tmp dirs
        self._pending = 0      # saves fed but not yet collected by wait()
        self._pipe: Optional[Pipeline] = None
        if async_:
            if isinstance(scheduler, str):
                nodes = [
                    Stage(self._serialize, name="ckpt-serialize",
                          capacity=4, substrate=scheduler),
                    Stage(self._publish, name="ckpt-publish",
                          capacity=4, substrate=scheduler),
                ]
            else:
                def serialize_publish(item: tuple) -> int:
                    return self._publish(self._serialize(item))
                nodes = [Stage(serialize_publish, name="ckpt-write",
                               capacity=4, substrate=scheduler)]
            self._pipe = Pipeline(nodes, capacity=4).start()
            self._pipe.pause()   # park until the first save window

    # ------------------------------------------------------------------ save

    def save(self, state, step: int, *, block: bool = False) -> None:
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flat(state).items()}
        seq = self._seq
        self._seq += 1
        if self._pipe is not None:
            self._pipe.resume()
            self._pipe.put((seq, host, step))
            self._pending += 1
            if block:
                self.wait()
        else:
            self._publish(self._serialize((seq, host, step)))

    def wait(self) -> None:
        """Drain outstanding saves; re-raises write errors (several failed
        saves surface together as ``TaskGroupError``)."""
        if self._pipe is None:
            return
        errors: List[BaseException] = []
        while self._pending:
            out = self._pipe.get_raw()
            self._pending -= 1
            if type(out) is StreamFailure:
                errors.append(out.error)
        self._pipe.pause()
        if len(errors) == 1:
            raise errors[0]
        if errors:
            raise TaskGroupError(errors)

    def _serialize(self, item: tuple) -> tuple:
        """Stage 1: write the tmp dir (the byte-heavy half of a save)."""
        seq, host, step = item
        tmp = self.dir / f"step_{step:08d}.tmp-{seq}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        entries = {}
        for key, arr in host.items():
            fname = key.replace("/", "__") + ".npy"
            logical = str(arr.dtype)
            if arr.dtype.kind not in "biufc":  # ml_dtypes (bfloat16, fp8...)
                arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            np.save(tmp / fname, arr)
            entries[key] = {"file": fname, "shape": list(arr.shape),
                            "dtype": logical}
        manifest = {"step": step, "time": time.time(), "entries": entries,
                    "hosts": 1}
        (tmp / MANIFEST).write_text(json.dumps(manifest))
        return (step, tmp)

    def _publish(self, item: tuple) -> int:
        """Stage 2: atomic rename + retention GC. Saves pass through here
        in submission order (the pipeline is FIFO), and this stage is the
        sole toucher of final names — the one-writer invariant the old
        ``_write_lock`` bought, now held structurally."""
        step, tmp = item
        final = self.dir / f"step_{step:08d}"
        if final.exists():  # idempotent re-save of the same step
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()
        return step

    def _gc(self) -> None:
        done = sorted(p for p in self.dir.glob("step_*")
                      if ".tmp" not in p.name)
        for p in done[: -self.keep] if self.keep else []:
            shutil.rmtree(p, ignore_errors=True)

    # --------------------------------------------------------------- restore

    def latest_step(self) -> Optional[int]:
        steps = []
        for p in sorted(self.dir.glob("step_*")):
            if ".tmp" in p.name or not (p / MANIFEST).exists():
                continue
            steps.append(int(p.name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int]:
        """Restore into `template`'s structure; `shardings` (optional pytree)
        places each array on the current mesh — the elastic-restart path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / MANIFEST).read_text())
        flat_t = _flat(template)
        flat_s = _flat(shardings) if shardings is not None else {}
        out = {}
        for key, ent in manifest["entries"].items():
            if key not in flat_t:
                continue  # forward-compat: ignore unknown entries
            arr = np.load(d / ent["file"])
            logical = np.dtype(jax.numpy.dtype(ent["dtype"]))
            if arr.dtype != logical:
                arr = arr.view(logical)  # bf16 etc. stored as raw uint views
            if key in flat_s:
                out[key] = jax.device_put(arr, flat_s[key])
            else:
                out[key] = jax.device_put(arr)
        missing = set(flat_t) - set(out)
        if missing:
            raise KeyError(f"checkpoint missing {sorted(missing)[:5]}...")
        return _unflat_into(template, out), step

    def close(self) -> None:
        if self._pipe is not None:
            try:
                self.wait()             # surfaces pending write errors
            finally:
                pipe, self._pipe = self._pipe, None
                pipe.close()            # but never leaks the worker threads
