"""Fault-tolerant checkpointing.

Design (1000+ node posture, see docs/schedulers.md for the substrate layer):
  * atomic: write into ``step_<n>.tmp`` then ``os.replace`` to ``step_<n>``;
    a manifest is the last file written, so a partially-written checkpoint is
    never restorable.
  * asynchronous: serialization to host memory happens on the main thread
    (cheap `jax.device_get`), the file I/O runs on the **Relic assistant**
    (`wake_up_hint` before the save window, `sleep_hint` after) — training
    continues while bytes hit disk. This is a production use of the paper's
    API, not a demo.
  * retention: keep the newest ``keep`` checkpoints.
  * restore: latest valid manifest wins; arrays are `device_put` with the
    *current* mesh's shardings, so restoring onto a different topology
    (elastic restart after losing a pod) is the same code path — see
    `repro.checkpoint.reshard`.
  * multi-host: each host writes `shard-<h>` subdirs of its addressable
    shards (single-process here, noted in the manifest).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.core.schedulers import Scheduler
from repro.tasks.api import TaskScope

MANIFEST = "manifest.json"


def _flat(tree) -> dict[str, Any]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        flat[key] = leaf
    return flat


def _unflat_into(template, flat: dict):
    def fill(kp, leaf):
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        return flat[key]

    return jax.tree_util.tree_map_with_path(fill, template)


class CheckpointManager:
    """``scheduler`` selects the host-overlap substrate for async saves: a
    ``repro.core.schedulers`` registry name or a not-yet-started
    ``Scheduler`` instance (default: the paper's Relic runtime). Async
    writes run inside a long-lived :class:`repro.tasks.api.TaskScope`
    whose ``barrier()`` (see :meth:`wait`) closes each save window."""

    def __init__(self, directory: str | Path, keep: int = 3,
                 async_: bool = True, scheduler: "str | Scheduler" = "relic"):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_ = async_
        # _write/_gc assume one writer at a time; multi-worker substrates
        # (pool) could otherwise interleave two saves on the same paths.
        self._write_lock = threading.Lock()
        self._scope: Optional[TaskScope] = None
        if async_:
            self._scope = TaskScope(scheduler)
            self._scope.sleep_hint()   # park until the first save window

    # ------------------------------------------------------------------ save

    def save(self, state, step: int, *, block: bool = False) -> None:
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flat(state).items()}
        if self._scope is not None:
            self._scope.wake_up_hint()
            self._scope.submit(self._write, host, step)
            if block:
                self.wait()
        else:
            self._write(host, step)

    def wait(self) -> None:
        """Barrier on outstanding writes; re-raises write errors (several
        failed saves surface together as ``TaskGroupError``)."""
        if self._scope is not None:
            self._scope.barrier()
            self._scope.sleep_hint()

    def _write(self, host: dict, step: int) -> None:
        with self._write_lock:
            self._write_locked(host, step)

    def _write_locked(self, host: dict, step: int) -> None:
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        entries = {}
        for key, arr in host.items():
            fname = key.replace("/", "__") + ".npy"
            logical = str(arr.dtype)
            if arr.dtype.kind not in "biufc":  # ml_dtypes (bfloat16, fp8...)
                arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            np.save(tmp / fname, arr)
            entries[key] = {"file": fname, "shape": list(arr.shape),
                            "dtype": logical}
        manifest = {"step": step, "time": time.time(), "entries": entries,
                    "hosts": 1}
        (tmp / MANIFEST).write_text(json.dumps(manifest))
        if final.exists():  # idempotent re-save of the same step
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        done = sorted(p for p in self.dir.glob("step_*")
                      if not p.name.endswith(".tmp"))
        for p in done[: -self.keep] if self.keep else []:
            shutil.rmtree(p, ignore_errors=True)

    # --------------------------------------------------------------- restore

    def latest_step(self) -> Optional[int]:
        steps = []
        for p in sorted(self.dir.glob("step_*")):
            if p.name.endswith(".tmp") or not (p / MANIFEST).exists():
                continue
            steps.append(int(p.name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int]:
        """Restore into `template`'s structure; `shardings` (optional pytree)
        places each array on the current mesh — the elastic-restart path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / MANIFEST).read_text())
        flat_t = _flat(template)
        flat_s = _flat(shardings) if shardings is not None else {}
        out = {}
        for key, ent in manifest["entries"].items():
            if key not in flat_t:
                continue  # forward-compat: ignore unknown entries
            arr = np.load(d / ent["file"])
            logical = np.dtype(jax.numpy.dtype(ent["dtype"]))
            if arr.dtype != logical:
                arr = arr.view(logical)  # bf16 etc. stored as raw uint views
            if key in flat_s:
                out[key] = jax.device_put(arr, flat_s[key])
            else:
                out[key] = jax.device_put(arr)
        missing = set(flat_t) - set(out)
        if missing:
            raise KeyError(f"checkpoint missing {sorted(missing)[:5]}...")
        return _unflat_into(template, out), step

    def close(self) -> None:
        if self._scope is not None:
            try:
                self._scope.barrier()   # surfaces pending write errors
            finally:
                self._scope.close()     # but never leaks the worker thread
                self._scope = None
