"""Fault-tolerant checkpointing.

Design (1000+ node posture, see docs/schedulers.md for the substrate layer):
  * atomic: write into ``step_<n>.tmp`` then ``os.replace`` to ``step_<n>``;
    a manifest is the last file written, so a partially-written checkpoint is
    never restorable.
  * asynchronous: serialization to host memory happens on the main thread
    (cheap `jax.device_get`), then the save flows through a two-stage
    streaming pipeline (`repro.stream`): a **serialize** stage writes the
    tmp dir, a **publish** stage atomically renames and GCs — so
    back-to-back `save()` calls overlap (save N+1 serializes while save N
    publishes) instead of serializing behind a lock, and training
    continues while bytes hit disk (`wake_up_hint` before the save
    window, `sleep_hint` after). This is a production use of the paper's
    API, not a demo.
  * retention: keep the newest ``keep`` checkpoints — but never collect
    the last manifest-valid one, even when ``keep`` would (a retention
    sweep must not delete the only thing ``--resume`` can use).
  * crash-consistent restore: the manifest carries ``format_version`` and
    (by default, ``RELIC_CKPT_CHECKSUM``) a CRC32 per entry over the
    stored bytes. ``latest_step()`` only counts steps whose manifest
    *parses and validates* (a torn ``manifest.json`` is skipped with a
    warning, not raised); ``restore()`` verifies entry checksums and falls
    back to the next-latest valid step, quarantining a corrupt dir as
    ``<dir>.corrupt`` (kept for post-mortem, never deleted) rather than
    restoring torn state. Crash points are deterministically testable via
    ``repro.runtime.chaos.FsFaultInjector``.
  * restore placement: arrays are `device_put` with the *current* mesh's
    shardings, so restoring onto a different topology (elastic restart
    after losing a pod) is the same code path — see
    `repro.checkpoint.reshard`.
  * multi-host: each host writes `shard-<h>` subdirs of its addressable
    shards (single-process here, noted in the manifest).
"""

from __future__ import annotations

import json
import os
import shutil
import time
import warnings
import zlib
from pathlib import Path
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.core.schedulers import Scheduler
from repro.runtime.config import resolve_checkpoint_config
from repro.stream import Pipeline, Stage, StreamFailure
from repro.tasks.api import TaskGroupError

MANIFEST = "manifest.json"
#: Manifest schema version. 1 = pre-checksum (implicit — no
#: ``format_version`` key); 2 = per-entry ``crc32``/``nbytes`` +
#: ``format_version``. Restore accepts both; an *unknown* (future) version
#: is treated like a torn manifest: skip-and-warn, fall back.
FORMAT_VERSION = 2


class CheckpointCorruptError(RuntimeError):
    """A specific requested checkpoint failed validation (torn manifest,
    missing entry file, CRC mismatch). Only raised for an *explicit*
    ``restore(step=...)`` — latest-wins restore falls back instead."""


def _flat(tree) -> dict[str, Any]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        flat[key] = leaf
    return flat


def _unflat_into(template, flat: dict):
    def fill(kp, leaf):
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        return flat[key]

    return jax.tree_util.tree_map_with_path(fill, template)


class CheckpointManager:
    """``scheduler`` selects the host-overlap substrate for async saves: a
    ``repro.core.schedulers`` registry name or a not-yet-started
    ``Scheduler`` instance (default: the paper's Relic runtime).

    Async saves flow through a 2-stage :class:`repro.stream.Pipeline`
    (serialize → publish). A registry name hosts each stage on its own
    assistant, so consecutive saves overlap; an instance substrate fuses
    both stages onto its single worker; ``"serial"`` (or ``async_=False``)
    writes synchronously on the caller. Each in-flight save serializes
    into a *sequence-unique* tmp dir (``step_<n>.tmp-<seq>``), so two
    overlapped saves of the same step never collide; the publish stage is
    the single FIFO owner of rename + GC, preserving the atomicity
    invariant (manifest last, ``os.replace`` to the final name) without
    the old ``_write_lock`` — one owner per resource instead of one lock
    around all of them.
    """

    def __init__(self, directory: str | Path, keep: int = 3,
                 async_: bool = True, scheduler: "str | Scheduler" = "relic",
                 checksum: Optional[bool] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_ = async_
        self.checksum = resolve_checkpoint_config(checksum=checksum).checksum
        self._seq = 0          # distinguishes overlapped tmp dirs
        self._pending = 0      # saves fed but not yet collected by wait()
        self._pipe: Optional[Pipeline] = None
        # Opt-in chaos hook (None in production): consulted at the named
        # filesystem crash points of _serialize/_publish. See
        # repro.runtime.chaos.FsFaultInjector.
        self._chaos_fs: Optional[Any] = None
        if async_:
            if isinstance(scheduler, str):
                nodes = [
                    Stage(self._serialize, name="ckpt-serialize",
                          capacity=4, substrate=scheduler),
                    Stage(self._publish, name="ckpt-publish",
                          capacity=4, substrate=scheduler),
                ]
            else:
                def serialize_publish(item: tuple) -> int:
                    return self._publish(self._serialize(item))
                nodes = [Stage(serialize_publish, name="ckpt-write",
                               capacity=4, substrate=scheduler)]
            self._pipe = Pipeline(nodes, capacity=4).start()
            self._pipe.pause()   # park until the first save window

    # ------------------------------------------------------------------ save

    def save(self, state, step: int, *, block: bool = False) -> None:
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flat(state).items()}
        seq = self._seq
        self._seq += 1
        if self._pipe is not None:
            self._pipe.resume()
            self._pipe.put((seq, host, step))
            self._pending += 1
            if block:
                self.wait()
        else:
            self._publish(self._serialize((seq, host, step)))

    def wait(self) -> None:
        """Drain outstanding saves; re-raises write errors (several failed
        saves surface together as ``TaskGroupError``)."""
        if self._pipe is None:
            return
        errors: List[BaseException] = []
        while self._pending:
            out = self._pipe.get_raw()
            self._pending -= 1
            if type(out) is StreamFailure:
                errors.append(out.error)
        self._pipe.pause()
        if len(errors) == 1:
            raise errors[0]
        if errors:
            raise TaskGroupError(errors)

    def _serialize(self, item: tuple) -> tuple:
        """Stage 1: write the tmp dir (the byte-heavy half of a save)."""
        seq, host, step = item
        fs = self._chaos_fs
        if fs is not None:
            fs.at("serialize-start", step)
        tmp = self.dir / f"step_{step:08d}.tmp-{seq}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        entries = {}
        for key, arr in host.items():
            fname = key.replace("/", "__") + ".npy"
            logical = str(arr.dtype)
            if arr.dtype.kind not in "biufc":  # ml_dtypes (bfloat16, fp8...)
                arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            np.save(tmp / fname, arr)
            ent = {"file": fname, "shape": list(arr.shape),
                   "dtype": logical}
            if self.checksum:
                # CRC over the stored payload bytes (post uint view): the
                # same bytes restore hashes after np.load, so a torn or
                # bit-flipped entry file cannot verify.
                stored = np.ascontiguousarray(arr)
                ent["crc32"] = zlib.crc32(stored.tobytes())
                ent["nbytes"] = int(stored.nbytes)
            entries[key] = ent
            if fs is not None:
                fs.entry_written(tmp / fname, step)
        manifest = {"format_version": FORMAT_VERSION, "step": step,
                    "time": time.time(), "entries": entries, "hosts": 1,
                    "checksum": self.checksum}
        text = json.dumps(manifest)
        if fs is not None:
            fs.write_manifest(tmp / MANIFEST, text, step)
        else:
            (tmp / MANIFEST).write_text(text)
        return (step, tmp)

    def _publish(self, item: tuple) -> int:
        """Stage 2: atomic rename + retention GC. Saves pass through here
        in submission order (the pipeline is FIFO), and this stage is the
        sole toucher of final names — the one-writer invariant the old
        ``_write_lock`` bought, now held structurally."""
        step, tmp = item
        fs = self._chaos_fs
        if fs is not None:
            fs.at("pre-publish", step)
        final = self.dir / f"step_{step:08d}"
        if final.exists():  # idempotent re-save of the same step
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()
        return step

    def _gc(self) -> None:
        done = sorted(p for p in self.dir.glob("step_*")
                      if ".tmp" not in p.name
                      and not p.name.endswith(".corrupt"))
        if not self.keep:
            return
        drop = done[: -self.keep]
        if drop and not any(
                self._load_manifest(p, warn=False) is not None
                for p in done[-self.keep:]):
            # Retention would delete every manifest-valid checkpoint (the
            # keep window holds only torn ones): spare the newest valid
            # dir below the window — --resume must always have something.
            spare = next((p for p in reversed(drop)
                          if self._load_manifest(p, warn=False) is not None),
                         None)
            if spare is not None:
                drop = [p for p in drop if p is not spare]
        for p in drop:
            shutil.rmtree(p, ignore_errors=True)

    # --------------------------------------------------------------- restore

    def _load_manifest(self, d: Path, warn: bool = True) -> Optional[dict]:
        """Parse and validate ``d``'s manifest; None (optionally with a
        warning) when it is missing, torn, structurally wrong, or written
        by an unknown future format — the skip-and-warn primitive
        ``latest_step``/``restore`` build their fallback on."""
        why = None
        manifest: Optional[dict] = None
        try:
            manifest = json.loads((d / MANIFEST).read_text())
        except FileNotFoundError:
            return None                 # mid-write dir: not even a warning
        except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
            why = f"unreadable manifest ({e})"
        if why is None:
            if not isinstance(manifest, dict):
                why = "manifest is not an object"
            elif not isinstance(manifest.get("entries"), dict) \
                    or not isinstance(manifest.get("step"), int):
                why = "manifest missing step/entries"
            elif manifest.get("format_version", 1) > FORMAT_VERSION:
                why = (f"unknown format_version "
                       f"{manifest.get('format_version')}")
        if why is not None:
            if warn:
                warnings.warn(
                    f"checkpoint {d.name}: {why}; skipping it",
                    RuntimeWarning, stacklevel=3)
            return None
        return manifest

    def valid_steps(self) -> List[int]:
        """Steps with a parseable, schema-valid manifest, ascending.
        (Manifest-valid, not checksum-verified — entry payloads are only
        hashed when actually restored.)"""
        steps = []
        for p in sorted(self.dir.glob("step_*")):
            if ".tmp" in p.name or p.name.endswith(".corrupt"):
                continue
            if self._load_manifest(p) is None:
                continue
            steps.append(int(p.name.split("_")[1]))
        return steps

    def latest_step(self) -> Optional[int]:
        steps = self.valid_steps()
        return steps[-1] if steps else None

    def _quarantine(self, d: Path) -> None:
        """Move a corrupt checkpoint dir aside as ``<name>.corrupt`` (kept
        for post-mortem — never deleted, never globbed as a step again)."""
        target = d.with_name(d.name + ".corrupt")
        n = 1
        while target.exists():
            target = d.with_name(f"{d.name}.corrupt-{n}")
            n += 1
        os.replace(d, target)
        warnings.warn(
            f"checkpoint {d.name}: corrupt; quarantined as {target.name}",
            RuntimeWarning, stacklevel=3)

    def _restore_step(self, d: Path, manifest: dict, template,
                      shardings) -> Any:
        """Load one validated manifest's entries, verifying checksums when
        the manifest carries them; raises :class:`CheckpointCorruptError`
        on any torn/mismatched entry."""
        flat_t = _flat(template)
        flat_s = _flat(shardings) if shardings is not None else {}
        out = {}
        for key, ent in manifest["entries"].items():
            if key not in flat_t:
                continue  # forward-compat: ignore unknown entries
            try:
                arr = np.load(d / ent["file"])
            except (OSError, ValueError, EOFError) as e:
                raise CheckpointCorruptError(
                    f"{d.name}/{ent['file']}: unreadable ({e})") from e
            if "crc32" in ent:
                stored = np.ascontiguousarray(arr)
                crc = zlib.crc32(stored.tobytes())
                if crc != ent["crc32"] or stored.nbytes != ent["nbytes"]:
                    raise CheckpointCorruptError(
                        f"{d.name}/{ent['file']}: checksum mismatch "
                        f"(crc {crc:#010x} != manifest "
                        f"{ent['crc32']:#010x})")
            logical = np.dtype(jax.numpy.dtype(ent["dtype"]))
            if arr.dtype != logical:
                arr = arr.view(logical)  # bf16 etc. stored as raw uint views
            if key in flat_s:
                out[key] = jax.device_put(arr, flat_s[key])
            else:
                out[key] = jax.device_put(arr)
        missing = set(flat_t) - set(out)
        if missing:
            raise KeyError(f"checkpoint missing {sorted(missing)[:5]}...")
        return _unflat_into(template, out)

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int]:
        """Restore into `template`'s structure; `shardings` (optional pytree)
        places each array on the current mesh — the elastic-restart path.

        With ``step=None`` (latest wins) a checkpoint that fails validation
        — torn manifest, missing or checksum-mismatched entry — is
        quarantined as ``.corrupt`` and the next-latest valid step is
        tried, so a crash mid-save can never brick the resume path. An
        *explicit* ``step=`` that fails validation raises
        :class:`CheckpointCorruptError` instead (the caller asked for that
        exact state; silently substituting another would be worse)."""
        if step is not None:
            d = self.dir / f"step_{step:08d}"
            manifest = self._load_manifest(d)
            if manifest is None:
                if not d.exists():
                    raise FileNotFoundError(f"no checkpoint {d}")
                raise CheckpointCorruptError(
                    f"{d.name}: invalid manifest")
            return self._restore_step(d, manifest, template, shardings), step
        tried = False
        for s in reversed(self.valid_steps()):
            tried = True
            d = self.dir / f"step_{s:08d}"
            manifest = self._load_manifest(d)
            if manifest is None:
                continue
            try:
                return (self._restore_step(d, manifest, template, shardings),
                        s)
            except CheckpointCorruptError:
                self._quarantine(d)
        if tried:
            raise FileNotFoundError(
                f"no restorable checkpoint under {self.dir} "
                "(every candidate was corrupt and has been quarantined)")
        raise FileNotFoundError(f"no checkpoint under {self.dir}")

    def close(self) -> None:
        if self._pipe is not None:
            try:
                self.wait()             # surfaces pending write errors
            finally:
                pipe, self._pipe = self._pipe, None
                pipe.close()            # but never leaks the worker threads
