"""Version-compat shims over JAX API drift.

Three APIs the repo uses moved between JAX releases: ``jax.make_mesh`` grew
an ``axis_types=`` keyword (and ``jax.sharding.AxisType`` appeared) after
0.4.x, ``jax.sharding.AbstractMesh`` changed from a single
``((name, size), ...)`` shape tuple to separate ``(sizes, names)``
arguments, and ``shard_map`` was promoted from ``jax.experimental`` to
``jax.shard_map`` (gaining ``axis_names=``). Every mesh and every
shard_map in the repo goes through these helpers so the support matrix
lives in one file.
"""

from __future__ import annotations

from typing import Sequence

import jax


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    shape, axes = tuple(shape), tuple(axes)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # pre-AxisType JAX: all axes are Auto implicitly
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Device-free mesh carrying only axis names/sizes, on any JAX."""
    shape, axes = tuple(shape), tuple(axes)
    try:
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:  # 0.4.x signature: AbstractMesh(((name, size), ...))
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict (0.4.x returned a
    one-element list of per-computation dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """``jax.shard_map`` where available, else the 0.4.x experimental one.

    ``axis_names`` (the new API's vma declaration) is forwarded when
    supported; the experimental version has no vma type system, so there it
    is dropped and replication checking is disabled for the collective
    loops it would have described (``check_rep=False``).
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kwargs = {} if axis_names is None else {"axis_names": axis_names}
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kwargs)
    from jax.experimental.shard_map import shard_map as sm_old

    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
