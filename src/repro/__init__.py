"""Relic-JAX: fine-grained two-lane task parallelism (Los & Petushkov 2024)
as a multi-pod JAX training/serving framework. See README.md and
docs/schedulers.md."""

__version__ = "0.1.0"
