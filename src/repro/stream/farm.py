"""Task farm: FastFlow's emitter → N workers → collector over SPSC rings.

A farm parallelizes ONE stage across N worker assistants while keeping
every ring 1P1C (Aldinucci et al., 2009 — no MPMC queue appears even
though N workers share the load):

* the **emitter** assistant is the sole consumer of the farm's input ring
  and the sole producer of each worker's private input ring (N rings, one
  producer each);
* each **worker** assistant (a plain :class:`Stage` wrapping the farm fn)
  is the sole consumer of its input ring and sole producer of its output
  ring;
* the **collector** assistant is the sole consumer of every worker output
  ring and the sole producer of the farm's output ring.

The emitter deals round-robin with a skip-if-full scan (a full — i.e.
slow — worker loses its turn instead of stalling the whole farm; the
bounded wait only engages when *every* worker ring is full). The emitter
tags each item with a sequence number; with ``ordered=True`` (default)
the collector releases results in exactly input order using the same
index-stash pattern ``PrefetchPipeline`` used for its in-order window —
out-of-order results park in a dict keyed by sequence until their turn.
``ordered=False`` releases in completion order (lower latency, no stash).

Failure semantics are fail-stop per assistant, like Relic: an item whose
fn raised becomes an in-stream :class:`StreamFailure` (the farm keeps
going), but a *dead worker assistant* (non-``Exception`` escape, killed
thread) is unrecoverable — the collector's bounded wait detects it,
drains what the worker already published, and raises
:class:`RelicDeadError`, which cascades through the liveness probes to
the driver.

A ``Farm`` presents the same node interface as :class:`Stage`, so it
drops into a :class:`repro.stream.Pipeline` anywhere a stage fits
(``Pipeline([pre, Farm(heavy, workers=4), post])``).
"""

from __future__ import annotations

import time
from typing import Any, Callable, List, Optional

from repro.core.relic import RelicDeadError
from repro.core.spsc import DEFAULT_CAPACITY, SpscRing
from repro.stream.stage import (STOP, Stage, StreamFailure, StreamUsageError,
                                _always_alive)

__all__ = ["Farm"]


class _Emitter(Stage):
    """Deals tagged items round-robin into the worker input rings."""

    def __init__(self, farm: "Farm", **kwargs: Any):
        super().__init__(None, name=f"{farm.name}-emit", **kwargs)
        self._farm = farm

    def _run_loop(self) -> None:
        farm = self._farm
        pop = self._in.pop
        rings = farm._worker_in
        workers = farm._workers
        n = len(rings)
        probe_every = self._probe_every
        pause_every = self._pause_every
        rr = 0
        seq = 0
        spins = 0
        while True:
            item = pop()
            if item is None:
                spins += 1
                if self._parked:
                    time.sleep(200e-6)    # parked idle (see Stage.sleep_hint)
                elif spins % pause_every == 0:
                    time.sleep(0)
                if not (probe_every and spins % probe_every == 0):
                    continue
                if self._upstream_alive():
                    continue
                item = pop()
                if item is None:
                    raise self._dead_upstream()
            spins = 0
            if item is STOP:
                for i in range(n):
                    self._broadcast_stop(rings[i], workers[i])
                return
            self.items_in += 1
            payload = (seq, item)
            seq += 1
            # Skip-if-full deal: first ring with space starting at rr.
            wait_spins = 0
            while True:
                placed = False
                for k in range(n):
                    i = (rr + k) % n
                    if rings[i].push(payload):
                        rr = i + 1
                        placed = True
                        break
                if placed:
                    break
                wait_spins += 1
                if wait_spins % pause_every == 0:
                    time.sleep(0)
                if (probe_every and wait_spins % probe_every == 0
                        and not any(w.alive() for w in workers)):
                    raise RelicDeadError(
                        f"farm {farm.name!r}: every worker is dead",
                        self.items_in, self.items_out,
                        self.items_in - self.items_out)
            self.items_out += 1

    def _broadcast_stop(self, ring: SpscRing, worker: Stage) -> None:
        if ring.push(STOP):
            return
        spins = 0
        while True:
            spins += 1
            if spins % self._pause_every == 0:
                time.sleep(0)
            if (self._probe_every and spins % self._probe_every == 0
                    and not worker.alive()):
                return      # dead worker: the collector's probe accounts it
            if ring.push(STOP):
                return


class _Collector(Stage):
    """Merges worker outputs; optional in-order release by sequence."""

    def __init__(self, farm: "Farm", **kwargs: Any):
        super().__init__(None, name=f"{farm.name}-collect", **kwargs)
        self._farm = farm

    def _run_loop(self) -> None:
        farm = self._farm
        workers = farm._workers
        outs = [w.out_ring for w in workers]
        n = len(outs)
        ordered = farm.ordered
        probe_every = self._probe_every
        pause_every = self._pause_every
        stops = [False] * n
        remaining = n
        stash: dict = {}
        next_rel = 0
        spins = 0

        def release(item: Any) -> None:
            nonlocal next_rel
            seq, payload = item
            self.items_in += 1
            if ordered:
                stash[seq] = payload
                while next_rel in stash:
                    self._push_out(stash.pop(next_rel))
                    next_rel += 1
                    self.items_out += 1
            else:
                self._push_out(payload)
                self.items_out += 1

        while remaining:
            progress = False
            for i in range(n):
                if stops[i]:
                    continue
                item = outs[i].pop()
                if item is None:
                    continue
                progress = True
                if item is STOP:
                    stops[i] = True
                    remaining -= 1
                else:
                    release(item)
            if progress:
                spins = 0
                continue
            spins += 1
            if self._parked:
                time.sleep(200e-6)        # parked idle (see Stage.sleep_hint)
            elif spins % pause_every == 0:
                time.sleep(0)
            if not (probe_every and spins % probe_every == 0):
                continue
            for i in range(n):
                if stops[i] or workers[i].alive():
                    continue
                item = outs[i].pop()   # racing final publication
                if item is STOP:
                    stops[i] = True
                    remaining -= 1
                elif item is not None:
                    release(item)
                else:
                    raise RelicDeadError(
                        f"farm {farm.name!r} worker {workers[i].name!r}",
                        self.items_in, self.items_out, len(stash))
        if stash:
            # Unreachable with live workers: sequence gaps only arise from
            # a dead worker, which raised above. Fail loudly over silently
            # reordering.
            raise RelicDeadError(
                f"farm {farm.name!r}: {len(stash)} items lost in-flight",
                self.items_in, self.items_out, len(stash))
        self._push_out(STOP)


class Farm:
    """Emitter → ``workers`` parallel stages → collector, as one node.

    ``fn`` is applied to each item by whichever worker the emitter dealt
    it to; ``ordered`` controls collector release order (input order vs
    completion order). ``substrate`` must be a registry *name* — a farm
    hosts ``workers + 2`` loops, so each gets its own instance; a single
    ``Scheduler`` instance cannot be shared (wrap the fn in a plain
    ``Stage`` for that). With a ``workers=0`` substrate the enclosing
    Pipeline runs the farm inline (``fn`` applied directly).
    """

    def __init__(self, fn: Callable[[Any], Any], *, workers: int = 2,
                 name: Optional[str] = None,
                 capacity: int = DEFAULT_CAPACITY,
                 substrate: str = "relic", ordered: bool = True,
                 record: bool = False):
        if not isinstance(substrate, str):
            raise StreamUsageError(
                "Farm needs a substrate registry name (it hosts "
                f"workers+2 assistant loops), got {type(substrate).__name__}")
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.fn = fn
        self.name = name or getattr(fn, "__name__", None) or "farm"
        self.capacity = capacity
        self.ordered = ordered
        self._emitter = _Emitter(self, capacity=1, substrate=substrate)
        self._workers: List[Stage] = [
            Stage(self._work, name=f"{self.name}-w{i}", capacity=capacity,
                  substrate=substrate, record=record)
            for i in range(workers)
        ]
        self._worker_in: List[SpscRing] = [SpscRing(capacity)
                                           for _ in range(workers)]
        self._collector = _Collector(self, capacity=capacity,
                                     substrate=substrate)
        self._collector.connect(SpscRing(1), _always_alive)  # loop is custom
        for w, ring in zip(self._workers, self._worker_in):
            w.connect(ring, self._emitter.alive)
            w.set_downstream_alive(self._collector.alive)
        self._all = [self._emitter, *self._workers, self._collector]
        self.workers = 0 if any(s.workers == 0 for s in self._all) else 1
        self.record = record

    def _work(self, tagged: tuple) -> tuple:
        seq, item = tagged
        if type(item) is StreamFailure:
            return tagged               # upstream failure: pass through
        try:
            return (seq, self.fn(item))
        except Exception as e:
            return (seq, StreamFailure(e, self.name))

    # -- node interface (same shape as Stage) ------------------------------
    @property
    def out_ring(self) -> SpscRing:
        return self._collector.out_ring

    @property
    def items_in(self) -> int:
        return self._emitter.items_in

    @items_in.setter
    def items_in(self, v: int) -> None:        # inline-mode accounting
        self._emitter.items_in = v

    @property
    def items_out(self) -> int:
        return self._collector.items_out

    @items_out.setter
    def items_out(self, v: int) -> None:
        self._collector.items_out = v

    def connect(self, in_ring: SpscRing, upstream_alive) -> None:
        self._emitter.connect(in_ring, upstream_alive)

    def set_downstream_alive(self, probe) -> None:
        self._collector.set_downstream_alive(probe)

    def start(self) -> "Farm":
        # Sink-first (collector, workers, emitter) so every probe target
        # is already running when its prober's loop begins.
        self._collector.start()
        for w in self._workers:
            w.start()
        self._emitter.start()
        return self

    def alive(self) -> bool:
        return self._collector.alive()

    def error(self) -> Optional[BaseException]:
        for s in (self._collector, *self._workers, self._emitter):
            e = s.error()
            if e is not None:
                return e
        return None

    def join(self, timeout: Optional[float] = None) -> None:
        for s in self._all:
            s.join(timeout)

    def close(self) -> None:
        for s in self._all:
            s.close()

    def sleep_hint(self) -> None:
        for s in self._all:
            s.sleep_hint()

    def wake_up_hint(self) -> None:
        for s in self._all:
            s.wake_up_hint()

    def stats(self) -> dict:
        return {
            "name": self.name,
            "items_in": self.items_in,
            "items_out": self.items_out,
            "ordered": self.ordered,
            "workers": [w.stats() for w in self._workers],
        }

    def __repr__(self) -> str:
        return (f"Farm({self.name!r}, workers={len(self._workers)}, "
                f"ordered={self.ordered})")
