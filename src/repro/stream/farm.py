"""Task farm: FastFlow's emitter → N workers → collector over SPSC rings.

A farm parallelizes ONE stage across N worker assistants while keeping
every ring 1P1C (Aldinucci et al., 2009 — no MPMC queue appears even
though N workers share the load):

* the **emitter** assistant is the sole consumer of the farm's input ring
  and the sole producer of each worker's private input ring (N rings, one
  producer each);
* each **worker** assistant (a plain :class:`Stage` wrapping the farm fn)
  is the sole consumer of its input ring and sole producer of its output
  ring;
* the **collector** assistant is the sole consumer of every worker output
  ring and the sole producer of the farm's output ring.

The emitter deals round-robin with a skip-if-full scan (a full — i.e.
slow — worker loses its turn instead of stalling the whole farm; the
bounded wait only engages when *every* worker ring is full). The emitter
tags each item with a sequence number; with ``ordered=True`` (default)
the collector releases results in exactly input order using the same
index-stash pattern ``PrefetchPipeline`` used for its in-order window —
out-of-order results park in a dict keyed by sequence until their turn.
``ordered=False`` releases in completion order (lower latency, no stash).

Failure semantics are fail-stop per assistant, like Relic: an item whose
fn raised becomes an in-stream :class:`StreamFailure` (the farm keeps
going), but a *dead worker assistant* (non-``Exception`` escape, killed
thread) takes its in-flight items with it. The farm accounts for that
loss **exactly**: the emitter keeps a per-worker dealt ledger (appended
before every push, retired by the collector on every release), so when
the collector's bounded wait detects a dead worker the lost in-flight
tags are precisely dealt-minus-released. What happens next is the PR 8
quarantine/respawn discipline lifted up a stratum:

* ``respawn=False`` (default): the collector quarantines the slot and
  raises :class:`StageFailedError` carrying the lost tag set — callers
  know exactly which items to re-submit instead of guessing from a count.
* ``respawn=True``: the collector quarantines the slot (the emitter stops
  dealing to the dead ring), swaps in a **fresh** worker stage with fresh
  rings (every ring keeps exactly one producer and one consumer for its
  whole lifetime), and hands the lost ``(tag, item)`` pairs back to the
  emitter over a dedicated 1P1C redeal ring for idempotent re-emit under
  their *original* sequence tags. The collector dedups releases by tag,
  so replay is exactly-once even if a copy ever raced through. A worker
  that dies after end-of-stream (its STOP already dealt or the emitter
  already draining) is recovered *inline* at the collector — same tags,
  same exactly-once ledger, no emitter involvement needed.

A ``Farm`` presents the same node interface as :class:`Stage`, so it
drops into a :class:`repro.stream.Pipeline` anywhere a stage fits
(``Pipeline([pre, Farm(heavy, workers=4), post])``).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Tuple

from repro.core.relic import RelicDeadError
from repro.core.spsc import DEFAULT_CAPACITY, SpscRing
from repro.stream.stage import (STOP, Stage, StageFailedError, StreamFailure,
                                StreamUsageError, _always_alive)

__all__ = ["Farm", "WorkerFailure"]


@dataclass(frozen=True)
class WorkerFailure:
    """One dead farm worker, fully accounted (the stream-layer analogue of
    ``repro.core.relic_pool.LaneFailure``): which slot died, exactly which
    sequence tags were in flight with it (dealt-minus-released), the fatal
    error, and how the farm recovered — ``respawned`` (fresh worker in the
    slot), ``reemitted`` (tags replayed through the emitter; ``False``
    with ``respawned`` unset means they were replayed inline at the
    collector after end-of-stream). ``detected_s``/``recovered_s`` are
    ``perf_counter`` stamps for detection/recovery latency measurement."""

    worker_index: int
    worker_name: str
    lost_tags: Tuple[int, ...]
    error: Optional[BaseException]
    respawned: bool
    reemitted: bool
    detected_s: float
    recovered_s: float


class _Emitter(Stage):
    """Deals tagged items round-robin into the worker input rings."""

    def __init__(self, farm: "Farm", **kwargs: Any):
        super().__init__(None, name=f"{farm.name}-emit", **kwargs)
        self._farm = farm
        #: Deal-progress epoch: bumped (single writer — this loop) before
        #: every quarantine-flag check and on every idle/wait spin. The
        #: collector's quarantine handshake waits for one tick: because
        #: the emitter is one thread, an observed advance proves any deal
        #: that predates the flag has completed its ledger append, and
        #: every later deal sees the flag.
        self._epoch = 0
        #: False once the emitter has popped STOP: quarantine recovery
        #: from then on happens inline at the collector (the workers are
        #: about to receive their STOPs; nothing can be re-emitted).
        self._accepting = True

    def _run_loop(self) -> None:
        farm = self._farm
        pop = self._in.pop
        redeal = farm._redeal
        pause_every = self._pause_every
        probe_every = self._probe_every
        rr = 0
        seq = 0
        spins = 0
        while True:
            rd = redeal.pop()
            if rd is not None:
                # A lost tag handed back by the collector after a worker
                # death: re-emit under its original sequence tag.
                rr = self._deal(rd, rr)
                self.items_out += 1
                spins = 0
                continue
            item = pop()
            if item is None:
                self._epoch += 1
                spins += 1
                if self._parked:
                    time.sleep(200e-6)    # parked idle (see Stage.sleep_hint)
                elif spins % pause_every == 0:
                    time.sleep(0)
                if not (probe_every and spins % probe_every == 0):
                    continue
                if self._upstream_alive():
                    continue
                item = pop()
                if item is None:
                    raise self._dead_upstream()
            spins = 0
            if item is STOP:
                self._shutdown()
                return
            self.items_in += 1
            rr = self._deal((seq, item), rr)
            seq += 1
            self.items_out += 1

    def _deal(self, payload: tuple, rr: int) -> int:
        """Skip-if-full deal into the first unquarantined worker ring
        starting at ``rr``; returns the next round-robin start. The
        speculative dealt-ledger append *precedes* the push, so a tag can
        never sit in a ring without being in the ledger (the collector's
        dealt-minus-released loss accounting depends on it); a failed
        push retracts the append (this loop is the deque's only
        right-end writer, the collector only ever pops the left)."""
        farm = self._farm
        n = len(farm._workers)
        pause_every = self._pause_every
        probe_every = self._probe_every
        wait_spins = 0
        while True:
            for k in range(n):
                i = (rr + k) % n
                self._epoch += 1
                if farm._quarantined[i]:
                    continue
                d = farm._dealt[i]
                d.append(payload)
                if farm._worker_in[i].push(payload):
                    return i + 1
                d.pop()
            wait_spins += 1
            if wait_spins % pause_every == 0:
                time.sleep(0)
            if probe_every and wait_spins % probe_every == 0:
                if not any(w.alive() for w in farm._workers) and not (
                        farm._respawn and self._accepting
                        and farm._collector.alive()):
                    raise RelicDeadError(
                        f"farm {farm.name!r}: every worker is dead",
                        self.items_in, self.items_out,
                        max(self.items_in - self.items_out, 0))

    def _shutdown(self) -> None:
        """End-of-stream: stop accepting re-emits, let an in-progress
        collector quarantine cycle finish (it reads ``_accepting`` under
        the farm's ``_claiming`` flag — after this wait any new cycle
        recovers inline instead), service the final re-emits, then
        broadcast STOP to every worker."""
        farm = self._farm
        self._accepting = False
        rr = 0
        spins = 0
        while farm._claiming and farm._collector.alive():
            self._epoch += 1
            rd = farm._redeal.pop()
            if rd is not None:
                rr = self._deal(rd, rr)
                self.items_out += 1
                continue
            spins += 1
            if spins % self._pause_every == 0:
                time.sleep(0)
        while True:
            rd = farm._redeal.pop()
            if rd is None:
                break
            rr = self._deal(rd, rr)
            self.items_out += 1
        for i in range(len(farm._workers)):
            self._broadcast_stop(i)

    def _broadcast_stop(self, i: int) -> None:
        farm = self._farm
        spins = 0
        while True:
            self._epoch += 1
            if (not farm._quarantined[i]
                    and farm._worker_in[i].push(STOP)):
                return
            spins += 1
            if spins % self._pause_every == 0:
                time.sleep(0)
            if (self._probe_every and spins % self._probe_every == 0
                    and not farm._workers[i].alive()):
                # Dead worker: the collector's probe accounts it (a
                # quarantined dead slot at this point is terminally
                # closed — post-STOP recovery is inline). A quarantined
                # *live* slot is a respawn completing; keep waiting for
                # the fresh ring.
                return


class _Collector(Stage):
    """Merges worker outputs: ordered release, exact loss accounting on a
    dead worker, quarantine + re-emit/inline recovery."""

    def __init__(self, farm: "Farm", **kwargs: Any):
        super().__init__(None, name=f"{farm.name}-collect", **kwargs)
        self._farm = farm

    def _run_loop(self) -> None:
        farm = self._farm
        workers = farm._workers
        outs = [w.out_ring for w in workers]
        n = len(outs)
        ordered = farm.ordered
        respawn = farm._respawn
        probe_every = self._probe_every
        pause_every = self._pause_every
        stops = [False] * n
        remaining = n
        stash: dict = {}
        next_rel = 0
        # Unordered dedup state (ordered mode dedups against
        # next_rel/stash directly): released-tag set compacted to a
        # contiguous watermark, bounded by the out-of-order window.
        released: set = set()
        rel_mark = -1
        spins = 0

        def release(item: Any) -> None:
            """Release one tagged result downstream, exactly once: a tag
            at or behind the release frontier is a replayed duplicate and
            is dropped (counted in ``farm.dup_dropped``)."""
            nonlocal next_rel, rel_mark
            seq, payload = item
            if ordered:
                if seq < next_rel or seq in stash:
                    farm.dup_dropped += 1
                    return
                self.items_in += 1
                stash[seq] = payload
                while next_rel in stash:
                    self._push_out(stash.pop(next_rel))
                    next_rel += 1
                    self.items_out += 1
            else:
                if respawn:
                    if seq <= rel_mark or seq in released:
                        farm.dup_dropped += 1
                        return
                    released.add(seq)
                    while rel_mark + 1 in released:
                        released.discard(rel_mark + 1)
                        rel_mark += 1
                self.items_in += 1
                self._push_out(payload)
                self.items_out += 1

        def take(i: int) -> Any:
            """Pop one item from worker ``i``, retiring its tag from the
            dealt ledger — the release half of dealt-minus-released."""
            item = outs[i].pop()
            if item is not None and item is not STOP:
                dealt = farm._dealt[i]
                if not dealt or dealt[0][0] != item[0]:
                    raise StageFailedError(
                        f"farm {farm.name!r}: dealt-ledger desync at "
                        f"worker {workers[i].name!r}",
                        self.items_in, self.items_out, (item[0],),
                        stage=workers[i].name)
                dealt.popleft()
            return item

        def pump() -> bool:
            """One merge sweep: at most one item per live worker."""
            nonlocal remaining
            progress = False
            for j in range(n):
                if stops[j]:
                    continue
                item = take(j)
                if item is None:
                    continue
                progress = True
                if item is STOP:
                    stops[j] = True
                    remaining -= 1
                else:
                    release(item)
            return progress

        def replay_inline(pairs: List[tuple]) -> None:
            """Recover lost tags on this thread (end-of-stream route):
            apply the farm fn and release under the same dedup ledger."""
            for pair in pairs:
                release(farm._work(pair))
                farm.reemitted_tags.append(pair[0])

        def push_redeal(pairs: List[tuple]) -> None:
            """Hand lost (tag, item) pairs back to the emitter (sole
            consumer of the redeal ring) for idempotent re-emit; keeps
            the merge pumping so a full network cannot deadlock the
            handover."""
            for pair in pairs:
                while not farm._redeal.push(pair):
                    if not pump():
                        time.sleep(0)
                    if not farm._emitter.alive():
                        raise StageFailedError(
                            f"farm {farm.name!r}: emitter died during "
                            "re-emit", self.items_in, self.items_out,
                            [p[0] for p in pairs],
                            stage=farm._emitter.name)
                farm.reemitted_tags.append(pair[0])

        def recover(i: int) -> bool:
            """Quarantine dead worker ``i`` and recover its in-flight
            tags, computed EXACTLY as dealt-minus-released. Returns True
            when the slot is terminally closed (counts as its STOP)."""
            emitter = farm._emitter
            t_detect = time.perf_counter()
            # 1. Freeze the deal flow into the slot, then wait one deal
            #    epoch: the emitter is a single thread that bumps the
            #    epoch before every quarantine check, so an observed
            #    advance proves the ledger below is final (any deal in
            #    flight at flag-set time appended its tag first; every
            #    later deal skips the slot).
            farm._quarantined[i] = True
            e0 = emitter._epoch
            hs = 0
            while emitter._epoch == e0 and emitter.alive():
                hs += 1
                if hs % pause_every == 0:
                    time.sleep(0)
            # 2. Adopt the abandoned input ring (its consumer is dead,
            #    its producer now skips it — 1P1C survives by the same
            #    argument as RelicPool's quarantine) and drain it: the
            #    items are replayed from the dealt ledger, but a STOP in
            #    there means this slot's stream already ended.
            stop_raced = False
            old_in = farm._worker_in[i]
            while True:
                it = old_in.pop()
                if it is None:
                    break
                if it is STOP:
                    stop_raced = True
            # 3. Snapshot the loss. take(i) already drained the final
            #    publications (a dead worker publishes nothing more), so
            #    the ledger remainder is exactly dealt-minus-released.
            lost = list(farm._dealt[i])
            lost_tags = tuple(p[0] for p in lost)
            error = workers[i].error()

            def record(respawned: bool, reemitted: bool) -> None:
                farm._failures.append(WorkerFailure(
                    worker_index=i, worker_name=workers[i].name,
                    lost_tags=lost_tags, error=error,
                    respawned=respawned, reemitted=reemitted,
                    detected_s=t_detect,
                    recovered_s=time.perf_counter()))

            if stop_raced:
                # The emitter already ended this slot's stream; recover
                # inline and close the slot (its STOP died with it).
                replay_inline(lost)
                record(respawned=False, reemitted=False)
                return True
            if not respawn:
                record(respawned=False, reemitted=False)
                raise StageFailedError(
                    f"farm {farm.name!r} worker {workers[i].name!r}",
                    self.items_in, self.items_out, lost_tags,
                    stage=workers[i].name)
            # Decide re-emit vs inline under the claiming flag: the
            # emitter's own STOP path waits for an in-progress claim
            # (draining re-emits meanwhile), which makes this read of
            # ``_accepting`` race-free — see _Emitter._shutdown.
            farm._claiming = True
            try:
                if emitter._accepting and emitter.alive():
                    self._respawn_slot(i, outs)
                    push_redeal(lost)
                    record(respawned=True, reemitted=True)
                    return False
                if not emitter._accepting:
                    # Stream ended normally while the worker died:
                    # recover inline, close the slot.
                    replay_inline(lost)
                    record(respawned=False, reemitted=False)
                    return True
                # Emitter died abnormally: items still in the farm input
                # are unreachable; recovery cannot preserve the stream.
                record(respawned=False, reemitted=False)
                raise StageFailedError(
                    f"farm {farm.name!r} worker {workers[i].name!r} "
                    "(emitter dead, stream unrecoverable)",
                    self.items_in, self.items_out, lost_tags,
                    stage=workers[i].name)
            finally:
                farm._claiming = False

        while remaining:
            if pump():
                spins = 0
                continue
            spins += 1
            if self._parked:
                time.sleep(200e-6)        # parked idle (see Stage.sleep_hint)
            elif spins % pause_every == 0:
                time.sleep(0)
            if not (probe_every and spins % probe_every == 0):
                continue
            for i in range(n):
                if stops[i] or workers[i].alive():
                    continue
                item = take(i)   # racing final publication
                if item is STOP:
                    stops[i] = True
                    remaining -= 1
                elif item is not None:
                    release(item)
                else:
                    if recover(i):
                        stops[i] = True
                        remaining -= 1
                    spins = 0
        if stash:
            # Sequence gaps with no dead worker left to blame: the tags
            # never released. Fail loudly — and say which — over silently
            # reordering.
            missing = tuple(s for s in range(next_rel, max(stash) + 1)
                            if s not in stash)
            raise StageFailedError(
                f"farm {farm.name!r}: {len(missing)} items lost in-flight",
                self.items_in, self.items_out, missing)
        self._push_out(STOP)

    def _respawn_slot(self, i: int, outs: List[SpscRing]) -> None:
        """Put a fresh worker in slot ``i`` (collector thread only):
        brand-new Stage, brand-new rings — so every ring keeps exactly
        one producer and one consumer for its whole lifetime — then
        reopen the slot to the emitter."""
        farm = self._farm
        farm._gen[i] += 1
        fresh = Stage(farm._work, name=f"{farm.name}-w{i}r{farm._gen[i]}",
                      capacity=farm.capacity, substrate=farm._substrate,
                      record=farm.record)
        fresh_ring = SpscRing(farm.capacity)
        fresh.connect(fresh_ring, farm._emitter.alive)
        fresh.set_downstream_alive(self.alive)
        farm._retired.append(farm._workers[i])
        farm._dealt[i] = deque()
        farm._worker_in[i] = fresh_ring
        farm._workers[i] = fresh        # same list object the emitter scans
        outs[i] = fresh.out_ring
        fresh.start()
        farm._quarantined[i] = False


class Farm:
    """Emitter → ``workers`` parallel stages → collector, as one node.

    ``fn`` is applied to each item by whichever worker the emitter dealt
    it to; ``ordered`` controls collector release order (input order vs
    completion order). ``substrate`` must be a registry *name* — a farm
    hosts ``workers + 2`` loops, so each gets its own instance; a single
    ``Scheduler`` instance cannot be shared (wrap the fn in a plain
    ``Stage`` for that). With a ``workers=0`` substrate the enclosing
    Pipeline runs the farm inline (``fn`` applied directly).

    ``respawn=True`` opts into dead-worker replacement: a worker whose
    assistant dies is quarantined, a fresh stage takes its slot, and its
    lost in-flight tags are re-emitted exactly once (see the module
    docstring for the recovery protocol). The default is fail-stop with
    exact accounting: a :class:`StageFailedError` carrying the lost tag
    set, so callers can re-submit precisely the lost work.
    """

    def __init__(self, fn: Callable[[Any], Any], *, workers: int = 2,
                 name: Optional[str] = None,
                 capacity: int = DEFAULT_CAPACITY,
                 substrate: str = "relic", ordered: bool = True,
                 respawn: bool = False, record: bool = False):
        if not isinstance(substrate, str):
            raise StreamUsageError(
                "Farm needs a substrate registry name (it hosts "
                f"workers+2 assistant loops), got {type(substrate).__name__}")
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.fn = fn
        self.name = name or getattr(fn, "__name__", None) or "farm"
        self.capacity = capacity
        self.ordered = ordered
        self._substrate = substrate
        self._respawn = respawn
        self._emitter = _Emitter(self, capacity=1, substrate=substrate)
        self._workers: List[Stage] = [
            Stage(self._work, name=f"{self.name}-w{i}", capacity=capacity,
                  substrate=substrate, record=record)
            for i in range(workers)
        ]
        self._worker_in: List[SpscRing] = [SpscRing(capacity)
                                           for _ in range(workers)]
        #: Per-worker dealt ledger: (seq, item) pairs appended by the
        #: emitter before each push, retired by the collector on each
        #: release — the remainder at a worker's death is exactly its
        #: lost in-flight set, values included for replay.
        self._dealt: List[deque] = [deque() for _ in range(workers)]
        #: Quarantine flags: set by the collector to stop the emitter
        #: dealing to a dead worker's ring (collector sole writer).
        self._quarantined: List[bool] = [False] * workers
        #: Collector → emitter handback of lost (tag, item) pairs (1P1C:
        #: collector produces, emitter consumes). Sized to hold a full
        #: in-flight window (ring capacity + the in-worker item) so a
        #: single quarantine's re-emit never blocks on a busy emitter.
        self._redeal = SpscRing(capacity + 4)
        #: True while the collector runs a quarantine decision cycle —
        #: the emitter's STOP path waits it out (see _Emitter._shutdown).
        self._claiming = False
        self._gen: List[int] = [0] * workers
        self._retired: List[Stage] = []
        self._failures: List[WorkerFailure] = []
        #: Tags replayed after worker deaths, in recovery order (via
        #: emitter re-emit or inline at the collector). The acceptance
        #: invariant: equals the union of failures' lost_tags.
        self.reemitted_tags: List[int] = []
        #: Duplicate releases dropped by the collector's dedup ledger
        #: (0 in every non-pathological run: replay is exactly-once by
        #: construction, the ledger is the belt-and-braces proof).
        self.dup_dropped = 0
        self._collector = _Collector(self, capacity=capacity,
                                     substrate=substrate)
        self._collector.connect(SpscRing(1), _always_alive)  # loop is custom
        for w, ring in zip(self._workers, self._worker_in):
            w.connect(ring, self._emitter.alive)
            w.set_downstream_alive(self._collector.alive)
        self.workers = 0 if any(
            s.workers == 0
            for s in (self._emitter, *self._workers, self._collector)) else 1
        self.record = record

    def _work(self, tagged: tuple) -> tuple:
        seq, item = tagged
        if type(item) is StreamFailure:
            return tagged               # upstream failure: pass through
        try:
            return (seq, self.fn(item))
        except Exception as e:
            return (seq, StreamFailure(e, self.name))

    # -- supervision surface ------------------------------------------------
    @property
    def failures(self) -> Tuple[WorkerFailure, ...]:
        """Worker-death records, in detection order (collector-written;
        read from the driver after the run or between polls)."""
        return tuple(self._failures)

    def take_worker_failures(self) -> Tuple[WorkerFailure, ...]:
        """Drain the recorded failures (driver-side observation read,
        mirroring ``RelicPool.take_lane_failures``)."""
        out = tuple(self._failures)
        self._failures.clear()
        return out

    @property
    def lost_tags(self) -> Tuple[int, ...]:
        """Union of all recorded failures' lost tag sets, sorted."""
        out: List[int] = []
        for f in self._failures:
            out.extend(f.lost_tags)
        return tuple(sorted(out))

    # -- node interface (same shape as Stage) ------------------------------
    @property
    def out_ring(self) -> SpscRing:
        return self._collector.out_ring

    @property
    def items_in(self) -> int:
        return self._emitter.items_in

    @items_in.setter
    def items_in(self, v: int) -> None:        # inline-mode accounting
        self._emitter.items_in = v

    @property
    def items_out(self) -> int:
        return self._collector.items_out

    @items_out.setter
    def items_out(self, v: int) -> None:
        self._collector.items_out = v

    def connect(self, in_ring: SpscRing, upstream_alive) -> None:
        self._emitter.connect(in_ring, upstream_alive)

    def set_downstream_alive(self, probe) -> None:
        self._collector.set_downstream_alive(probe)

    def start(self) -> "Farm":
        # Sink-first (collector, workers, emitter) so every probe target
        # is already running when its prober's loop begins.
        self._collector.start()
        for w in self._workers:
            w.start()
        self._emitter.start()
        return self

    def alive(self) -> bool:
        return self._collector.alive()

    def error(self) -> Optional[BaseException]:
        for s in (self._collector, *self._workers, self._emitter):
            e = s.error()
            if e is not None:
                return e
        return None

    def _members(self) -> List[Stage]:
        """Every stage this farm ever hosted: the current roster plus the
        retired casualties of respawns (their scopes still need closing)."""
        return [self._emitter, *self._workers, self._collector,
                *self._retired]

    def join(self, timeout: Optional[float] = None) -> None:
        for s in self._members():
            s.join(timeout)

    def close(self) -> None:
        for s in self._members():
            s.close()

    def sleep_hint(self) -> None:
        for s in self._members():
            s.sleep_hint()

    def wake_up_hint(self) -> None:
        for s in self._members():
            s.wake_up_hint()

    def stats(self) -> dict:
        return {
            "name": self.name,
            "items_in": self.items_in,
            "items_out": self.items_out,
            "ordered": self.ordered,
            "respawn": self._respawn,
            "failures": len(self._failures),
            "reemitted": len(self.reemitted_tags),
            "dup_dropped": self.dup_dropped,
            "workers": [w.stats() for w in self._workers],
        }

    def __repr__(self) -> str:
        return (f"Farm({self.name!r}, workers={len(self._workers)}, "
                f"ordered={self.ordered}, respawn={self._respawn})")
