"""Streaming executor: compose SPSC lanes into pipelines and farms.

The paper's Relic runtime is one SPSC producer/consumer pair; FastFlow
(Aldinucci et al., 2009) shows exactly that primitive composes into
arbitrary streaming networks — pipelines and farms — without ever adding
a lock or an MPMC queue. This package is that composition layer for the
repro codebase:

* :class:`Stage` — one assistant looping fn over an input/output ring pair
* :class:`Pipeline` — linear driver → stages → driver network
* :class:`Farm` — emitter → N workers → collector, as one pipeline node
* :data:`STOP`, :class:`StreamFailure`, :class:`StreamError` — in-band
  end-of-stream and failure flow

Built on it (PR 9): ``TaskGraph.run(streaming=True)``,
``PrefetchPipeline`` (produce → transform as a 2-stage pipeline, its
``_push_lock`` deleted), ``CheckpointManager`` (overlapped serialize →
publish stages), and ``Workload.streamed()``. See docs/streaming.md.
"""

from repro.stream.farm import Farm, WorkerFailure
from repro.stream.pipeline import Pipeline
from repro.stream.stage import (STOP, Stage, StageFailedError, StreamError,
                                StreamFailure, StreamUsageError, worker_alive)

__all__ = [
    "STOP",
    "Stage",
    "StageFailedError",
    "Pipeline",
    "Farm",
    "WorkerFailure",
    "StreamError",
    "StreamFailure",
    "StreamUsageError",
    "worker_alive",
]
