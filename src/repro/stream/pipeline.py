"""Linear streaming pipeline: driver → stage → stage → … → driver.

Composition rules (docs/streaming.md walks through the why):

* The driver thread is the **sole producer** of the source ring and the
  **sole consumer** of the sink ring (the last node's output ring). Each
  inter-stage ring is produced by exactly one stage's assistant and
  consumed by exactly the next stage's assistant. Every ring in the
  network is therefore strictly 1P1C *by construction* — no lock, no MPMC
  queue, anywhere (pinned by ``tests/test_stream.py``).
* Backpressure is per-ring and bounded: a pipeline of N stages with ring
  capacity C holds at most ``(N+1) * C`` items in flight; a slow stage
  stalls its producer at the full ring, propagating backwards to ``put``.
* Substrates: each node built from a registry *name* gets its **own**
  scheduler instance (one assistant per stage — the invariant above). A
  single ``Scheduler`` *instance* cannot host N independent loops, so
  passing one fuses all callable stages into a single stage running the
  composed function on that instance. A ``workers=0`` substrate
  ("serial") cannot host any loop: the whole pipeline degrades to
  fully-inline execution on the driver thread — same results, same error
  marking, zero threads — which is also the natural A/B baseline.
* End-of-stream and failure are **in-band**: ``close()`` flows ``STOP``
  through every stage; an item whose stage fn raised travels on as a
  :class:`StreamFailure` marker so slot accounting never skews. ``get()``
  unwraps markers into :class:`StreamError`; ``get_raw()`` hands them
  back for callers that do their own accounting (PrefetchPipeline's
  error contract, CheckpointManager's wait()).
* Every driver-side wait is bounded by the PR 8 supervision discipline:
  liveness probe every ``_PROBE_EVERY_SPINS`` spins, ``RelicDeadError``
  with fed/drained diagnostics when a stage died, ``RELIC_SUPERVISE=0``
  opt-out.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, Iterable, List, Optional, Sequence, Union

from repro.core.relic import _PROBE_EVERY_SPINS, RelicDeadError
from repro.core.schedulers import Scheduler
from repro.core.spsc import DEFAULT_CAPACITY, SpscRing
from repro.runtime.config import (resolve_spin_pause_every,
                                  resolve_supervise_config)
from repro.stream.stage import (STOP, Stage, StreamError, StreamFailure,
                                StreamUsageError)

__all__ = ["Pipeline"]


def _compose(fns: Sequence[Callable[[Any], Any]]) -> Callable[[Any], Any]:
    """Left-to-right function composition (the fused-stage body)."""
    if len(fns) == 1:
        return fns[0]

    def fused(item: Any) -> Any:
        for fn in fns:
            item = fn(item)
        return item

    fused.__name__ = "+".join(getattr(f, "__name__", "fn") for f in fns)
    return fused


class Pipeline:
    """Compose stages into a driveable linear streaming network.

    ``stages`` mixes ready-made nodes (:class:`Stage`, ``Farm``) with bare
    callables; callables are wrapped into stages using the pipeline-level
    ``substrate``/``capacity``/``record`` defaults. ``capacity`` also sizes
    the source ring (the driver's put window).

    Driving::

        with Pipeline([parse, enrich, write]) as pipe:
            outs = pipe.run(items)          # feed + drain, order-preserving

    or item-at-a-time with explicit ``put()`` / ``get()`` (strict
    one-in/one-out accounting; ``get`` raises :class:`StreamError` for an
    item whose stage failed, ``get_raw`` returns the marker instead).

    ``supervisor=`` takes a :class:`repro.runtime.fault.LaneSupervisor`
    sized to the stage count for advisory stalled/straggler *stage*
    detection — see :meth:`check_stages`.
    """

    def __init__(self, stages: Sequence[Union[Stage, Callable[[Any], Any], Any]],
                 *, substrate: Union[str, Scheduler] = "relic",
                 capacity: int = DEFAULT_CAPACITY, record: bool = False,
                 supervisor: Optional[Any] = None):
        if not stages:
            raise StreamUsageError("a Pipeline needs at least one stage")
        if isinstance(substrate, Scheduler):
            # One instance cannot host N loops: fuse the callables into a
            # single stage on it. Pre-built nodes keep their own substrates.
            callables = [s for s in stages if not hasattr(s, "out_ring")]
            if len(callables) == len(stages):
                stages = [Stage(_compose(list(stages)), name="fused",
                                capacity=capacity, substrate=substrate,
                                record=record)]
            elif callables:
                raise StreamUsageError(
                    "cannot mix bare callables with pre-built nodes when "
                    "fusing onto a single Scheduler instance; wrap the "
                    "callables in Stage(...) explicitly")
        self._nodes: List[Any] = [
            s if hasattr(s, "out_ring")
            else Stage(s, capacity=capacity, substrate=substrate, record=record)
            for s in stages
        ]
        self._inline = any(node.workers == 0 for node in self._nodes)
        self._source = SpscRing(capacity)
        self._sink: SpscRing = self._nodes[-1].out_ring
        self._inline_out: deque = deque()
        # Wire rings and liveness probes. The driver end is always "alive".
        prev_ring, prev_alive = self._source, _driver_alive
        for node in self._nodes:
            node.connect(prev_ring, prev_alive)
            prev_ring, prev_alive = node.out_ring, node.alive
        for up, down in zip(self._nodes, self._nodes[1:]):
            up.set_downstream_alive(down.alive)
        self._nodes[-1].set_downstream_alive(_driver_alive)
        self._fed = 0      # items put (driver-side single writer)
        self._got = 0      # items got
        self._started = False
        self._closed = False
        self._probe_every = (_PROBE_EVERY_SPINS
                             if resolve_supervise_config().supervise else 0)
        self._pause_every = resolve_spin_pause_every()
        # Advisory progress supervision (PR 8's LaneSupervisor lifted to
        # the stage stratum): one "lane" per stage, fed this pipeline's
        # fed/drained counters on every driver-side bounded-wait probe
        # (and on explicit check_stages() calls). Strictly advisory — the
        # *liveness* story is the bounded waits; this flags the cases they
        # cannot: a stage that is alive but wedged (stalled) or alive but
        # persistently slow (straggler).
        if supervisor is not None and supervisor.n_lanes != len(self._nodes):
            raise StreamUsageError(
                f"supervisor has {supervisor.n_lanes} lanes for "
                f"{len(self._nodes)} stages — size it with "
                "LaneSupervisor(n_lanes=len(stages), ...)")
        self._supervisor = supervisor
        if supervisor is not None and getattr(supervisor, "names", None) is None:
            supervisor.names = [node.name for node in self._nodes]

    # -- introspection -----------------------------------------------------
    @property
    def inline(self) -> bool:
        """True when a workers=0 substrate degraded the network to run
        synchronously on the driver thread."""
        return self._inline

    @property
    def nodes(self) -> tuple:
        return tuple(self._nodes)

    @property
    def sink_ring(self) -> SpscRing:
        """The ring the driver consumes (the last node's output ring)."""
        return self._sink

    def in_flight(self) -> int:
        return self._fed - self._got

    def stats(self) -> List[dict]:
        return [node.stats() for node in self._nodes]

    # -- advisory supervision (needs a supervisor= at construction) --------
    def check_stages(self) -> bool:
        """One supervision sweep: feed each stage's drained counter and its
        backlog (driver-fed minus stage-drained) to the supervisor. Cheap
        to call often — the supervisor samples once per heartbeat period.
        Returns True when a sample was actually taken. The driver's own
        bounded waits call this on their probe cadence, so a pipeline
        being driven supervises itself."""
        sup = self._supervisor
        if sup is None:
            return False
        completed = [node.items_out for node in self._nodes]
        outstanding = [max(self._fed - c, 0) for c in completed]
        return sup.observe(completed, outstanding)

    def stalled_stages(self) -> List[str]:
        """Names of stages with a backlog and no progress for ~2 heartbeat
        periods. Advisory: one long-running item and a wedged assistant
        look identical here — the bounded waits decide *dead*."""
        sup = self._supervisor
        if sup is None:
            return []
        return [self._nodes[i].name for i in sup.stalled()]

    def straggler_stages(self) -> List[str]:
        """Names of stages persistently slower than their peers (the
        StragglerMonitor's median/MAD z-score over per-period pace)."""
        sup = self._supervisor
        if sup is None:
            return []
        return [self._nodes[i].name for i in sup.stragglers()]

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "Pipeline":
        if self._started:
            raise StreamUsageError("Pipeline already started")
        if self._closed:
            raise StreamUsageError("Pipeline cannot restart after close()")
        self._started = True
        if not self._inline:
            # Sink-first so every stage's downstream probe refers to an
            # already-started node by the time its own loop runs.
            for node in reversed(self._nodes):
                node.start()
        return self

    def close(self) -> None:
        """Flow STOP through the network, join every stage loop, release
        the substrates. Idempotent; discards any undrained output items."""
        if self._closed:
            return
        self._closed = True
        if not self._started or self._inline:
            for node in self._nodes:
                node.close()
            return
        first, last = self._nodes[0], self._nodes[-1]
        try:
            # Feed STOP (bounded: give up if the head stage died — the
            # death cascades through the probes instead).
            spins = 0
            while not self._source.push(STOP):
                spins += 1
                if spins % self._pause_every == 0:
                    time.sleep(0)
                if (self._probe_every and spins % self._probe_every == 0
                        and not first.alive()):
                    break
            # Drain the sink until STOP comes out the far end (discarding
            # leftovers a caller abandoned), bounded by the tail stage's
            # liveness.
            spins = 0
            while True:
                item = self._sink.pop()
                if item is STOP:
                    break
                if item is not None:
                    continue
                spins += 1
                if spins % self._pause_every == 0:
                    time.sleep(0)
                if (self._probe_every and spins % self._probe_every == 0
                        and not last.alive()):
                    if self._sink.pop() is None:  # racing final publication
                        break
            for node in self._nodes:
                node.join(timeout=5)
        finally:
            for node in self._nodes:
                node.close()

    def __enter__(self) -> "Pipeline":
        if not self._started:
            self.start()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- hints (advisory, forwarded to every stage) ------------------------
    def pause(self) -> None:
        for node in self._nodes:
            node.sleep_hint()

    def resume(self) -> None:
        for node in self._nodes:
            node.wake_up_hint()

    # -- driving -----------------------------------------------------------
    def _check_driveable(self) -> None:
        if not self._started:
            raise StreamUsageError("Pipeline not started (use start() or 'with')")
        if self._closed:
            raise StreamUsageError("Pipeline is closed")

    def _apply_inline(self, item: Any) -> Any:
        for node in self._nodes:
            if type(item) is StreamFailure:
                return item
            node.items_in += 1
            try:
                item = node.fn(item)
            except Exception as e:
                item = StreamFailure(e, node.name)
            node.items_out += 1
        return item

    def put(self, item: Any) -> None:
        """Feed one item (bounded blocking on a full source ring)."""
        self._check_driveable()
        if self._inline:
            self._inline_out.append(self._apply_inline(item))
            self._fed += 1
            return
        if self._source.push(item):
            self._fed += 1
            return
        first = self._nodes[0]
        spins = 0
        while True:
            spins += 1
            if spins % self._pause_every == 0:
                time.sleep(0)
            if self._probe_every and spins % self._probe_every == 0:
                self.check_stages()
                if not first.alive():
                    raise self._dead(first)
            if self._source.push(item):
                self._fed += 1
                return

    def put_nowait(self, item: Any) -> bool:
        """Non-blocking feed; False when the source ring is full."""
        self._check_driveable()
        if self._inline:
            self.put(item)
            return True
        if self._source.push(item):
            self._fed += 1
            return True
        return False

    def get_raw(self) -> Any:
        """Next output item in stream order — a value or a
        :class:`StreamFailure` marker (bounded blocking)."""
        self._check_driveable()
        if self._inline:
            if not self._inline_out:
                raise StreamUsageError("get() with no item in flight")
            self._got += 1
            return self._inline_out.popleft()
        if self._fed == self._got:
            raise StreamUsageError("get() with no item in flight")
        last = self._nodes[-1]
        pop = self._sink.pop
        spins = 0
        while True:
            item = pop()
            if item is not None:
                if item is STOP:
                    raise StreamUsageError("stream already ended (STOP)")
                self._got += 1
                return item
            spins += 1
            if spins % self._pause_every == 0:
                time.sleep(0)
            if self._probe_every and spins % self._probe_every == 0:
                self.check_stages()
                if not last.alive():
                    item = pop()  # final re-pop: published right before death
                    if item is not None and item is not STOP:
                        self._got += 1
                        return item
                    raise self._dead(last)

    def get(self) -> Any:
        """Next output item; raises :class:`StreamError` (chaining the
        stage's original exception) if that item failed in-stream."""
        item = self.get_raw()
        if type(item) is StreamFailure:
            raise StreamError(
                f"stage {item.stage!r} failed on an item") from item.error
        return item

    def run(self, items: Iterable[Any], raw: bool = False) -> List[Any]:
        """Feed every item and return the outputs, in order.

        Feeding and draining interleave (non-blocking put, opportunistic
        sink pop), so bounded rings never deadlock the driver no matter
        how ``len(items)`` compares to the ring capacities. Raises
        :class:`StreamError` on the first failed item unless ``raw=True``,
        which instead leaves each failure's :class:`StreamFailure` marker
        in its output slot (strict one-in/one-out accounting). Requires
        one-in/one-out stages and no other items in flight.
        """
        unwrap = (lambda item: item) if raw else self._unwrap
        self._check_driveable()
        if self.in_flight():
            raise StreamUsageError("run() with items already in flight")
        if self._inline:
            out = []
            for item in items:
                self.put(item)
                out.append(unwrap(self.get_raw()))
            return out
        out: List[Any] = []
        it = iter(items)
        nxt: Any = _PENDING
        exhausted = False
        last = self._nodes[-1]
        push, pop = self._source.push, self._sink.pop
        spins = 0
        while True:
            progress = False
            if nxt is _PENDING and not exhausted:
                try:
                    nxt = next(it)
                except StopIteration:
                    exhausted = True
                    nxt = _PENDING
            if nxt is not _PENDING and push(nxt):
                self._fed += 1
                nxt = _PENDING
                progress = True
            item = pop()
            if item is not None:
                self._got += 1
                out.append(unwrap(item))
                progress = True
            if exhausted and nxt is _PENDING and self._fed == self._got:
                return out
            if progress:
                spins = 0
                continue
            spins += 1
            if spins % self._pause_every == 0:
                time.sleep(0)
            if self._probe_every and spins % self._probe_every == 0:
                self.check_stages()
                if not last.alive():
                    item = pop()
                    if item is not None and item is not STOP:
                        self._got += 1
                        out.append(unwrap(item))
                        spins = 0
                        continue
                    raise self._dead(last)

    def __iter__(self):
        """Drain whatever is in flight, in order (no further feeding)."""
        while self.in_flight() or (self._inline and self._inline_out):
            yield self.get()

    # -- internals ---------------------------------------------------------
    def _unwrap(self, item: Any) -> Any:
        if type(item) is StreamFailure:
            raise StreamError(
                f"stage {item.stage!r} failed on an item") from item.error
        return item

    def _dead(self, node: Any) -> RelicDeadError:
        err = RelicDeadError(f"stream-pipeline stage {node.name!r}",
                             self._fed, self._got, self._fed - self._got)
        # Chain the most downstream fatal stage error as the cause — the
        # probes cascade, so the root cause is the first dead stage.
        cause = None
        for n in self._nodes:
            e = n.error()
            if e is not None:
                cause = e
                break
        if cause is not None:
            err.__cause__ = cause
        return err


class _Pending:
    __slots__ = ()

    def __repr__(self) -> str:
        return "<PENDING>"


_PENDING = _Pending()


def _driver_alive() -> bool:
    return True
