"""One streaming stage: a Relic assistant looping over a pair of SPSC rings.

A :class:`Stage` is the unit FastFlow (Aldinucci et al., 2009) composes
networks from: one worker thread, one bounded input ring, one bounded
output ring. The stage's loop runs as a *single long-lived task* on its
own scheduling substrate (a ``TaskScope`` over ``"relic"`` by default), so
the whole streaming layer is built out of the paper's existing primitive —
an SPSC ring plus one assistant — rather than a new thread pool:

* the **driver** (or the upstream stage's assistant) is the sole producer
  of the stage's input ring;
* the stage's assistant is the sole consumer of its input ring and the
  sole producer of its output ring;
* the downstream stage's assistant (or the driver) is the sole consumer
  of the output ring.

Every ring is therefore strictly 1P1C *by construction* — the cached-index
fast paths of :class:`repro.core.spsc.SpscRing` stay valid, and no lock or
MPMC queue appears anywhere on the item path (pinned by
``tests/test_stream.py``).

Waiting discipline (PR 8): every spin loop here is *bounded*. A popping
stage probes its upstream's liveness every ``_PROBE_EVERY_SPINS`` spins
and raises :class:`repro.core.relic.RelicDeadError` (with fed/drained
diagnostics) instead of spinning forever on a ring nothing will ever fill;
a pushing stage symmetrically probes its downstream before waiting on a
ring nothing will ever drain. ``RELIC_SUPERVISE=0`` opts out, same switch
as the substrate.

In-band control flow:

* :data:`STOP` — end-of-stream sentinel. Forwarded exactly once by every
  stage, *after* its last data item (the GIL orders the ring write before
  the loop-exit flag, so a consumer that sees the stage dead re-pops once
  and still finds the STOP).
* :class:`StreamFailure` — an item whose ``fn`` raised. The marker flows
  downstream *in-stream* (later stages forward it untouched), preserving
  slot accounting: every item put in yields exactly one item (value or
  marker) out, so drivers never hang on a failed item. The driver-facing
  ``Pipeline.get()`` unwraps markers into raised exceptions.

Anything that is *not* an ``Exception`` (``SystemExit``,
``KeyboardInterrupt``) kills the stage loop itself — that is the
"assistant died" case, surfaced to whoever is waiting via the liveness
probes, exactly like a killed Relic assistant.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Iterable, List, Optional, Union

from repro.core.relic import _PROBE_EVERY_SPINS, RelicDeadError
from repro.core.schedulers import Scheduler, make_scheduler
from repro.core.spsc import DEFAULT_CAPACITY, SpscRing
from repro.runtime.config import (resolve_spin_pause_every,
                                  resolve_supervise_config)
from repro.runtime.metrics import Gauge, LatencySeries
from repro.tasks.api import TaskScope

__all__ = ["STOP", "StreamFailure", "StreamError", "StreamUsageError",
           "StageFailedError", "Stage", "worker_alive"]


class _Stop:
    """End-of-stream sentinel (singleton). Compared by identity."""

    __slots__ = ()

    def __repr__(self) -> str:
        return "<STOP>"


STOP = _Stop()


class StreamFailure:
    """In-stream marker for one item whose stage ``fn`` raised.

    Not an exception: it *flows* through the remaining stages (each
    forwards it untouched) so the one-in/one-out slot accounting that the
    bounded rings rely on survives failures. ``error`` is the original
    exception, ``stage`` the name of the stage that raised it.
    """

    __slots__ = ("error", "stage")

    def __init__(self, error: BaseException, stage: str):
        self.error = error
        self.stage = stage

    def __repr__(self) -> str:
        return f"StreamFailure({type(self.error).__name__}, stage={self.stage!r})"


class StreamError(RuntimeError):
    """A :class:`StreamFailure` unwrapped at the driver (``Pipeline.get``);
    the original stage exception is chained as ``__cause__``."""


class StreamUsageError(RuntimeError):
    """Structural misuse of the streaming API (wrong lifecycle order,
    un-hostable substrate, get without put)."""


class StageFailedError(RelicDeadError):
    """A stream stage died with in-flight items — and here is *which*.

    The stream-layer refinement of :class:`RelicDeadError`: on top of the
    fed/drained/lost counters it carries ``stage`` (the dead loop's name)
    and ``lost_tags`` — the exact sequence tags of the items that were
    dealt to the dead stage and never released, computed as
    dealt-minus-released by the farm collector's per-worker ledger. With
    the tags in hand a caller can re-submit precisely the lost work (the
    primitive ``Farm(respawn=True)``'s own re-emit is built on) instead of
    guessing from a bare count.
    """

    def __init__(self, lane: str, submitted: int, completed: int,
                 lost_tags: Iterable[int], stage: str = "") -> None:
        tags = tuple(sorted(lost_tags))
        super().__init__(lane, submitted, completed, len(tags))
        self.stage = stage
        self.lost_tags = tags


def worker_alive(sched: Scheduler) -> bool:
    """Best-effort liveness probe for a substrate's worker thread(s).

    Duck-typed against the in-repo adapters, the same surface the serve
    layer's ingest probe uses: chaos delegates to its inner substrate;
    relic adapters expose ``._rt.is_alive()``; the queue substrates expose
    their ``._t`` thread. Substrates with no probeable worker — the pool
    executor (workers never die) or RelicPool (its lanes self-supervise
    and respawn) — report alive, which only means the *bounded wait*
    cannot blame them; their own supervision still fires.
    """
    inner = getattr(sched, "_inner", None)
    if inner is not None:                      # chaos: pure delegation
        return worker_alive(inner)
    rt = getattr(sched, "_rt", None)
    if rt is not None:                         # relic family
        probe = getattr(rt, "is_alive", None)
        if probe is not None:
            return probe()
        return True                            # RelicPool: self-supervising
    t = getattr(sched, "_t", None)
    if t is not None:                          # spin / condvar worker thread
        return t.is_alive()
    return True                                # serial / pool / unknown


def _always_alive() -> bool:
    return True


class Stage:
    """One streaming stage: ``fn`` applied to every item flowing through.

    ``substrate`` is a registry name (the stage instantiates its *own*
    scheduler, so each stage gets its own assistant — the 1P1C invariant)
    or an unstarted/started ``Scheduler`` instance (adopted/borrowed by the
    stage's scope; the caller guarantees nothing else occupies its worker).
    Stages are wired by :class:`repro.stream.Pipeline` / ``Farm`` — the
    composition layer assigns the input ring and both liveness probes; a
    bare Stage is not driveable on its own.

    ``record=True`` keeps a :class:`LatencySeries` of per-item ``fn`` time
    and a :class:`Gauge` of input-ring occupancy (sampled per item, by the
    consumer, so exact) — the shared ``repro.runtime.metrics`` primitives,
    surfaced through ``stats()`` for the benchmark's stage rows.
    """

    def __init__(self, fn: Optional[Callable[[Any], Any]], *,
                 name: Optional[str] = None,
                 capacity: int = DEFAULT_CAPACITY,
                 substrate: Union[str, Scheduler] = "relic",
                 record: bool = False):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", None) or "stage"
        self.capacity = capacity
        if isinstance(substrate, str):
            self._sched: Scheduler = make_scheduler(substrate)
        else:
            self._sched = substrate
        #: advertised worker count — 0 means "cannot host a loop" and makes
        #: the enclosing Pipeline degrade to fully-inline execution.
        self.workers: int = getattr(self._sched, "workers", 1)
        self._out = SpscRing(capacity)
        self._in: Optional[SpscRing] = None
        self._upstream_alive: Callable[[], bool] = _always_alive
        self._downstream_alive: Callable[[], bool] = _always_alive
        self._scope: Optional[TaskScope] = None
        self._handle = None
        # Single-writer counters (the stage's own assistant writes both).
        self.items_in = 0
        self.items_out = 0
        self.record = record
        self.latency: Optional[LatencySeries] = LatencySeries() if record else None
        self.occupancy: Optional[Gauge] = Gauge() if record else None
        # Park flag (plain bool, single writer = the driver via the hint
        # methods; GIL-published like the ring counters). The loop *spins*
        # while unparked — µs wake latency, the paper's discipline — but a
        # parked idle loop sleeps in ms ticks so a stopped-but-alive
        # network doesn't tax the host (sleep_hint's whole point).
        self._parked = False
        self._probe_every = (_PROBE_EVERY_SPINS
                             if resolve_supervise_config().supervise else 0)
        self._pause_every = resolve_spin_pause_every()
        # Opt-in chaos hook (None in production): consulted once per popped
        # data item; a fired switch kills the loop with the item popped but
        # unprocessed — the deterministic "stage died with in-flight work"
        # scenario. See repro.runtime.chaos.StageKillSwitch.
        self._chaos_kill: Optional[Callable[[int], bool]] = None

    # -- wiring (called by the composition layer, before start) ------------
    @property
    def out_ring(self) -> SpscRing:
        """The ring this stage's assistant is the sole producer of."""
        return self._out

    def connect(self, in_ring: SpscRing,
                upstream_alive: Callable[[], bool]) -> None:
        """Assign the input ring (this stage becomes its sole consumer) and
        the probe for whoever produces into it."""
        self._in = in_ring
        self._upstream_alive = upstream_alive

    def set_downstream_alive(self, probe: Callable[[], bool]) -> None:
        """Assign the probe for whoever consumes the output ring."""
        self._downstream_alive = probe

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "Stage":
        if self._handle is not None:
            raise StreamUsageError(f"stage {self.name!r} already started")
        if self._in is None:
            raise StreamUsageError(
                f"stage {self.name!r} has no input ring; compose it through "
                "Pipeline/Farm before starting")
        if self.workers == 0:
            raise StreamUsageError(
                f"stage {self.name!r}: a workers=0 substrate cannot host a "
                "stage loop (Pipeline runs such networks inline instead)")
        self._scope = TaskScope(self._sched)
        # The loop occupies the assistant for the stage's whole life, so
        # park/unpark hints from stop-start drivers must find it awake.
        self._scope.wake_up_hint()
        self._handle = self._scope.submit(self._run_loop)
        return self

    def alive(self) -> bool:
        """Can this stage still make progress? False once its loop exited
        (STOP processed, or a fatal error) or its worker thread died. A
        not-yet-started stage reports alive — ``Pipeline.start`` brings
        the network up sink-first, so a running stage may probe a sibling
        that is about to start; "never ran" must not read as "died"."""
        h = self._handle
        if h is None:
            return True
        return (not h._done) and worker_alive(self._sched)

    def error(self) -> Optional[BaseException]:
        """The loop's fatal error, if it exited with one (None otherwise —
        including while still running)."""
        h = self._handle
        return h._error if h is not None and h._done else None

    def join(self, timeout: Optional[float] = None) -> None:
        """Wait for the loop task to exit (it does after forwarding STOP)."""
        if self._handle is not None:
            self._handle._wait(timeout)

    def close(self) -> None:
        """Release the substrate (idempotent). The loop must have exited —
        ``Pipeline.close`` drains STOP through the network first."""
        scope, self._scope = self._scope, None
        if scope is not None:
            scope.close()
        elif isinstance(self._sched, Scheduler) and self._handle is None:
            # Never started (e.g. the pipeline degraded to inline): closing
            # the never-started scheduler is a safe no-op for registry
            # substrates and releases nothing.
            try:
                self._sched.close()
            except Exception:
                pass

    # -- hints (advisory) --------------------------------------------------
    def sleep_hint(self) -> None:
        """Park the idle loop: while no item is available it sleeps in
        ~200us ticks instead of spinning hot — the streaming analogue of
        the paper's explicit between-parallel-sections hint. An item
        already in the ring is still processed immediately."""
        self._parked = True
        if self._scope is not None:
            self._scope.sleep_hint()

    def wake_up_hint(self) -> None:
        self._parked = False
        if self._scope is not None:
            self._scope.wake_up_hint()

    # -- introspection -----------------------------------------------------
    def stats(self) -> dict:
        out = {"name": self.name, "items_in": self.items_in,
               "items_out": self.items_out}
        if self.record and self.latency is not None and len(self.latency):
            pct = self.latency.percentiles()
            out["latency_us"] = {f"p{int(q)}": v * 1e6 for q, v in pct.items()}
            out["occupancy"] = self.occupancy.asdict()
        return out

    def __repr__(self) -> str:
        state = ("unstarted" if self._handle is None
                 else "alive" if self.alive() else "exited")
        return f"Stage({self.name!r}, {state}, in={self.items_in}, out={self.items_out})"

    # -- the loop (runs on this stage's assistant) -------------------------
    def _dead_upstream(self) -> RelicDeadError:
        return RelicDeadError(f"stream-stage {self.name!r} upstream",
                              self.items_in, self.items_in, 0)

    def _dead_downstream(self) -> RelicDeadError:
        return RelicDeadError(f"stream-stage {self.name!r} downstream",
                              self.items_in, self.items_out, len(self._out))

    def _run_loop(self) -> None:
        fn = self.fn
        pop = self._in.pop
        probe_every = self._probe_every
        pause_every = self._pause_every
        record = self.record
        spins = 0
        while True:
            item = pop()
            if item is None:
                # Bounded wait (PR 8 discipline): yield on the pause
                # cadence; every probe_every spins check the producer is
                # still there, re-popping once after a failed probe so an
                # item (or STOP) published right before death is drained.
                # A parked loop trades wake latency for idle CPU instead.
                spins += 1
                if self._parked:
                    time.sleep(200e-6)
                elif spins % pause_every == 0:
                    time.sleep(0)
                if not (probe_every and spins % probe_every == 0):
                    continue
                if self._upstream_alive():
                    continue
                item = pop()
                if item is None:
                    raise self._dead_upstream()
            spins = 0
            if item is STOP:
                self._push_out(STOP)
                return
            if (self._chaos_kill is not None
                    and self._chaos_kill(self.items_in)):
                raise SystemExit("chaos: stage loop killed")
            self.items_in += 1
            if type(item) is StreamFailure:
                self._push_out(item)        # failed upstream: forward as-is
                self.items_out += 1
                continue
            if record:
                self.occupancy.observe(len(self._in))
                t0 = time.perf_counter()
            try:
                out = fn(item)
            except Exception as e:
                out = StreamFailure(e, self.name)
            if record:
                self.latency.add(time.perf_counter() - t0)
            self._push_out(out)
            self.items_out += 1

    def _push_out(self, item: Any) -> None:
        """Bounded-wait push into the output ring (backpressure point)."""
        if self._out.push(item):
            return
        probe_every = self._probe_every
        pause_every = self._pause_every
        spins = 0
        while True:
            spins += 1
            if spins % pause_every == 0:
                time.sleep(0)
            if (probe_every and spins % probe_every == 0
                    and not self._downstream_alive()):
                raise self._dead_downstream()
            if self._out.push(item):
                return
