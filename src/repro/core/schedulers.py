"""Swappable host scheduling substrates behind one ``Scheduler`` contract.

The paper's central experiment is a head-to-head comparison of *scheduling
structures* — Relic's busy-wait SPSC ring against lock-based spinning,
condition-variable suspension, and general thread pools — on a fixed task
stream. This module makes those competitors first-class citizens of the
runtime instead of throwaway benchmark classes, so every consumer (data
pipeline, async checkpointing, the wavefront task driver, benchmarks, and
the conformance suite) can swap substrates by name.

Substrate-to-paper-framework mapping (see docs/schedulers.md):

  ==========  =====================================  =======================
  name        structure                              paper framework flavour
  ==========  =====================================  =======================
  serial      run inline on the producer thread      the serial baseline
  relic       busy-wait SPSC ring, fixed roles       Relic (the paper's §VI)
  relic-pool  N lanes, each its own SPSC ring +      Relic scaled past the
              assistant; lane-striped submission     SMT pair (lanes=N)
  relic2/4    relic-pool at lanes=2 / lanes=4        convenience names
  spin        mutex-protected deque + spin waits     X-OpenMP (lock + spin)
  condvar     bounded queue, condvar suspension      GNU OpenMP (suspension)
  pool        general thread pool + futures          oneTBB / Taskflow
  chaos       fault-injecting wrapper over any of    the chaos harness
              the above (repro.runtime.chaos)        (robustness testing)
  ==========  =====================================  =======================

The observable contract (enforced by tests/test_schedulers_conformance.py):

  * ``start()`` before any ``submit()``; returns ``self``; double-start
    raises. ``close()`` is idempotent, safe without ``start()``, and drains
    in-flight tasks before returning.
  * ``submit(fn, *args, **kwargs)`` enqueues a task; every substrate is
    bounded by ``capacity`` and backpressures (blocks) when full — tasks
    are never dropped. Burst-draining workers (relic, condvar) may hold
    up to one drained burst (≤ ``capacity`` tasks) in flight on top of
    the full queue, so the worst-case submitted-but-unfinished count is
    2×``capacity``, a constant — never unbounded growth.
  * ``submit_many(tasks)`` enqueues a burst of ``(fn, args, kwargs)``
    tuples with the same ordering, bounding, and error semantics as the
    equivalent ``submit()`` loop. The base class provides exactly that
    loop as the fallback (third-party substrates inherit it for free);
    relic/spin/condvar override it with native batch paths that pay one
    role-check/lock/counter-publication per burst instead of per task.
  * ``wait()`` blocks until every task submitted so far has completed. If
    any task raised since the last ``wait()``, the first such exception is
    re-raised there (and cleared); the scheduler stays usable.
  * ``sleep_hint()`` / ``wake_up_hint()`` are advisory (paper §VI-B): they
    may park/unpark a spinning worker and are no-ops for substrates that
    already suspend when idle.
  * ``stats`` exposes at least ``submitted``, ``completed``,
    ``task_errors``, and ``last_error``.
  * ``workers`` (optional, defaulting to 1 via ``getattr`` at use sites)
    advertises how many worker threads can run tasks concurrently —
    0 for serial (inline), 1 for the single-assistant substrates, N for
    pools. Consumers like ``repro.tasks.api.parallel_for`` derive their
    default grain from it; global FIFO is only guaranteed when
    ``workers <= 1``.

``submit()``/``wait()`` are owning-thread-only, mirroring Relic's
no-recursive-spawn rule (paper §VI-A): a task may not submit more tasks.
"""

from __future__ import annotations

import collections
import functools
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, List, Optional, Protocol,
                    Tuple, runtime_checkable)

from repro.core.relic import (Relic, RelicUsageError,
                              resolve_spin_pause_every)
from repro.core.relic_pool import RelicPool
from repro.core.spsc import DEFAULT_CAPACITY

__all__ = [
    "Scheduler",
    "SchedulerStats",
    "SchedulerUsageError",
    "SerialScheduler",
    "RelicScheduler",
    "RelicPoolScheduler",
    "SpinQueueScheduler",
    "CondvarQueueScheduler",
    "PoolScheduler",
    "ChaosScheduler",
    "available_schedulers",
    "make_scheduler",
    "register_scheduler",
]


class SchedulerUsageError(RuntimeError):
    """Raised on contract misuse (submit after close, double start, ...)."""


# Relic predates this module and raises its own error type; both satisfy
# the "misuse raises" clause of the contract.
USAGE_ERRORS = (SchedulerUsageError, RelicUsageError)


@dataclass
class SchedulerStats:
    """Minimal counter surface every substrate exposes (Relic's superset
    of these counters in ``RelicStats`` is duck-compatible)."""

    submitted: int = 0
    completed: int = 0
    task_errors: int = 0
    last_error: Optional[BaseException] = field(default=None, repr=False)


@runtime_checkable
class Scheduler(Protocol):
    """Structural type for a host scheduling substrate."""

    def start(self) -> "Scheduler": ...
    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> None: ...
    def submit_many(self, tasks: Iterable[Tuple[Callable[..., Any],
                                                tuple, dict]]) -> None: ...
    def wait(self) -> None: ...
    def sleep_hint(self) -> None: ...
    def wake_up_hint(self) -> None: ...
    def close(self) -> None: ...

    @property
    def stats(self) -> SchedulerStats: ...


# --------------------------------------------------------------------- registry

_REGISTRY: Dict[str, Callable[..., "Scheduler"]] = {}


def register_scheduler(name: str):
    """Class decorator registering a substrate under ``name`` (mirrors
    ``models/registry.py``: one flat name -> factory map)."""

    def deco(cls):
        _REGISTRY[name] = cls
        cls.name = name
        return cls

    return deco


def available_schedulers() -> List[str]:
    """Registered substrate names, stable order."""
    return sorted(_REGISTRY)


def make_scheduler(name: str, **kwargs: Any) -> "Scheduler":
    """Instantiate a substrate by name (not started)."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; available: {available_schedulers()}"
        ) from None
    return factory(**kwargs)


# ------------------------------------------------------------------ base class

class _SchedulerBase:
    """Shared plumbing: owning-thread checks, lifecycle flags, hints."""

    # Advertised concurrent-worker count (the optional SPI property):
    # how many worker threads can run tasks at once. 1 is the SPI-wide
    # default (a single assistant/worker); serial overrides with 0 and
    # pools with their lane/thread count. ``repro.tasks.api`` reads it
    # via getattr so borrowed third-party substrates need not have it.
    workers: int = 1

    def __init__(self) -> None:
        self.stats = SchedulerStats()
        self._started = False
        self._closed = False
        self._owner: Optional[int] = None

    # lifecycle ------------------------------------------------------------
    def start(self) -> "Scheduler":
        if self._started:
            raise SchedulerUsageError(f"{type(self).__name__} already started")
        self._started = True
        self._closed = False
        self._owner = threading.get_ident()
        self._start_impl()
        return self

    def close(self) -> None:
        if not self._started or self._closed:
            self._closed = True
            return
        self._closed = True
        self._close_impl()

    def _start_impl(self) -> None:  # pragma: no cover - trivial default
        pass

    def _close_impl(self) -> None:  # pragma: no cover - trivial default
        pass

    # batch submission: the SPI-wide fallback is the equivalent submit()
    # loop, so any substrate (including third-party registrations) honours
    # submit_many; relic/spin/condvar override with native batch paths.
    def submit_many(self, tasks: Iterable[Tuple[Callable[..., Any],
                                                tuple, dict]]) -> None:
        for fn, args, kwargs in tasks:
            self.submit(fn, *args, **kwargs)

    # hints: advisory, default no-op (substrates that suspend when idle
    # need no parking; spinning substrates override)
    def sleep_hint(self) -> None:
        pass

    def wake_up_hint(self) -> None:
        pass

    # helpers --------------------------------------------------------------
    def _check_submit(self, what: str = "submit()") -> None:
        if not self._started:
            raise SchedulerUsageError(f"{what} before start()")
        if self._closed:
            raise SchedulerUsageError(f"{what} after close()")
        if self._owner is not None and threading.get_ident() != self._owner:
            raise SchedulerUsageError(
                f"{what} must be called from the owning (producer) thread"
            )

    def _record_error(self, exc: BaseException) -> None:
        self.stats.task_errors += 1
        if self.stats.last_error is None:
            self.stats.last_error = exc

    def _raise_pending(self) -> None:
        if self.stats.last_error is not None:
            err, self.stats.last_error = self.stats.last_error, None
            raise err

    # context manager ------------------------------------------------------
    def __enter__(self) -> "Scheduler":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.close()


# ------------------------------------------------------------------ substrates

@register_scheduler("serial")
class SerialScheduler(_SchedulerBase):
    """The paper's baseline: no concurrency, tasks run inline at submit.

    Useful as the control in benchmarks and as the zero-thread fallback
    (e.g. pipelines in environments where spawning threads is undesirable).
    """

    workers = 0        # no worker threads: parallel_for runs fully inline

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        super().__init__()
        del capacity  # no queue: nothing to bound

    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> None:
        self._check_submit()
        self.stats.submitted += 1
        try:
            fn(*args, **kwargs)
        except BaseException as e:  # surfaced at the next wait()
            self._record_error(e)
        self.stats.completed += 1

    def wait(self) -> None:
        self._raise_pending()


class _RelicAdapterBase(_SchedulerBase):
    """Shared adapter plumbing for the Relic-family runtimes (the pair and
    the pool). Everything here is the non-hot-path boilerplate both
    adapters need verbatim — lifecycle, batch-SPI guards, misuse
    classification, the close()-must-not-raise error stash — factored out
    so a contract change cannot silently diverge the two. Only the merged
    ``submit()`` fast path stays per-adapter (its whole point is being
    inlined against one runtime's internals).

    Subclass ``__init__`` must set ``self._rt`` to the backing runtime:
    anything exposing ``start``/``submit_batch``/``wait``/``sleep_hint``/
    ``wake_up_hint``/``shutdown``/``_check_main`` and a ``stats`` object
    whose ``last_error`` is assignable (``RelicStats`` field /
    ``RelicPoolStats`` setter)."""

    _rt: Any

    @property  # type: ignore[override]
    def stats(self):
        return self._rt.stats

    @stats.setter
    def stats(self, value):  # _SchedulerBase.__init__ assigns; ignore it
        pass

    def _start_impl(self) -> None:
        self._rt.start()

    def submit_many(self, tasks: Iterable[Tuple[Callable[..., Any],
                                                tuple, dict]]) -> None:
        if not self._started:
            raise SchedulerUsageError("submit_many() before start()")
        if self._closed:
            raise SchedulerUsageError("submit_many() after close()")
        self._rt.submit_batch(tasks)

    def _submit_misuse(self, what: str) -> None:
        """Slow path: classify (and raise) the fast-path rejection."""
        if not self._started:
            # The runtime itself would accept this (roles are fixed at
            # start()); the uniform contract says it must raise, like
            # every substrate.
            raise SchedulerUsageError(f"{what} before start()")
        if self._closed:
            raise SchedulerUsageError(f"{what} after close()")
        self._rt._check_main(what)         # wrong thread (incl. assistants)
        raise SchedulerUsageError(f"{what} after shutdown")

    def wait(self) -> None:
        # The runtimes themselves guarantee advisory hints cannot deadlock
        # the barrier (wait/full-ring submit un-park assistants).
        self._rt.wait()

    def sleep_hint(self) -> None:
        self._rt.sleep_hint()

    def wake_up_hint(self) -> None:
        self._rt.wake_up_hint()

    def _close_impl(self) -> None:
        try:
            # Drain and update counters. close() must not raise, but the
            # error stays observable on stats (RelicStats keeps the field;
            # RelicPoolStats stashes it through its setter).
            self._rt.wait()
        except BaseException as e:
            self._rt.stats.last_error = e
        self._rt.shutdown()


@register_scheduler("relic")
class RelicScheduler(_RelicAdapterBase):
    """The paper's design (§VI): busy-wait SPSC ring, fixed producer and
    assistant roles. Adapter over :class:`repro.core.relic.Relic`;
    ``stats`` is the underlying ``RelicStats`` (a superset of
    ``SchedulerStats`` counters, including spin/park telemetry).

    ``submit()`` is deliberately *not* a thin forwarder: stacking the
    adapter's contract checks on top of ``Relic.submit``'s own (plus a
    second ``*args``/``**kwargs`` splat) costs several hundred ns per
    task — comparable to the ring push itself. The fast path merges both
    layers' checks into one branch and pushes straight into the ring;
    ``_submit_misuse`` re-runs the layered checks only to classify a
    failure. This couples the adapter to Relic internals, which is the
    point of the adapter being *in* the runtime package."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY, start_awake: bool = True):
        super().__init__()
        self._rt = self._relic = Relic(capacity=capacity,
                                       start_awake=start_awake)
        # Hot-path pre-binds: one attribute load each per submit, resolved
        # once here instead of chasing the relic -> ring chain per task.
        self._push2 = self._relic._push2
        self._rstats = self._relic.stats

    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> None:
        # _closed covers relic._shutdown (close() is its only caller), and
        # _owner equals relic's main ident (start() runs on one thread), so
        # three loads + one get_ident() decide the whole contract.
        if (self._closed or not self._started
                or threading.get_ident() != self._owner):
            self._submit_misuse("submit()")
        if kwargs:
            fn = functools.partial(fn, **kwargs)
        # Account after the hand-off, as Relic.submit does (an interrupt
        # unwinding the full-ring spin must not strand submitted > pushed).
        if self._push2(fn, args):
            self._rstats.submitted += 1
            return
        self._relic._push_spin(fn, args)
        self._rstats.submitted += 1


@register_scheduler("relic-pool")
class RelicPoolScheduler(_RelicAdapterBase):
    """Relic scaled past the SMT pair (see ``repro.core.relic_pool``): N
    lanes, each an independent SPSC ring + assistant preserving the exact
    invariants and fast paths of the pair; the producer stripes submissions
    round-robin with a least-loaded fallback and shards ``submit_many``
    bursts across the lanes in one pass. ``stats`` is the live aggregate
    ``RelicPoolStats`` view (``stats.lanes`` has the per-lane detail).

    Like :class:`RelicScheduler`, ``submit()`` is a merged fast path
    rather than a layered forwarder: one branch covers both the adapter's
    and the pool's contract checks, then the pre-bound striped push runs.
    ``capacity`` is **per lane** (each lane is its own bounded ring), so
    the backpressure bound is ``2 × capacity`` per lane — still a
    constant, never unbounded growth.

    Ordering: FIFO holds per lane, not globally (``workers = lanes``);
    callers needing global FIFO use a ``workers <= 1`` substrate.
    Registered as ``relic-pool`` (``lanes=N`` keyword, default 2) with
    convenience names ``relic2`` and ``relic4``.

    ``rebalance`` (default on, multi-lane only) enables the pool's skew
    resistance: producer-side re-striping of stuck burst remainders plus
    per-lane victim-cooperative handoff rings — dynamic load balancing
    that keeps every ring strictly SPSC (see ``repro.core.relic_pool``
    and docs/schedulers.md). ``rebalance=False`` is the PR 5 static
    striping, kept addressable for A/B measurement (the ``skew``
    benchmark section runs both)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY, lanes: int = 2,
                 start_awake: bool = True, rebalance: bool = True,
                 respawn: bool = False, supervise: Optional[bool] = None,
                 heartbeat_ms: Optional[float] = None):
        super().__init__()
        self._rt = self._pool = RelicPool(lanes=lanes, capacity=capacity,
                                          start_awake=start_awake,
                                          rebalance=rebalance,
                                          respawn=respawn,
                                          supervise=supervise,
                                          heartbeat_ms=heartbeat_ms)
        # Hot-path pre-bind: the pool's no-checks striped push.
        self._submit2 = self._pool._submit2
        if lanes == 1 and not respawn:
            # Degenerate pool, adapter edition: shadow submit() with a
            # closure whose hot path is byte-for-byte the pair adapter's
            # (free-variable loads, no pool hop) — the lanes=1 scaling
            # rows must measure the pair, not an extra call frame.
            lane0 = self._pool._lane0
            push2 = self._pool._push2_0
            rstats = self._pool._stats0

            def submit_single(fn: Callable[..., Any], *args: Any,
                              **kwargs: Any) -> None:
                if (self._closed or not self._started
                        or threading.get_ident() != self._owner):
                    self._submit_misuse("submit()")
                if kwargs:
                    fn = functools.partial(fn, **kwargs)
                if push2(fn, args):
                    rstats.submitted += 1
                    return
                lane0._push_spin(fn, args)
                rstats.submitted += 1

            self.submit = submit_single    # instance attr shadows the method

    @property
    def workers(self) -> int:  # type: ignore[override]
        return self._pool.n_lanes

    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> None:
        # Same merged contract check as RelicScheduler: _closed covers
        # pool shutdown (close() is its only caller) and _owner equals the
        # pool's main ident (start() runs on one thread).
        if (self._closed or not self._started
                or threading.get_ident() != self._owner):
            self._submit_misuse("submit()")
        if kwargs:
            fn = functools.partial(fn, **kwargs)
        self._submit2(fn, args)

    # Lane-supervision pass-throughs (PR 8) for fire-and-observe consumers
    # (the serve loop never calls wait(), so it reads lane health here).
    def poll_lane_failures(self):
        """One supervision sweep + drain: quarantine newly dead lanes
        (respawning when configured) and return every not-yet-consumed
        ``LaneFailure``. Owning-thread only."""
        self._pool.check_lanes()
        return self._pool.take_lane_failures()

    def in_flight_estimate(self) -> int:
        return self._pool.in_flight_estimate()

    def stalled_lanes(self):
        return self._pool.stalled_lanes()

    def straggler_lanes(self):
        return self._pool.straggler_lanes()


def _register_pool_convenience(name: str, lanes: int) -> None:
    """Fixed-lane-count convenience names (``relic2``/``relic4``): the same
    ``RelicPoolScheduler``, pre-parameterized, so benchmark matrices and
    ``scheduler=`` strings can name a lane count without kwargs plumbing."""

    def factory(**kwargs: Any) -> RelicPoolScheduler:
        if kwargs.setdefault("lanes", lanes) != lanes:
            # The name IS the lane count: a row or stats dump labelled
            # relic4 must never secretly be a 2-lane pool. Overriding
            # lanes is what the generic "relic-pool" name is for.
            raise ValueError(
                f"{name!r} is fixed at lanes={lanes}; got "
                f"lanes={kwargs['lanes']} (use 'relic-pool' to pick a "
                "lane count)")
        sched = RelicPoolScheduler(**kwargs)
        sched.name = name              # instance attr shadows the class name
        return sched

    _REGISTRY[name] = factory


_register_pool_convenience("relic2", 2)
_register_pool_convenience("relic4", 4)


@register_scheduler("spin")
class SpinQueueScheduler(_SchedulerBase):
    """Persistent worker over a mutex-protected deque with spin waits on
    both sides (the X-OpenMP flavour: lock-based queue + spinning).

    Promoted from the benchmark-private ``_SpinWorker`` and hardened: the
    queue is bounded (backpressure instead of unbounded growth), task
    exceptions are captured and re-raised at ``wait()`` instead of killing
    the worker, and ``sleep_hint()`` actually parks the spinning worker.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        super().__init__()
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self._capacity = capacity
        # Per-instance spin/yield cadence (RELIC_SPIN_PAUSE_EVERY aware),
        # same resolution rule as Relic so the spin-vs-relic comparison
        # benchmarks the same yield regime.
        self._spin_pause_every = resolve_spin_pause_every()
        self._dq: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._completed = 0            # worker-only writer
        self._stop = False
        self._awake = threading.Event()
        self._awake.set()
        self._t: Optional[threading.Thread] = None

    def _start_impl(self) -> None:
        self._t = threading.Thread(
            target=self._loop, name=f"{self.name}-worker", daemon=True)
        self._t.start()

    def _loop(self) -> None:
        spins = 0
        pause_every = self._spin_pause_every
        while True:
            item = None
            with self._lock:
                if self._dq:
                    item = self._dq.popleft()
            if item is None:
                if self._stop:
                    return
                if not self._awake.is_set():
                    self._awake.wait()
                    continue
                spins += 1
                if spins % pause_every == 0:
                    time.sleep(0)
                continue
            spins = 0
            fn, args, kwargs = item
            try:
                fn(*args, **kwargs)
            except BaseException as e:
                self._record_error(e)
            self._completed += 1

    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> None:
        self._check_submit()
        spins = 0
        while True:
            with self._lock:
                if len(self._dq) < self._capacity:
                    self._dq.append((fn, args, kwargs))
                    break
            if spins == 0:
                # Full queue + parked worker would deadlock this spin:
                # hints are advisory, not fatal — un-park once (only this
                # blocked thread could re-park it).
                self._awake.set()
            spins += 1               # bounded queue: spin until a slot frees
            if spins % self._spin_pause_every == 0:
                time.sleep(0)
        self.stats.submitted += 1

    def submit_many(self, tasks: Iterable[Tuple[Callable[..., Any],
                                                tuple, dict]]) -> None:
        """Native batch path: each lock acquisition moves as many tasks as
        the bounded deque has room for, instead of one."""
        self._check_submit("submit_many()")
        if not isinstance(tasks, (list, tuple)):
            tasks = list(tasks)
        n = len(tasks)
        pos = 0
        spins = 0
        while pos < n:
            with self._lock:
                free = self._capacity - len(self._dq)
                if free > 0:
                    take = min(free, n - pos)
                    self._dq.extend(tasks[pos:pos + take])
                    pos += take
                    self.stats.submitted += take
                    spins = 0
                    continue
            if spins == 0:
                self._awake.set()     # same advisory-hint rule as submit()
            spins += 1
            if spins % self._spin_pause_every == 0:
                time.sleep(0)

    def wait(self) -> None:
        if self._completed < self.stats.submitted:
            # Advisory hints must not deadlock the barrier: un-park the
            # worker (callers wanting it parked re-issue sleep_hint after).
            self._awake.set()
        spins = 0
        pause_every = self._spin_pause_every
        while self._completed < self.stats.submitted:
            spins += 1
            if spins % pause_every == 0:
                time.sleep(0)
        self.stats.completed = self._completed
        self._raise_pending()

    def sleep_hint(self) -> None:
        self._awake.clear()

    def wake_up_hint(self) -> None:
        self._awake.set()

    def _close_impl(self) -> None:
        self._stop = True
        self._awake.set()
        if self._t is not None:
            self._t.join(timeout=5)
            self._t = None
        self.stats.completed = self._completed


@register_scheduler("condvar")
class CondvarQueueScheduler(_SchedulerBase):
    """Persistent worker over a bounded condvar-guarded deque (suspension on
    both sides — the GNU-OpenMP flavour: suspension-based waits). Promoted
    from the benchmark-private ``_CondvarWorker`` and hardened: bounded
    queue, exception capture, idempotent shutdown. The deque+Condition pair
    replaced ``queue.Queue`` so the native ``submit_many`` path can move a
    whole burst per lock acquisition (and the worker can drain one), which
    a ``Queue`` cannot express."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        super().__init__()
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self._capacity = capacity
        self._dq: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._done = threading.Semaphore(0)
        self._outstanding = 0
        self._t: Optional[threading.Thread] = None

    def _start_impl(self) -> None:
        self._t = threading.Thread(
            target=self._loop, name=f"{self.name}-worker", daemon=True)
        self._t.start()

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._dq:
                    self._cv.wait()
                # Drain the full burst under one lock acquisition; the None
                # shutdown sentinel is FIFO-last so it ends the final batch.
                batch = list(self._dq)
                self._dq.clear()
                self._cv.notify()         # free a producer blocked on full
            for item in batch:
                if item is None:
                    return
                fn, args, kwargs = item
                try:
                    fn(*args, **kwargs)
                except BaseException as e:
                    self._record_error(e)
                finally:
                    self.stats.completed += 1
                    self._done.release()

    def _put(self, item: Any) -> None:
        with self._cv:
            while len(self._dq) >= self._capacity:
                self._cv.wait()           # blocks when full: backpressure
            self._dq.append(item)
            self._cv.notify()

    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> None:
        self._check_submit()
        self._put((fn, args, kwargs))
        self.stats.submitted += 1
        self._outstanding += 1

    def submit_many(self, tasks: Iterable[Tuple[Callable[..., Any],
                                                tuple, dict]]) -> None:
        """Native batch path: each wakeup hands the worker every task the
        bounded queue has room for, one notify per sub-burst."""
        self._check_submit("submit_many()")
        if not isinstance(tasks, (list, tuple)):
            tasks = list(tasks)
        n = len(tasks)
        pos = 0
        with self._cv:
            while pos < n:
                free = self._capacity - len(self._dq)
                if free <= 0:
                    self._cv.wait()
                    continue
                take = min(free, n - pos)
                self._dq.extend(tasks[pos:pos + take])
                pos += take
                self.stats.submitted += take
                self._outstanding += take
                self._cv.notify()

    def wait(self) -> None:
        for _ in range(self._outstanding):
            self._done.acquire()
        self._outstanding = 0
        self._raise_pending()

    def _close_impl(self) -> None:
        if self._t is not None:
            self._put(None)               # drains FIFO: sentinel is last
            self._t.join(timeout=5)
            self._t = None


@register_scheduler("pool")
class PoolScheduler(_SchedulerBase):
    """General thread pool + futures (the oneTBB/Taskflow flavour): dynamic
    worker assignment, no fixed roles, OS-mediated wakeups. ``capacity``
    bounds the number of in-flight tasks (a semaphore blocks submit when
    full), matching the bounded-backpressure contract of the other
    substrates — without it, consumers like the async checkpoint manager
    could queue unbounded host-memory snapshots."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY, workers: int = 2):
        super().__init__()
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self._workers = workers
        self._slots = threading.BoundedSemaphore(capacity)
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pending: List[Future] = []

    @property
    def workers(self) -> int:  # type: ignore[override]
        return self._workers

    def _start_impl(self) -> None:
        self._pool = ThreadPoolExecutor(
            max_workers=self._workers, thread_name_prefix=f"{self.name}-worker")

    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> None:
        self._check_submit()
        assert self._pool is not None
        self._slots.acquire()        # backpressure: block at capacity in flight
        self.stats.submitted += 1
        fut = self._pool.submit(fn, *args, **kwargs)
        fut.add_done_callback(lambda _f: self._slots.release())
        self._pending.append(fut)
        if len(self._pending) >= 4 * self._workers:
            # Consumers like PrefetchPipeline submit forever without ever
            # calling wait(); reap finished futures so _pending stays O(1).
            self._reap(block=False)

    def _reap(self, block: bool) -> None:
        still: List[Future] = []
        for f in self._pending:
            if block or f.done():
                try:
                    f.result()
                except BaseException as e:
                    self._record_error(e)
                self.stats.completed += 1
            else:
                still.append(f)
        self._pending = still

    def wait(self) -> None:
        self._reap(block=True)
        self._raise_pending()

    def _close_impl(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        # every future is done after shutdown(wait=True); record outcomes
        # (close() must not raise — errors stay observable in stats)
        self._reap(block=True)


# Registered last so the registry is complete the moment this module is
# importable: the chaos wrapper lives in repro.runtime.chaos (which must
# not import this module at top level — it resolves make_scheduler lazily)
# and joins the registry here, exactly like the substrates defined above.
from repro.runtime.chaos import ChaosScheduler  # noqa: E402

register_scheduler("chaos")(ChaosScheduler)
