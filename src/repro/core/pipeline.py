"""Inter-pod pipeline parallelism (GPipe schedule) — the third scale of the
Relic pattern.

Pods are connected by slower DCN/ICI links than chips within a pod, so the
natural pod-axis parallelism choices are pure DP (the dry-run default) or
**pipeline stages**. This module implements the latter: contiguous layer
blocks live on each pod (`stage = pod index`), microbatches stream through,
and the stage→stage activation handoff is a `ppermute` — a fixed-role
producer/consumer chain with a depth-1 buffer, i.e. the paper's SPSC queue
stretched across pods.

Schedule: GPipe (fill, steady state, drain): T = M + S - 1 ticks for M
microbatches over S stages. Bubble fraction = (S-1)/(M+S-1); callers pick
M >> S. Reverse-mode AD works through the schedule (static trip counts), so
`jax.grad` of a pipelined loss gives pipelined backward for free — XLA
schedules the backward ppermutes against the backward stage compute.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat


def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,
    x_mb: jax.Array,
    mesh,
    *,
    axis_name: str = "pod",
) -> jax.Array:
    """Run microbatches through pod-resident pipeline stages.

    Args:
      stage_fn: ``(stage_params_local, x) -> y`` — one stage's layer block
        applied to one microbatch activation ``[mb, S, D]``.
      stage_params: pytree with leading dim = n_stages, sharded over
        ``axis_name`` (each pod holds exactly its stage's slice).
      x_mb: ``[M, mb, S, D]`` microbatches (replicated across the axis).
      mesh: the device mesh containing ``axis_name``.

    Returns: ``[M, mb, S, D]`` outputs of the final stage (replicated).
    """
    n_stages = mesh.shape[axis_name]
    m = x_mb.shape[0]
    ticks = m + n_stages - 1
    fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

    def local(params_local, x_all):
        # params_local: [1, ...] this pod's stage block; x_all: [M, mb, S, D]
        params_me = jax.tree.map(lambda a: a[0], params_local)
        stage = lax.axis_index(axis_name)
        mb_shape = x_all.shape[1:]

        def tick(t, carry):
            in_buf, outputs = carry
            mb_idx = t - stage                      # microbatch at this stage
            active = (mb_idx >= 0) & (mb_idx < m)
            safe_idx = jnp.clip(mb_idx, 0, m - 1)
            # stage 0 consumes fresh microbatches; others consume the buffer
            # filled by their upstream neighbor last tick (the SPSC slot).
            x_in = jnp.where(stage == 0,
                             lax.dynamic_index_in_dim(x_all, safe_idx, 0,
                                                      keepdims=False),
                             in_buf)
            y = stage_fn(params_me, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # producer lane: hand the activation to the next stage
            out_buf = lax.ppermute(y, axis_name, fwd_perm)
            # last stage retires finished microbatches
            is_last = stage == n_stages - 1
            write_idx = jnp.where(active & is_last, safe_idx, m)  # m == drop
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(active & is_last, y,
                          lax.dynamic_index_in_dim(outputs,
                                                   jnp.minimum(write_idx, m - 1),
                                                   0, keepdims=False)),
                jnp.minimum(write_idx, m - 1), 0)
            return out_buf, outputs

        buf0 = jnp.zeros(mb_shape, x_all.dtype)
        if hasattr(lax, "pvary"):
            buf0 = lax.pvary(buf0, (axis_name,))
        outputs0 = jnp.zeros((m,) + mb_shape, x_all.dtype)
        if hasattr(lax, "pvary"):
            outputs0 = lax.pvary(outputs0, (axis_name,))
        _, outputs = lax.fori_loop(0, ticks, tick, (buf0, outputs0))
        # only the last stage holds real outputs; broadcast them to every pod
        # (psum of one-hot contributions — replicated result).
        is_last = (lax.axis_index(axis_name) == n_stages - 1)
        contrib = jnp.where(is_last, outputs, jnp.zeros_like(outputs))
        return lax.psum(contrib, axis_name)

    n_leading = {a.shape[0] for a in jax.tree.leaves(stage_params)}
    assert n_leading == {n_stages}, (n_leading, n_stages)
    in_specs = (jax.tree.map(lambda _: P(axis_name), stage_params), P())
    return compat.shard_map(
        local, mesh=mesh, in_specs=in_specs, out_specs=P(),
        axis_names={axis_name},
    )(stage_params, x_mb)


def split_stages(layers_stacked: Any, n_stages: int) -> Any:
    """[L, ...] stacked layer params -> [n_stages, L/n_stages, ...]."""
    def one(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])

    return jax.tree.map(one, layers_stacked)
