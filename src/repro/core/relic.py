"""Relic host runtime: specialized two-thread fine-grained tasking (paper §VI).

Faithful port of the paper's design to a Python host runtime:

  * exactly two roles — the **main** thread (producer) and one **assistant**
    thread (consumer). The assistant is created and owned by the runtime.
  * the only scheduling structure is a bounded SPSC ring (capacity 128);
    no work stealing, no priorities, no dynamic load balancing.
  * task submission is only legal from the main thread; the assistant cannot
    submit (recursive task creation is unsupported, exactly as in the paper).
  * waiting is busy-wait first (paper §VI-B: spinning wins for short waits in
    lightly-contended two-thread settings), with explicit developer-driven
    ``wake_up_hint()`` / ``sleep_hint()`` to park the assistant across long
    serial sections instead of a hybrid spin-then-sleep heuristic.

On TPU the same schedule is realized by the DMA/compute lanes inside the
Pallas kernels (see ``repro.kernels.relic_matmul``) and by the ppermute ring
in ``repro.core.collective_matmul``; this module is the host-scale instance,
used by the data pipeline and the async checkpoint manager.

CPython note (recorded in docs/schedulers.md): overlap is only real for tasks that
release the GIL (JAX dispatch/compute, NumPy kernels, file I/O). That matches
the paper's scope — Relic targets *parallelizable sections*, and the hints
exist precisely because the rest of the application is serial.
"""

from __future__ import annotations

import functools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Optional, Sequence, Tuple

from repro.core.spsc import DEFAULT_CAPACITY, SpscRing

# Task protocol: the ring carries bare ``fn, args`` pairs striped across two
# slots (``push2``/flattened ``push_many``) — no per-task wrapper object, so
# a submit allocates nothing beyond what the call protocol already built.
# Keyword arguments (rare on a µs-scale hot path) are folded into ``fn`` via
# ``functools.partial`` before the push. Both counters therefore always
# advance by even amounts, and every drained burst has even length.


class RelicUsageError(RuntimeError):
    """Raised on API misuse (e.g. submit from the assistant thread)."""


class RelicDeadError(RuntimeError):
    """The assistant thread died with work outstanding.

    Raised by the producer's bounded-wait liveness probes (``_barrier``,
    ``_push_spin``, ``_push_flat`` check ``assistant.is_alive()`` every
    ``_PROBE_EVERY_SPINS`` spin rounds when ``RELIC_SUPERVISE`` is on)
    instead of spinning forever on a counter that can no longer advance.
    Carries the diagnostics a supervisor needs: which lane, how many tasks
    were submitted/completed, and how many in-flight tasks are lost with
    the dead consumer. See docs/robustness.md for the failure model.
    """

    def __init__(self, lane: str, submitted: int, completed: int,
                 lost: int) -> None:
        super().__init__(
            f"assistant thread {lane!r} is dead: submitted={submitted} "
            f"completed={completed} lost={lost} (in-flight tasks on a ring "
            "nothing will ever drain)")
        self.lane = lane
        self.submitted = submitted
        self.completed = completed
        self.lost = lost


def flatten_tasks(
    tasks: Iterable[Tuple[Callable[..., Any], tuple, dict]]
) -> list:
    """Flatten ``(fn, args, kwargs)`` triples into the ring's ``fn, args``
    pair stripe (kwargs fold into a ``functools.partial``) — THE task wire
    format both the pair and the pool push and every assistant pops; keep
    it in exactly one place."""
    flat: list = []
    append = flat.append
    for fn, args, kwargs in tasks:
        if kwargs:
            fn = functools.partial(fn, **kwargs)
        append(fn)
        append(args)
    return flat


@dataclass
class RelicStats:
    """Counters for observability; all updated on the owning thread only."""

    submitted: int = 0
    completed: int = 0
    producer_full_spins: int = 0     # times submit() found the ring full
    assistant_empty_spins: int = 0   # assistant poll iterations that found no work
    parks: int = 0                   # times the assistant actually parked
    task_errors: int = 0
    last_error: Optional[BaseException] = field(default=None, repr=False)
    # Submission index (0-based, per runtime) of the task behind
    # ``last_error`` — how RelicPool orders first-errors across lanes.
    # ``first_error_index`` counts primary-ring completions; when the
    # failed task arrived through the handoff (overflow) ring instead,
    # ``first_error_handoff_index`` is set (counting handoff completions)
    # and ``first_error_index`` stays None. Exactly one is non-None while
    # ``last_error`` is pending; both clear with it (see ``_take_error``).
    first_error_index: Optional[int] = None
    first_error_handoff_index: Optional[int] = None


# Spin-cadence resolution lives with the other env-var knobs in
# ``repro.runtime.config``; re-exported here because this module is where
# callers (tests, benchmarks, docs) historically found it.
from repro.runtime.config import (_default_spin_yield,
                                  resolve_spin_pause_every,
                                  resolve_supervise_config)

SPIN_PAUSE_EVERY = _default_spin_yield()

# Liveness-probe cadence for the producer's spin loops: one
# ``Thread.is_alive()`` read per this many spin rounds. Spin rounds are
# sub-microsecond, so detection latency stays well under a millisecond
# while the probe cost is amortized to noise; the clean fast paths
# (submit-with-room, the assistant drain) never reach a probe at all.
_PROBE_EVERY_SPINS = 1024


class Relic:
    """The Relic runtime: one producer (main) + one assistant (consumer).

    Usage::

        rt = Relic()
        rt.start()
        rt.wake_up_hint()          # before a parallelizable section
        rt.submit(fn, a, b)        # main thread only
        ...                        # main thread does its own half of the work
        rt.wait()                  # barrier for all submitted tasks
        rt.sleep_hint()            # after the section
        rt.shutdown()
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY, start_awake: bool = False,
                 name: str = "relic-assistant", handoff: bool = False):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        # Two ring slots per task (the fn, args stripe — see the task
        # protocol note above), so `capacity` stays a task count.
        self._ring = SpscRing(2 * capacity)
        self._push2 = self._ring.push2      # pre-bound: the submit hot path
        # Optional victim-cooperative handoff ring (RelicPool rebalancing):
        # a second, equally-bounded SPSC ring the *pool producer* fills only
        # when this lane's primary is backed up, and the assistant drains
        # only when the primary is empty. Still strictly 1P1C per ring —
        # same producer thread, same consumer thread, two rings. The plain
        # pair never allocates it and keeps its original assistant loop.
        self._oring: Optional[SpscRing] = SpscRing(2 * capacity) if handoff else None
        self._name = name                   # assistant thread name (pool lanes)
        self._spin_pause_every = resolve_spin_pause_every()
        # Bounded waits (PR 8): with RELIC_SUPERVISE on (the default) the
        # producer's spin loops probe assistant liveness every
        # _PROBE_EVERY_SPINS rounds and raise RelicDeadError instead of
        # hanging; 0 disables every probe (the pre-supervision spins).
        self._probe_every = (_PROBE_EVERY_SPINS
                             if resolve_supervise_config().supervise else 0)
        # Opt-in chaos hook (repro.runtime.chaos): when set, the assistant
        # calls it once per drained burst (with the burst's task count) and
        # exits abruptly — simulated thread death — when it returns True.
        # None for every production instance: the cost on a live assistant
        # is one attribute load + is-None branch per *burst*, off the
        # per-task hot path.
        self._chaos_kill: Optional[Callable[[int], bool]] = None
        self.stats = RelicStats()
        self._completed = 0              # written by assistant only (both rings)
        self._completed_ovf = 0          # handoff-ring completions only
        self._shutdown = False
        self._awake = threading.Event()  # wake_up_hint/sleep_hint state
        if start_awake:
            self._awake.set()
        self._assistant: Optional[threading.Thread] = None
        self._main_ident: Optional[int] = None

    # ------------------------------------------------------------------ roles

    def start(self) -> "Relic":
        if self._assistant is not None:
            raise RelicUsageError("Relic runtime already started")
        self._main_ident = threading.get_ident()
        target = (self._assistant_loop if self._oring is None
                  else self._assistant_loop_handoff)
        self._assistant = threading.Thread(
            target=target, name=self._name, daemon=True
        )
        self._assistant.start()
        return self

    def _check_main(self, what: str) -> None:
        ident = threading.get_ident()
        if self._assistant is not None and ident == self._assistant.ident:
            # Paper §VI-A: "The assistant thread cannot submit tasks, hence,
            # creating tasks recursively is not supported in Relic."
            raise RelicUsageError(f"{what} called from the assistant thread")
        if self._main_ident is not None and ident != self._main_ident:
            raise RelicUsageError(
                f"{what} must be called from the main (producer) thread"
            )

    # ------------------------------------------------------------- public API

    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> None:
        """Submit a fine-grained task (main thread only). Busy-waits if full.

        Allocation-free: the hot path pushes the ``fn, args`` pair the call
        protocol already built straight into two ring slots (§VI: expressing
        a task must be nearly free). Keyword arguments take the rare
        ``functools.partial`` fold."""
        if threading.get_ident() != self._main_ident:
            self._check_main("submit()")   # slow path: classify the misuse
        if self._shutdown:
            raise RelicUsageError("submit() after shutdown")
        if kwargs:
            fn = functools.partial(fn, **kwargs)
        # Account after the hand-off (not before): an interrupt unwinding
        # the full-ring spin must not strand submitted > pushed, which
        # would wedge every later wait() (see submit_batch).
        if self._push2(fn, args):
            self.stats.submitted += 1
            return
        self._push_spin(fn, args)
        self.stats.submitted += 1

    def submit_batch(
        self, tasks: Iterable[Tuple[Callable[..., Any], tuple, dict]]
    ) -> None:
        """Submit a burst of ``(fn, args, kwargs)`` tasks (main thread only).

        One role check covers the whole burst, which is flattened into the
        ring's pair stripe and handed off by ``push_many`` — a single
        ``_tail`` store per sub-burst. Busy-waits (ring backpressure)
        whenever the burst outsizes the free slots. Accounting is
        committed as tasks are handed to the ring, not up front, so a
        ``BaseException`` (KeyboardInterrupt) escaping the backpressure
        spin can never strand ``submitted`` above what the assistant will
        ever see — the next ``wait()`` still terminates."""
        if threading.get_ident() != self._main_ident:
            self._check_main("submit_batch()")
        if self._shutdown:
            raise RelicUsageError("submit_batch() after shutdown")
        flat = flatten_tasks(tasks)
        if not flat:
            return
        self._push_flat(flat, account=True)

    def _push_flat(self, flat: Sequence[Any], start: int = 0,
                   stop: Optional[int] = None, account: bool = False) -> None:
        """Hand a pre-flattened ``fn, args`` stripe (``flat[start:stop]``)
        to the ring, busy-waiting under backpressure. Retries advance an
        offset into ``flat`` (push_many's ``start``): a burst far larger
        than the ring spins here, and slicing the remainder per sub-burst
        would be quadratic. ``RelicPool`` pushes each lane's shard of one
        shared flattened burst through this without slicing it either.
        With ``account=True``, ``stats.submitted`` advances with each
        successful sub-push (after it, never before — an interrupt unwinding
        from the spin leaves ``submitted <= pushed``, which can only make a
        later barrier return early by the unaccounted stragglers, never
        busy-spin forever on tasks that were never handed off)."""
        ring = self._ring
        stats = self.stats
        n = len(flat) if stop is None else stop
        pos = start
        pushed = ring.push_many(flat, start, n)
        if pushed:
            pos += pushed
            if account:
                stats.submitted += pushed // 2
        spins = 0
        pause_every = self._spin_pause_every
        probe_every = self._probe_every
        while pos < n:
            if spins == 0:
                # Advisory hints must not deadlock a full-ring burst: the
                # parked assistant is the only possible drain (§VI-B rule).
                self._awake.set()
            stats.producer_full_spins += 1
            spins += 1
            if spins % pause_every == 0:
                time.sleep(0)
            if probe_every and spins % probe_every == 0:
                self._probe_alive()   # a dead consumer never frees a slot
            pushed = ring.push_many(flat, pos, n)
            if pushed:
                pos += pushed
                if account:
                    stats.submitted += pushed // 2
                spins = 0

    def _push_spin(self, fn: Callable[..., Any], args: tuple) -> None:
        """Full-ring slow path for submit(): bounded ring is the backpressure."""
        spins = 0
        pause_every = self._spin_pause_every
        probe_every = self._probe_every
        while not self._push2(fn, args):
            if spins == 0:
                # Hints are advisory (§VI-B): a full ring with a parked
                # assistant cannot drain, so submission un-parks it. Once
                # is enough — only this (blocked) thread could re-park it.
                self._awake.set()
            self.stats.producer_full_spins += 1
            spins += 1
            if spins % pause_every == 0:
                time.sleep(0)  # the Python analogue of `pause`: yield, no park
            if probe_every and spins % probe_every == 0:
                self._probe_alive()   # a dead consumer never frees a slot

    def wait(self) -> None:
        """Block (busy-wait) until every submitted task has completed."""
        self._check_main("wait()")
        self._barrier()
        err = self._take_error()
        if err is not None:
            raise err

    def is_alive(self) -> bool:
        """True while the assistant thread can still make progress: not yet
        started, or started and its thread is alive. (After a clean
        ``shutdown`` the assistant reference is dropped and this is True
        again — a shut-down runtime is not *dead*, it is closed.)"""
        a = self._assistant
        return a is None or a.is_alive()

    def _probe_alive(self) -> None:
        """Liveness probe for the producer's spin loops: raise
        ``RelicDeadError`` if the assistant thread died. Once dead its
        ``_completed`` counter is final, so the lost count (submitted but
        never-to-complete tasks) is deterministic at the raise."""
        a = self._assistant
        if a is None or a.is_alive():
            return
        submitted = self.stats.submitted
        completed = self._completed
        if submitted - completed <= 0:
            # The assistant finished everything before dying: the caller's
            # spin condition will observe that on its next check (a dead
            # counter is final — nothing is lost, nothing can hang).
            return
        raise RelicDeadError(self._name, submitted, completed,
                             submitted - completed)

    def _barrier(self) -> None:
        """The spin half of ``wait()``: block until every submitted task
        completed. RelicPool barriers each lane through this so it can map
        lane-local error indexes to pool-global submission order *before*
        the error state is consumed. Raises nothing — except
        ``RelicDeadError`` when supervision is on and the assistant thread
        died with the barrier outstanding (the wait-liveness contract,
        docs/schedulers.md): spinning on a counter whose only writer is
        gone would never return."""
        target = self.stats.submitted
        if self._completed < target:
            # Advisory hints must not deadlock the barrier: outstanding
            # work with a parked assistant un-parks it (callers that want
            # the assistant parked re-issue sleep_hint() after waiting).
            self._awake.set()
        spins = 0
        pause_every = self._spin_pause_every
        probe_every = self._probe_every
        while self._completed < target:
            spins += 1
            if spins % pause_every == 0:
                time.sleep(0)
            if probe_every and spins % probe_every == 0:
                self._probe_alive()
        self.stats.completed = self._completed

    def _take_error(self) -> Optional[BaseException]:
        """Consume the pending first error, clearing ``last_error`` AND both
        first-error indexes together. They are one unit of state: clearing
        the error while leaving an index (the pre-PR 6 bug) let
        ``RelicPoolStats.last_error`` and ``_trim_runs`` observe a
        submission index from a dead window."""
        stats = self.stats
        err = stats.last_error
        if err is not None:
            stats.last_error = None
            stats.first_error_index = None
            stats.first_error_handoff_index = None
        return err

    def _completed_main_estimate(self) -> int:
        """Lower bound on *primary-ring* completions, safe to read from the
        producer: the total is read before the handoff count and both only
        grow, so the difference can only undercount (the clamp covers the
        pathological interleaving where many handoff tasks complete between
        the two reads). Exact (== ``_completed``) for a plain pair."""
        total = self._completed
        est = total - self._completed_ovf
        return est if est > 0 else 0

    def wake_up_hint(self) -> None:
        """Developer hint: a parallelizable section is imminent (paper §VI-B)."""
        self._awake.set()

    def sleep_hint(self) -> None:
        """Developer hint: no tasks for a while; assistant may park."""
        self._awake.clear()

    def shutdown(self, timeout: float = 5.0) -> None:
        if self._assistant is None:
            return
        self._shutdown = True
        self._awake.set()  # release a parked assistant so it can observe shutdown
        self._assistant.join(timeout)
        if self._assistant.is_alive():
            # The join expired: the assistant is wedged in a task. Dropping
            # the reference here would let a later start() spawn a SECOND
            # consumer on the SPSC ring (single-consumer invariant broken).
            # Keep the live thread, stay shut down (submit keeps raising,
            # start() keeps raising "already started"): non-restartable.
            raise RelicUsageError(
                f"shutdown(): assistant did not exit within {timeout}s "
                "(wedged task?); runtime left in a non-restartable state"
            )
        self._assistant = None

    # ---------------------------------------------------------- assistant side

    def _assistant_loop(self) -> None:
        ring = self._ring
        stats = self.stats
        pop_many = ring.pop_many
        spins = 0
        pause_every = self._spin_pause_every
        while True:
            # Drain the whole burst before re-checking hints or shutdown: one
            # _head publication per burst (pop_many), not one per task. The
            # drain must stay unbounded — every producer publication is a
            # whole number of fn,args pairs, so an unbounded pop keeps the
            # stripe aligned (an odd max_items could split a pair).
            batch = pop_many()
            if not batch:
                if self._shutdown:
                    return
                if not self._awake.is_set():
                    # sleep_hint() was given: park on the event (OS suspension)
                    # instead of burning the core. wake_up_hint() releases us.
                    stats.parks += 1
                    self._awake.wait()
                    continue
                stats.assistant_empty_spins += 1
                spins += 1
                if spins % pause_every == 0:
                    time.sleep(0)  # `pause`-like: yield the GIL, stay runnable
                continue
            spins = 0
            if self._chaos_kill is not None and self._chaos_kill(len(batch) // 2):
                return  # injected thread death: the popped burst is lost
            completed = self._completed    # assistant-only writer: no race
            for i in range(0, len(batch), 2):
                try:
                    batch[i](*batch[i + 1])
                except BaseException as e:  # surfaced at the next wait()
                    stats.task_errors += 1
                    if stats.last_error is None:
                        # First error wins (the SPI contract shared by every
                        # substrate — see docs/schedulers.md); later failures
                        # only bump task_errors. The submission index lets
                        # RelicPool order first-errors across lanes.
                        stats.first_error_index = completed
                        stats.last_error = e
                # Atomic per-task publication of completion (store of a
                # local, not a read-modify-write) so the producer's barrier
                # observes progress early.
                completed += 1
                self._completed = completed

    def _assistant_loop_handoff(self) -> None:
        """Assistant loop for a lane with a handoff ring (RelicPool
        rebalancing). Identical to ``_assistant_loop`` except: when the
        primary ring is empty, the assistant pulls from its handoff ring
        before parking/spinning — the victim-cooperative half of the
        pool's skew resistance. Primary work always drains first, so the
        lane's own FIFO is untouched; handoff tasks run at lane-idle
        priority, each ring still strictly one-producer/one-consumer.
        Kept as a separate loop so the plain pair's drain stays
        byte-for-byte the paper's two-thread hot path."""
        ring = self._ring
        oring = self._oring
        stats = self.stats
        pop_many = ring.pop_many
        opop_many = oring.pop_many
        spins = 0
        pause_every = self._spin_pause_every
        ovf_poll_every = 8  # idle iterations between overflow-ring polls
        ovf_countdown = 1   # first idle pass polls immediately
        c_main = 0      # primary-ring completions (local; assistant-only)
        c_ovf = 0       # handoff-ring completions
        while True:
            from_ovf = False
            batch = pop_many()
            if not batch:
                # Primary idle: help with handed-off (rebalanced) work.
                # An empty-ring pop still pays a cross-thread index read,
                # so the steady-state idle spin polls the handoff ring
                # only every few iterations (it is the lane's *cold* path
                # by construction — the producer fills it only when
                # primaries are backed up). Shutdown and park force the
                # poll: both must observe a drained handoff ring first.
                ovf_countdown -= 1
                if (ovf_countdown <= 0 or self._shutdown
                        or not self._awake.is_set()):
                    ovf_countdown = ovf_poll_every
                    batch = opop_many()
                    from_ovf = True
                if not batch:
                    if self._shutdown:
                        return          # both rings drained
                    if not self._awake.is_set():
                        stats.parks += 1
                        self._awake.wait()
                        continue
                    stats.assistant_empty_spins += 1
                    spins += 1
                    if spins % pause_every == 0:
                        time.sleep(0)
                    continue
            spins = 0
            if self._chaos_kill is not None and self._chaos_kill(len(batch) // 2):
                return  # injected thread death: the popped burst is lost
            for i in range(0, len(batch), 2):
                try:
                    batch[i](*batch[i + 1])
                except BaseException as e:
                    stats.task_errors += 1
                    if stats.last_error is None:
                        # First error wins, as in the primary loop; which
                        # ring carried the task decides which index field
                        # RelicPool maps through (seq log vs handoff log).
                        if from_ovf:
                            stats.first_error_handoff_index = c_ovf
                        else:
                            stats.first_error_index = c_main
                        stats.last_error = e
                if from_ovf:
                    c_ovf += 1
                    # Publication order matters for _trim_runs' racy reads:
                    # _completed_ovf first, then the total — a reader that
                    # takes the total first and the ovf count second can
                    # only *under*count primary completions (total - ovf),
                    # so seq-log trimming stays conservative.
                    self._completed_ovf = c_ovf
                else:
                    c_main += 1
                self._completed = c_main + c_ovf

    # ------------------------------------------------------------- context mgr

    def __enter__(self) -> "Relic":
        return self.start()

    def __exit__(self, exc_type: Any, *exc: Any) -> None:
        try:
            self.shutdown()
        except RelicUsageError:
            # A wedged-assistant shutdown is worth raising on a clean exit,
            # but must never mask the body's own in-flight exception.
            if exc_type is None:
                raise
