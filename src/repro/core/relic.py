"""Relic host runtime: specialized two-thread fine-grained tasking (paper §VI).

Faithful port of the paper's design to a Python host runtime:

  * exactly two roles — the **main** thread (producer) and one **assistant**
    thread (consumer). The assistant is created and owned by the runtime.
  * the only scheduling structure is a bounded SPSC ring (capacity 128);
    no work stealing, no priorities, no dynamic load balancing.
  * task submission is only legal from the main thread; the assistant cannot
    submit (recursive task creation is unsupported, exactly as in the paper).
  * waiting is busy-wait first (paper §VI-B: spinning wins for short waits in
    lightly-contended two-thread settings), with explicit developer-driven
    ``wake_up_hint()`` / ``sleep_hint()`` to park the assistant across long
    serial sections instead of a hybrid spin-then-sleep heuristic.

On TPU the same schedule is realized by the DMA/compute lanes inside the
Pallas kernels (see ``repro.kernels.relic_matmul``) and by the ppermute ring
in ``repro.core.collective_matmul``; this module is the host-scale instance,
used by the data pipeline and the async checkpoint manager.

CPython note (recorded in docs/schedulers.md): overlap is only real for tasks that
release the GIL (JAX dispatch/compute, NumPy kernels, file I/O). That matches
the paper's scope — Relic targets *parallelizable sections*, and the hints
exist precisely because the rest of the application is serial.
"""

from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.spsc import DEFAULT_CAPACITY, SpscRing


class RelicUsageError(RuntimeError):
    """Raised on API misuse (e.g. submit from the assistant thread)."""


@dataclass
class RelicStats:
    """Counters for observability; all updated on the owning thread only."""

    submitted: int = 0
    completed: int = 0
    producer_full_spins: int = 0     # times submit() found the ring full
    assistant_empty_spins: int = 0   # assistant poll iterations that found no work
    parks: int = 0                   # times the assistant actually parked
    task_errors: int = 0
    last_error: Optional[BaseException] = field(default=None, repr=False)


class _Task:
    __slots__ = ("fn", "args", "kwargs")

    def __init__(self, fn, args, kwargs):
        self.fn = fn
        self.args = args
        self.kwargs = kwargs


def _default_spin_yield() -> int:
    """`pause`-cadence adaptation: the paper assumes two hardware contexts
    (SMT). When the host has them, yield rarely (spin hot, paper §VI-B);
    when threads outnumber cores (this 1-core container), spin-waiting
    starves the partner thread across the GIL, so yield every iteration."""
    return 1 if (os.cpu_count() or 1) < 2 + 1 else 64


SPIN_PAUSE_EVERY = _default_spin_yield()


class Relic:
    """The Relic runtime: one producer (main) + one assistant (consumer).

    Usage::

        rt = Relic()
        rt.start()
        rt.wake_up_hint()          # before a parallelizable section
        rt.submit(fn, a, b)        # main thread only
        ...                        # main thread does its own half of the work
        rt.wait()                  # barrier for all submitted tasks
        rt.sleep_hint()            # after the section
        rt.shutdown()
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY, start_awake: bool = False):
        self._ring = SpscRing(capacity)
        self.stats = RelicStats()
        self._completed = 0              # written by assistant only
        self._shutdown = False
        self._awake = threading.Event()  # wake_up_hint/sleep_hint state
        if start_awake:
            self._awake.set()
        self._assistant: Optional[threading.Thread] = None
        self._main_ident: Optional[int] = None

    # ------------------------------------------------------------------ roles

    def start(self) -> "Relic":
        if self._assistant is not None:
            raise RelicUsageError("Relic runtime already started")
        self._main_ident = threading.get_ident()
        self._assistant = threading.Thread(
            target=self._assistant_loop, name="relic-assistant", daemon=True
        )
        self._assistant.start()
        return self

    def _check_main(self, what: str) -> None:
        ident = threading.get_ident()
        if self._assistant is not None and ident == self._assistant.ident:
            # Paper §VI-A: "The assistant thread cannot submit tasks, hence,
            # creating tasks recursively is not supported in Relic."
            raise RelicUsageError(f"{what} called from the assistant thread")
        if self._main_ident is not None and ident != self._main_ident:
            raise RelicUsageError(
                f"{what} must be called from the main (producer) thread"
            )

    # ------------------------------------------------------------- public API

    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> None:
        """Submit a fine-grained task (main thread only). Busy-waits if full."""
        self._check_main("submit()")
        if self._shutdown:
            raise RelicUsageError("submit() after shutdown")
        self.stats.submitted += 1
        task = _Task(fn, args, kwargs)
        spins = 0
        while not self._ring.push(task):
            # Producer-side busy wait: bounded ring is the backpressure.
            if spins == 0:
                # Hints are advisory (§VI-B): a full ring with a parked
                # assistant cannot drain, so submission un-parks it. Once
                # is enough — only this (blocked) thread could re-park it.
                self._awake.set()
            self.stats.producer_full_spins += 1
            spins += 1
            if spins % SPIN_PAUSE_EVERY == 0:
                time.sleep(0)  # the Python analogue of `pause`: yield, no park

    def wait(self) -> None:
        """Block (busy-wait) until every submitted task has completed."""
        self._check_main("wait()")
        target = self.stats.submitted
        if self._completed < target:
            # Advisory hints must not deadlock the barrier: outstanding
            # work with a parked assistant un-parks it (callers that want
            # the assistant parked re-issue sleep_hint() after waiting).
            self._awake.set()
        spins = 0
        while self._completed < target:
            spins += 1
            if spins % SPIN_PAUSE_EVERY == 0:
                time.sleep(0)
        self.stats.completed = self._completed
        if self.stats.last_error is not None:
            err, self.stats.last_error = self.stats.last_error, None
            raise err

    def wake_up_hint(self) -> None:
        """Developer hint: a parallelizable section is imminent (paper §VI-B)."""
        self._awake.set()

    def sleep_hint(self) -> None:
        """Developer hint: no tasks for a while; assistant may park."""
        self._awake.clear()

    def shutdown(self, timeout: float = 5.0) -> None:
        if self._assistant is None:
            return
        self._shutdown = True
        self._awake.set()  # release a parked assistant so it can observe shutdown
        self._assistant.join(timeout)
        self._assistant = None

    # ---------------------------------------------------------- assistant side

    def _assistant_loop(self) -> None:
        ring = self._ring
        stats = self.stats
        spins = 0
        while True:
            task = ring.pop()
            if task is None:
                if self._shutdown:
                    return
                if not self._awake.is_set():
                    # sleep_hint() was given: park on the event (OS suspension)
                    # instead of burning the core. wake_up_hint() releases us.
                    stats.parks += 1
                    self._awake.wait()
                    continue
                stats.assistant_empty_spins += 1
                spins += 1
                if spins % SPIN_PAUSE_EVERY == 0:
                    time.sleep(0)  # `pause`-like: yield the GIL, stay runnable
                continue
            spins = 0
            try:
                task.fn(*task.args, **task.kwargs)
            except BaseException as e:  # surfaced at the next wait()
                stats.task_errors += 1
                stats.last_error = e
            # Single atomic publication of completion (assistant-only writer).
            self._completed += 1

    # ------------------------------------------------------------- context mgr

    def __enter__(self) -> "Relic":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.shutdown()
