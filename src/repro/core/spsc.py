"""Bounded single-producer single-consumer ring queue (paper §VI-A).

The paper uses Boost.Lockfree's SPSC queue with capacity 128. This is the
CPython analogue: a preallocated ring with two monotonically increasing
counters. Only the producer writes ``_tail``; only the consumer writes
``_head``. Under CPython, aligned int stores/loads are atomic (protected by
the interpreter), so the fast path takes no lock — structurally identical to
the Lamport SPSC queue the paper builds on [61].

Two FastFlow-style optimizations (Aldinucci et al., 2009) keep the hot path
allocation- and contention-slim:

* **Cached indexes.** The producer keeps a private snapshot of the
  consumer's ``_head`` and refreshes it only when the ring *appears* full
  against the snapshot (symmetrically, the consumer's single-item ``pop``
  caches ``_tail`` and refreshes only on apparent-empty). On hardware this
  eliminates the cache-line ping-pong of reading the other side's counter
  every operation; under CPython it keeps the per-item path free of
  cross-thread reads, and the refresh-then-recheck makes push/pop exact
  whenever the snapshot goes stale — single-threaded callers observe
  identical semantics to the uncached ring.
* **Batch operations.** ``push_many``/``pop_many`` move a whole burst with
  a single counter publication, so the partner observes (and pays for) one
  update per burst instead of one per item. The batch ops refresh their
  snapshot whenever it cannot satisfy the request — for an unbounded
  ``pop_many`` that is every call — i.e. they pay one cross-thread read
  per *burst*, amortized over the items it moves, rather than relying on
  the stale snapshot (which would return partial drains).

The queue is intentionally *not* multi-producer safe: Relic forbids the
assistant thread from submitting tasks (no recursive spawn, paper §VI-A), so a
single producer is an invariant, not a limitation.
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence

DEFAULT_CAPACITY = 128  # paper: "We set a capacity of the queue to 128 entries."


class SpscRing:
    """Lamport-style bounded SPSC ring buffer with cached indexes.

    push/pop never block; they return False/None when full/empty so callers
    control their own waiting policy (busy-wait in Relic, paper §VI-B).
    """

    __slots__ = ("_buf", "_capacity", "_head", "_tail",
                 "_cached_head", "_cached_tail")

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self._capacity = capacity
        self._buf: list[Any] = [None] * capacity
        self._head = 0  # next slot to pop  (written by consumer only)
        self._tail = 0  # next slot to push (written by producer only)
        self._cached_head = 0  # producer's snapshot of _head
        self._cached_tail = 0  # consumer's snapshot of _tail

    @property
    def capacity(self) -> int:
        return self._capacity

    def __len__(self) -> int:
        # Racy but monotonic-safe estimate; exact when called from either end.
        # A third (observer) thread — e.g. RelicPool's least-loaded lane
        # picker — can read a fresh _head against a stale _tail and compute
        # a negative length; clamp so load signals and stats readers never
        # see one.
        d = self._tail - self._head
        return d if d > 0 else 0

    def empty(self) -> bool:
        return self._tail == self._head

    def full(self) -> bool:
        return self._tail - self._head >= self._capacity

    def free_slots(self) -> int:
        """Producer-side free-slot count: a *lower bound* that a subsequent
        ``push_many`` of at most this many items is guaranteed to satisfy
        in full. Reads ``_head`` directly (one cross-thread read — this is
        a slow-path planning call, not the cached hot path); a stale read
        only undercounts pops, so the bound never overpromises. RelicPool's
        re-striping uses it to size a window that must not partially push."""
        free = self._capacity - (self._tail - self._head)
        return free if free > 0 else 0

    def push(self, item: Any) -> bool:
        """Producer side. Returns False if the ring is full."""
        tail = self._tail
        if tail - self._cached_head >= self._capacity:
            # Apparently full against the snapshot: refresh once (the only
            # cross-thread read) and recheck. Exact after the refresh.
            self._cached_head = self._head
            if tail - self._cached_head >= self._capacity:
                return False
        self._buf[tail % self._capacity] = item
        # Publication: the tail increment makes the slot visible. In CPython
        # the GIL orders the buffer write before the counter write.
        self._tail = tail + 1
        return True

    def push2(self, a: Any, b: Any) -> bool:
        """Producer side: push two items with one ``_tail`` publication and
        no container allocation (the degenerate batch). Returns False —
        pushing neither — unless both fit. Relic's task protocol stripes
        ``fn, args`` pairs through this, so a task submit allocates nothing
        beyond what the call protocol already built."""
        tail = self._tail
        if tail + 2 - self._cached_head > self._capacity:
            self._cached_head = self._head
            if tail + 2 - self._cached_head > self._capacity:
                return False
        capacity = self._capacity
        buf = self._buf
        idx = tail % capacity
        buf[idx] = a
        idx += 1
        buf[idx if idx < capacity else 0] = b
        self._tail = tail + 2
        return True

    def push_many(self, items: Sequence[Any], start: int = 0,
                  stop: Optional[int] = None) -> int:
        """Producer side: push as many of ``items[start:stop]`` as fit, in
        order, with a single ``_tail`` publication. Returns the number
        pushed (0 when full). Callers loop on the remainder under their own
        wait policy — advancing ``start`` instead of slicing, so retrying a
        large burst against a full ring never copies the tail. ``stop``
        bounds the window without slicing either: RelicPool pushes each
        lane's shard of one shared flattened burst this way."""
        tail = self._tail
        capacity = self._capacity
        n = (len(items) if stop is None else stop) - start
        if n <= 0:
            return 0        # an exhausted/overshot offset must not move _tail
        free = capacity - (tail - self._cached_head)
        if free < n:
            self._cached_head = self._head
            free = capacity - (tail - self._cached_head)
            if free <= 0:
                return 0
            if free < n:
                n = free
        buf = self._buf
        for i in range(n):
            buf[(tail + i) % capacity] = items[start + i]
        self._tail = tail + n
        return n

    def pop(self) -> Optional[Any]:
        """Consumer side. Returns None if the ring is empty."""
        head = self._head
        if self._cached_tail == head:
            self._cached_tail = self._tail
            if self._cached_tail == head:
                return None
        idx = head % self._capacity
        item = self._buf[idx]
        self._buf[idx] = None  # drop reference early (keeps GC pressure flat)
        self._head = head + 1
        return item

    def pop_many(self, max_items: Optional[int] = None) -> List[Any]:
        """Consumer side: pop every available item (up to ``max_items``), in
        order, with a single ``_head`` publication. Returns a possibly-empty
        list — the burst the consumer drains before re-checking hints."""
        if max_items is not None and max_items <= 0:
            return []       # a non-positive budget must not rewind _head
        head = self._head
        avail = self._cached_tail - head
        if max_items is None or avail < max_items:
            # The snapshot cannot satisfy the request: refresh (the one
            # cross-thread read this burst pays) and recheck — so a
            # same-thread caller always sees every published item.
            self._cached_tail = self._tail
            avail = self._cached_tail - head
            if avail <= 0:
                return []
        if max_items is not None and avail > max_items:
            avail = max_items
        buf = self._buf
        capacity = self._capacity
        out = [None] * avail
        for i in range(avail):
            idx = (head + i) % capacity
            out[i] = buf[idx]
            buf[idx] = None
        self._head = head + avail
        return out
