"""Bounded single-producer single-consumer ring queue (paper §VI-A).

The paper uses Boost.Lockfree's SPSC queue with capacity 128. This is the
CPython analogue: a preallocated ring with two monotonically increasing
counters. Only the producer writes ``_tail``; only the consumer writes
``_head``. Under CPython, aligned int stores/loads are atomic (protected by
the interpreter), so the fast path takes no lock — structurally identical to
the Lamport SPSC queue the paper builds on [61].

The queue is intentionally *not* multi-producer safe: Relic forbids the
assistant thread from submitting tasks (no recursive spawn, paper §VI-A), so a
single producer is an invariant, not a limitation.
"""

from __future__ import annotations

from typing import Any, Optional

DEFAULT_CAPACITY = 128  # paper: "We set a capacity of the queue to 128 entries."


class SpscRing:
    """Lamport-style bounded SPSC ring buffer.

    push/pop never block; they return False/None when full/empty so callers
    control their own waiting policy (busy-wait in Relic, paper §VI-B).
    """

    __slots__ = ("_buf", "_capacity", "_head", "_tail")

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self._capacity = capacity
        self._buf: list[Any] = [None] * capacity
        self._head = 0  # next slot to pop  (written by consumer only)
        self._tail = 0  # next slot to push (written by producer only)

    @property
    def capacity(self) -> int:
        return self._capacity

    def __len__(self) -> int:
        # Racy but monotonic-safe estimate; exact when called from either end.
        return self._tail - self._head

    def empty(self) -> bool:
        return self._tail == self._head

    def full(self) -> bool:
        return self._tail - self._head >= self._capacity

    def push(self, item: Any) -> bool:
        """Producer side. Returns False if the ring is full."""
        tail = self._tail
        if tail - self._head >= self._capacity:
            return False
        self._buf[tail % self._capacity] = item
        # Publication: the tail increment makes the slot visible. In CPython
        # the GIL orders the buffer write before the counter write.
        self._tail = tail + 1
        return True

    def pop(self) -> Optional[Any]:
        """Consumer side. Returns None if the ring is empty."""
        head = self._head
        if self._tail == head:
            return None
        idx = head % self._capacity
        item = self._buf[idx]
        self._buf[idx] = None  # drop reference early (keeps GC pressure flat)
        self._head = head + 1
        return item
