"""RelicPool: the paper's SMT pair scaled to N lanes (one producer, N assistants).

The paper's Relic is deliberately a *two*-thread runtime — one producer and
one assistant on SMT sibling contexts, joined by a single bounded SPSC ring
(§VI). This module is the repo's first step past that ceiling, following
the FastFlow construction (Aldinucci et al., 2009): lock-free SPSC queues
*compose* into larger networks without giving up the single-producer /
single-consumer fast path. A ``RelicPool`` is N independent **lanes**, each
a full :class:`repro.core.relic.Relic` (its own ``SpscRing`` + assistant
thread + hints + stats), so every lane preserves the exact SPSC invariants
and cached-index/batch fast paths of the pair — no MPMC queue anywhere, no
lock on the submit path.

What the pool adds on top of the lanes:

* **Lane-striped submission.** ``submit()`` round-robins a cursor over the
  lanes; when the target lane's ring is full it tries the other lanes,
  least-loaded first (by the ring's racy-but-monotonic ``len()`` — a
  stale read costs balance, never correctness), and busy-waits *sweeping
  all lanes* only while every ring is full — so a lane wedged behind a
  long task can never block a submission another lane has room for
  (bounded backpressure engages pool-wide, not per-lane).
  ``submit_batch()`` flattens the burst once and deals contiguous shards
  across the lanes — each lane ``push_many``-ing its window of the
  *shared* flattened list (no per-lane slicing) — in two phases: a
  non-blocking pass hands every lane what its ring has room for, then
  the remainders are swept round-robin, so here too a wedged lane never
  starves the shards the other lanes already have room to run.
* **Skew resistance (dynamic load balancing, PR 6).** Static striping
  pins a task to its lane forever — exactly where irregular (power-law
  cost) workloads bleed speedup when one lane wedges behind a long task.
  With ``rebalance=True`` (the default for multi-lane pools) two
  mechanisms fix that without touching any hot path or SPSC invariant:
  (1) *re-striping* — a burst remainder the sweep cannot place in its
  own lane is re-dealt, producer-side, to lanes with room; (2) a
  *victim-cooperative handoff ring* per lane — a second bounded SPSC
  ring the producer fills only when primaries are backed up and the
  lane's assistant drains only when its primary is idle. Every ring
  stays strictly one-producer/one-consumer (the pool's single producer
  pushes, that lane's single assistant pops); there is still no MPMC
  structure and no lock anywhere. ``rebalance=False`` reproduces the
  static PR 5 pool bit-for-bit.
* **Lane supervision & graceful degradation (PR 8).** With
  ``RELIC_SUPERVISE`` on (the default) every producer slow path is a
  *bounded* wait: the spin loops periodically probe assistant liveness,
  so a lane whose thread died is **quarantined** — pulled out of
  striping, its in-flight tasks deterministically accounted as lost
  (:class:`LaneFailure`), the event surfaced at ``wait()`` as
  :class:`LaneFailedError` — instead of hanging the producer forever.
  ``respawn=True`` additionally rebuilds the slot with a fresh lane
  (fresh rings, fresh thread), amending the pair's non-restartable
  contract at pool scope only. A ``LaneSupervisor`` fed from the lanes'
  completion counters flags stalled and straggling lanes as advisory
  telemetry (``stalled_lanes()`` / ``straggler_lanes()``).
* **Broadcast hints.** ``sleep_hint()`` / ``wake_up_hint()`` fan out to
  every lane (paper §VI-B, now meaning "park/unpark the whole pool").
* **Aggregated stats.** ``stats`` is a live view summing the per-lane
  ``RelicStats`` counters; ``stats.lanes`` exposes the per-lane detail
  (striping tests and benchmarks read it).
* **First-error-wins across lanes.** Each lane already keeps its *own*
  first error plus the submission index it happened at; ``wait()`` barriers
  every lane, maps those lane-local indexes back to the pool-global
  submission order (a per-window seq log the producer appends to), and
  re-raises the error of the **earliest-submitted** failed task — the SPI
  contract, extended across lanes. Later failures only bump
  ``task_errors``, exactly as in the pair.

The pair's usage rules apply unchanged: submission and waiting are
main-thread-only, assistants cannot submit (no recursive spawn, §VI-A),
and hints are advisory (they may never deadlock a barrier or a full-ring
submit). A ``lanes=1`` pool is semantically the pair with striping
bookkeeping on top — the ``scaling`` benchmark section records what that
bookkeeping costs (it must stay within a few percent of raw Relic).

Ordering caveat: the pool preserves FIFO *per lane*, not globally — two
tasks striped onto different lanes may complete in either order. Callers
needing global FIFO use a single-lane runtime (``workers <= 1`` on the
scheduler SPI).
"""

from __future__ import annotations

import functools
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Iterable, List, Optional, Tuple

from repro.core.relic import (_PROBE_EVERY_SPINS, Relic, RelicDeadError,
                              RelicStats, RelicUsageError, flatten_tasks)
from repro.core.spsc import DEFAULT_CAPACITY
from repro.runtime.config import resolve_supervise_config
from repro.runtime.fault import LaneSupervisor

__all__ = ["LaneFailedError", "LaneFailure", "RelicPool", "RelicPoolStats"]


@dataclass(frozen=True)
class LaneFailure:
    """One quarantined lane: the deterministic accounting of a lane death.

    ``lost`` is exactly the dead ring's in-flight count (``submitted`` minus
    the final ``completed`` — final because the only writer of the
    completion counter is the dead thread), covering both the primary and
    the handoff ring. ``error`` carries the lane's pending first task error
    if one was recorded before death; ``respawned`` says whether a fresh
    lane took the slot (``RelicPool(respawn=True)``).
    """

    lane_index: int
    lane_name: str
    submitted: int
    completed: int
    lost: int
    error: Optional[BaseException]
    respawned: bool


class LaneFailedError(RelicDeadError):
    """One or more pool lanes died; surfaced deterministically at
    ``wait()`` (and by submit paths that can no longer make progress).

    Subclasses :class:`RelicDeadError` so ``except RelicDeadError`` covers
    both the pair and the pool; ``failures`` holds the per-lane
    :class:`LaneFailure` records, and the aggregate ``submitted`` /
    ``completed`` / ``lost`` fields sum them. ``first_task_error`` carries
    the window's earliest-submitted pending task error, if any — the lane
    failure outranks it on the error channel, but it stays observable.
    """

    def __init__(self, failures: Tuple[LaneFailure, ...],
                 first_task_error: Optional[BaseException] = None) -> None:
        self.failures = tuple(failures)
        self.lane = ", ".join(f.lane_name for f in self.failures)
        self.submitted = sum(f.submitted for f in self.failures)
        self.completed = sum(f.completed for f in self.failures)
        self.lost = sum(f.lost for f in self.failures)
        self.first_task_error = first_task_error
        detail = "; ".join(
            f"{f.lane_name}: lost={f.lost}"
            + (" (respawned)" if f.respawned else "")
            for f in self.failures)
        RuntimeError.__init__(
            self,
            f"pool lane(s) died [{detail}]: {self.lost} in-flight task(s) "
            "lost")


class RelicPoolStats:
    """Live aggregate view over the per-lane :class:`RelicStats`.

    Duck-compatible with ``SchedulerStats`` (``submitted``/``completed``/
    ``task_errors``/``last_error``) plus the Relic telemetry counters, all
    computed on read by summing the lanes — there is no second set of hot
    counters to keep coherent on the submit path. ``lanes`` exposes the
    underlying per-lane stats objects.
    """

    __slots__ = ("_pool",)

    def __init__(self, pool: "RelicPool"):
        self._pool = pool

    def _sum(self, attr: str) -> int:
        # _retired folds in the final counters of lanes replaced by a
        # respawn, keeping every aggregate monotonic across lane swaps.
        return (getattr(self._pool._retired, attr)
                + sum(getattr(lane.stats, attr)
                      for lane in self._pool._lanes))

    @property
    def submitted(self) -> int:
        return self._sum("submitted")

    @property
    def completed(self) -> int:
        return self._sum("completed")

    @property
    def task_errors(self) -> int:
        return self._sum("task_errors")

    @property
    def producer_full_spins(self) -> int:
        return self._sum("producer_full_spins")

    @property
    def assistant_empty_spins(self) -> int:
        return self._sum("assistant_empty_spins")

    @property
    def parks(self) -> int:
        return self._sum("parks")

    @property
    def last_error(self) -> Optional[BaseException]:
        """The stashed error (a ``close()``-time capture) if any, else the
        earliest-submitted pending lane error (the one ``wait()`` would
        raise). Observability only — reading it clears nothing."""
        if self._pool._stashed_error is not None:
            return self._pool._stashed_error
        best: Tuple[int, Optional[BaseException]] = (0, None)
        for i, lane in enumerate(self._pool._lanes):
            err = lane.stats.last_error
            if err is None:
                continue
            seq = self._pool._pending_error_seq(i, lane.stats)
            if best[1] is None or seq < best[0]:
                best = (seq, err)
        return best[1]

    @last_error.setter
    def last_error(self, value: Optional[BaseException]) -> None:
        # SchedulerStats duck-compat: the pool adapter stashes a close()-time
        # error here so it stays observable after shutdown.
        self._pool._stashed_error = value

    @property
    def lost_tasks(self) -> int:
        """Tasks deterministically written off to dead lanes (the sum of
        every :class:`LaneFailure`'s ``lost``)."""
        return self._pool._lost_tasks

    @property
    def lanes(self) -> Tuple[RelicStats, ...]:
        return tuple(lane.stats for lane in self._pool._lanes)

    def __repr__(self) -> str:
        return (f"RelicPoolStats(lanes={len(self._pool._lanes)}, "
                f"submitted={self.submitted}, completed={self.completed}, "
                f"task_errors={self.task_errors})")


class RelicPool:
    """N-lane Relic: one producer striping over N independent SPSC pairs.

    Usage mirrors :class:`Relic` exactly::

        pool = RelicPool(lanes=4)
        pool.start()
        pool.wake_up_hint()          # broadcast: a parallel section is imminent
        pool.submit(fn, a, b)        # main thread only; striped over the lanes
        ...                          # main thread does its own share
        pool.wait()                  # barrier across every lane
        pool.sleep_hint()            # broadcast park
        pool.shutdown()
    """

    def __init__(self, lanes: int = 2, capacity: int = DEFAULT_CAPACITY,
                 start_awake: bool = False, rebalance: bool = True,
                 respawn: bool = False, supervise: Optional[bool] = None,
                 heartbeat_ms: Optional[float] = None):
        if lanes <= 0:
            raise ValueError(f"lanes must be positive, got {lanes}")
        self._n = lanes
        self._capacity = capacity
        self._start_awake = start_awake
        # Graceful degradation (PR 8): a lane whose assistant thread died is
        # *quarantined* — removed from striping, its in-flight tasks
        # accounted as lost (see _quarantine_lane), the event surfaced at
        # the next wait() as LaneFailedError. With ``respawn=True`` a fresh
        # Relic takes the dead lane's slot so the pool keeps its width; the
        # pair's non-restartable contract is amended at *pool scope only* —
        # an individual Relic still never restarts, the pool replaces it.
        self._respawn = bool(respawn)
        # kwargs > RELIC_SUPERVISE / RELIC_HEARTBEAT_MS env > defaults.
        sup_cfg = resolve_supervise_config(supervise=supervise,
                                           heartbeat_ms=heartbeat_ms)
        self._supervise = sup_cfg.supervise
        self._heartbeat_s = sup_cfg.heartbeat_ms / 1000.0
        # Skew resistance (PR 6): with ``rebalance`` on, a burst remainder
        # stuck behind a wedged lane is re-dealt to lanes with room
        # (producer-side re-striping — see _rebalance_pending) and each
        # lane grows a victim-cooperative handoff ring its assistant
        # drains when idle. Off reproduces the PR 5 static striping
        # exactly. A single-lane pool has nowhere to re-deal to, so it
        # never pays for any of it (the degenerate pair path below).
        self._rebalance = bool(rebalance) and lanes > 1
        self._lanes = [
            Relic(capacity=capacity, start_awake=start_awake,
                  name=f"relic-pool-lane{i}", handoff=self._rebalance)
            for i in range(lanes)
        ]
        # The lanes' own bounded-wait probes follow the *pool's* resolved
        # supervision setting (a kwarg must be able to override the env the
        # lane constructors just read).
        for lane in self._lanes:
            lane._probe_every = _PROBE_EVERY_SPINS if self._supervise else 0
        self._rr = 0                 # round-robin cursor (next lane to try)
        self._seq = 0                # pool-global submission counter
        # Per-window submission log: _runs[i][k] is the global seq of lane
        # i's (base[i]+k)-th task. Appended by the producer per submission,
        # cleared at every wait() — it exists so first-error-wins can be
        # ordered by *submission order* across lanes, and it is the whole
        # per-task cost of pooling beyond the lane push itself. Between
        # waits it is kept bounded by trimming entries for already-
        # completed tasks (see _trim_runs), so a long-lived scope that
        # never barriers (pipeline-style fire-and-observe-by-handle use)
        # holds O(capacity) ints per lane, not one per task ever submitted.
        self._runs: List[List[int]] = [[] for _ in range(lanes)]
        self._base = [0] * lanes     # lane-local index of _runs[i][0]
        self._trim_at = 4 * capacity  # in-flight bound is 2*capacity, so at
        #                               this length at least half is trimmable
        # Handoff-ring twin of the seq log: _oruns[i][k] is the global seq
        # of the (obase[i]+k)-th task the producer pushed into lane i's
        # handoff ring. Same trim discipline, keyed off the lane's
        # handoff-completion counter — so first-error-wins ordering
        # survives re-striping (the seq rides whichever log matches the
        # ring that carried the task).
        self._oruns: List[List[int]] = [[] for _ in range(lanes)]
        self._obase = [0] * lanes
        self._stashed_error: Optional[BaseException] = None
        self._shutdown = False
        self._started = False
        self._main_ident: Optional[int] = None
        # Lane-supervision state: ``_live`` is the ordered list of lane
        # indexes still accepting submissions (striping runs over it, not
        # over range(n)); quarantine removes a slot, respawn re-adds it
        # with a fresh lane. ``_retired`` accumulates the final counters of
        # replaced lanes so the aggregate stats view stays monotonic across
        # a swap, and ``_lost_tasks`` is the cumulative deterministic
        # lost-task count (see LaneFailure).
        self._live: List[int] = list(range(lanes))
        self._failures: List[LaneFailure] = []
        self._failure_history: List[LaneFailure] = []  # never cleared
        self._lost_tasks = 0
        self._retired = RelicStats()
        self._gen = [0] * lanes      # respawn generation per slot (naming)
        self._supervisor = (
            LaneSupervisor(lanes, heartbeat_s=self._heartbeat_s)
            if self._supervise else None)
        # Hot-path pre-binds: one tuple load per submit instead of chasing
        # lane -> ring / lane -> stats chains per task. Rebuilt whenever
        # the live-lane set changes.
        self._hot: List[tuple] = []
        self._nl = lanes             # len(_live): the striping modulus
        self._rebuild_hot()
        if lanes == 1 and not self._respawn:
            # Degenerate pool == the pair, exactly: with one lane the
            # cursor never moves, every shard is the whole burst, and
            # cross-lane error ordering is the lane's own — so the
            # single-lane configuration pays for none of that bookkeeping
            # ("scaling must not tax the pair", measured by the scaling
            # benchmark's lanes1-vs-relic rows). With respawn on the slot
            # can be re-bound to a fresh lane, so the general striped path
            # (which reads ``_hot`` per call) is used instead.
            self._lane0 = self._lanes[0]
            self._push2_0 = self._lane0._push2
            self._stats0 = self._lane0.stats
            self._submit2 = self._submit2_single
        self.stats = RelicPoolStats(self)

    @property
    def n_lanes(self) -> int:
        return self._n

    # ------------------------------------------------------------------ roles

    def start(self) -> "RelicPool":
        if self._started:
            raise RelicUsageError("RelicPool already started")
        self._started = True
        self._main_ident = threading.get_ident()
        for lane in self._lanes:
            lane.start()
        return self

    def _check_main(self, what: str) -> None:
        ident = threading.get_ident()
        for lane in self._lanes:
            if lane._assistant is not None and ident == lane._assistant.ident:
                # Same rule as the pair (§VI-A): assistants cannot submit.
                raise RelicUsageError(f"{what} called from an assistant thread")
        if self._main_ident is not None and ident != self._main_ident:
            raise RelicUsageError(
                f"{what} must be called from the main (producer) thread")

    # ------------------------------------------------------------- public API

    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> None:
        """Submit one task (main thread only), striped round-robin over the
        lanes with a least-loaded fallback. Busy-waits only when the
        fallback lane is full too (bounded backpressure)."""
        if threading.get_ident() != self._main_ident:
            self._check_main("submit()")   # slow path: classify the misuse
        if self._shutdown:
            raise RelicUsageError("submit() after shutdown")
        if kwargs:
            fn = functools.partial(fn, **kwargs)
        self._submit2(fn, args)

    def _submit2_single(self, fn: Callable[..., Any], args: tuple) -> None:
        """No-checks push for the lanes=1 degenerate pool (bound over
        ``_submit2`` at construction): the pair's own submit, nothing more.
        Accounts after the push like the pair (interrupt safety)."""
        if self._push2_0(fn, args):
            self._stats0.submitted += 1
            return
        self._lane0._push_spin(fn, args)
        self._stats0.submitted += 1

    def _submit2(self, fn: Callable[..., Any], args: tuple) -> None:
        """No-checks striped push (the scheduler adapter's fast path).
        Stripes over the *live* lanes (``_hot`` mirrors ``_live``)."""
        i = self._rr
        nxt = i + 1
        self._rr = nxt if nxt < self._nl else 0
        push2, lane_stats, runs, li = self._hot[i]
        if push2(fn, args):
            seq = self._seq
            self._seq = seq + 1
            lane_stats.submitted += 1
            runs.append(seq)
            if len(runs) >= self._trim_at:
                self._trim_runs(li)
            return
        self._submit_overflow(fn, args)

    def _submit2_dead(self, fn: Callable[..., Any], args: tuple) -> None:
        """Bound over ``_submit2`` once every lane is quarantined with
        respawn off: the pool can never run another task, so submitting
        raises instead of silently feeding a dead ring. (Pre-bound
        references — the scheduler adapter binds ``_submit2`` once — are
        covered by the sentinel hot entry ``_rebuild_hot`` installs, whose
        "push" raises the same way.)"""
        self._raise_pool_dead()

    def _submit_overflow(self, fn: Callable[..., Any], args: tuple) -> None:
        """Round-robin target full: try the other live lanes least-loaded
        first (by the ring's racy-but-monotonic ``len()`` — reading
        another lane's ring from here is the observer case its clamp
        exists for; a stale read costs balance, never correctness) and
        busy-wait *sweeping* until some lane accepts. Sweeping — rather
        than committing to one fallback lane — keeps the pool live when a
        lane is wedged behind a long task: backpressure engages only while
        every ring is full. With rebalancing on, "every ring" includes the
        handoff rings: a pool whose primaries are all backed up hands the
        task to the least-loaded lane's handoff ring (its assistant pulls
        from it when its primary goes idle) before resigning to the spin.

        The spin is *bounded* (PR 8): every ``_PROBE_EVERY_SPINS``
        no-progress rounds it sweeps lane liveness (``check_lanes``), so a
        pool spinning on rings whose assistants died quarantines them —
        respawn refills the slot with an empty ring the next round, and a
        fully-dead pool raises ``LaneFailedError`` instead of hanging."""
        lanes = self._lanes
        rebalance = self._rebalance
        supervise = self._supervise
        spins = 0
        pause_every = lanes[0]._spin_pause_every
        while True:
            live = self._live
            if not live:
                self._raise_pool_dead()
            order = sorted(live, key=lambda j: len(lanes[j]._ring))
            for j in order:
                lane = lanes[j]
                if lane._push2(fn, args):
                    seq = self._seq
                    self._seq = seq + 1
                    lane.stats.submitted += 1
                    runs = self._runs[j]
                    runs.append(seq)
                    if len(runs) >= self._trim_at:
                        self._trim_runs(j)
                    return
            if rebalance:
                for j in order:
                    lane = lanes[j]
                    if lane._oring.push2(fn, args):
                        seq = self._seq
                        self._seq = seq + 1
                        lane.stats.submitted += 1
                        oruns = self._oruns[j]
                        oruns.append(seq)
                        if len(oruns) >= self._trim_at:
                            self._trim_oruns(j)
                        return
            if spins == 0:
                # Advisory hints must not deadlock a full pool: un-park
                # every assistant once (only this blocked thread could
                # re-park them).
                for lane in lanes:
                    lane._awake.set()
            lanes[order[0]].stats.producer_full_spins += 1
            spins += 1
            if spins % pause_every == 0:
                time.sleep(0)
            if supervise and spins % _PROBE_EVERY_SPINS == 0:
                self.check_lanes()

    def submit_batch(
        self, tasks: Iterable[Tuple[Callable[..., Any], tuple, dict]]
    ) -> None:
        """Submit a burst of ``(fn, args, kwargs)`` tasks (main thread
        only), sharded across the lanes: the burst is flattened once into
        the ``fn, args`` stripe and split into contiguous near-equal
        shards dealt out from the round-robin cursor. Delivery is
        two-phase so a wedged lane cannot starve the others' shards: a
        first non-blocking pass hands every lane as much of its shard as
        its ring has room for (one ``push_many`` per lane), then the
        remainders are busy-wait *swept* round-robin under ring
        backpressure — every other lane's work is already flowing while
        the producer waits on a full one, and a cross-shard dependency
        (a lane-0 task blocking on a handle from lane 1's shard) can
        always make progress. With rebalancing on, a remainder the sweep
        cannot place at all is *re-striped* to lanes that do have room
        (see ``_rebalance_pending``) instead of waiting out its original
        lane.

        Accounting (``submitted``, the seq logs) is committed as each
        window is handed to a ring, never before: a ``BaseException``
        (KeyboardInterrupt) escaping the sweep therefore cannot strand
        ``submitted`` above what any assistant will ever pop — the
        pre-PR 6 failure mode where the next ``wait()`` busy-spun
        forever. The unaccounted residue of an interrupt is at most the
        tasks of one in-flight ``push_many`` window, which can only make
        a later barrier return *early*, never hang."""
        if threading.get_ident() != self._main_ident:
            self._check_main("submit_batch()")
        if self._shutdown:
            raise RelicUsageError("submit_batch() after shutdown")
        flat = flatten_tasks(tasks)
        k = len(flat) // 2
        if not k:
            return
        if self._n == 1 and not self._respawn:
            # Degenerate pool: the whole burst is lane 0's shard, and the
            # seq log is pointless with nothing to order across. (The
            # push raises RelicDeadError — bounded, never a hang — if the
            # assistant died mid-burst; with respawn off there is no slot
            # to rebuild, so it propagates as-is.)
            self._lanes[0]._push_flat(flat, account=True)
            return
        live = self._live
        n = len(live)
        if n == 0:
            self._raise_pool_dead()
        share, rem = divmod(k, n)
        seq0 = self._seq
        self._seq = seq0 + k
        cursor = self._rr
        if cursor >= n:
            cursor = 0
        pos = 0                       # task offset into the burst
        pending: List[list] = []      # [lane_idx, next_slot, stop_slot]
        for step in range(n):
            take = share + (1 if step < rem else 0)
            if take == 0:
                break                 # k < n: only the first k lanes get one
            s = cursor + step
            if s >= n:
                s -= n
            i = live[s]
            lane = self._lanes[i]
            start2, stop2 = 2 * pos, 2 * (pos + take)
            pushed = lane._ring.push_many(flat, start2, stop2)
            if pushed:
                self._account_window(i, lane, seq0 + pos, pushed // 2)
            if start2 + pushed < stop2:
                pending.append([i, start2 + pushed, stop2])
            pos += take
        # Advance the cursor by the burst remainder so the next burst's
        # +1 shards (and the next single submit) land on fresh lanes.
        self._rr = (cursor + rem) % n
        if pending:
            self._sweep_remainders(flat, pending, seq0)

    def _account_window(self, i: int, lane: Relic, seq_start: int,
                        p: int) -> None:
        """Record ``p`` tasks just pushed into lane ``i``'s *primary* ring,
        holding seqs ``seq_start..seq_start+p-1``. Called immediately after
        the push (never before — interrupt safety, see submit_batch)."""
        lane.stats.submitted += p
        runs = self._runs[i]
        runs.extend(range(seq_start, seq_start + p))
        if len(runs) >= self._trim_at:
            self._trim_runs(i)

    def _account_handoff_window(self, i: int, lane: Relic, seq_start: int,
                                p: int) -> None:
        """Same as ``_account_window`` for lane ``i``'s *handoff* ring."""
        lane.stats.submitted += p
        oruns = self._oruns[i]
        oruns.extend(range(seq_start, seq_start + p))
        if len(oruns) >= self._trim_at:
            self._trim_oruns(i)

    def _sweep_remainders(self, flat: list, pending: List[list],
                          seq0: int) -> None:
        """Phase 2 of a burst: drain shard remainders into their lanes,
        sweeping all of them each iteration (never committing to one full
        lane) and yielding under full-pool backpressure. Partial pushes
        are always pair-aligned: every publication is even-sized, so the
        free-slot count every ``push_many`` sees is even by induction.
        When a whole sweep makes no progress and rebalancing is on, the
        stuck remainders are re-striped to lanes with room before the
        producer resigns itself to spinning.

        Like ``_submit_overflow`` the spin is bounded (PR 8): a periodic
        liveness sweep quarantines dead lanes mid-burst. A respawned slot
        offers the remainder a fresh empty ring; with rebalancing on the
        remainder re-stripes to the survivors; with *both* off a dead
        slot's remainder can never drain, so the sweep raises
        ``LaneFailedError`` (the un-pushed remainder stays unaccounted —
        the same interrupt-safety contract as a KeyboardInterrupt here)."""
        lanes = self._lanes
        rebalance = self._rebalance
        supervise = self._supervise
        spins = 0
        pause_every = lanes[0]._spin_pause_every
        while pending:
            progressed = False
            for entry in list(pending):
                i, next2, stop2 = entry
                lane = lanes[i]
                pushed = lane._ring.push_many(flat, next2, stop2)
                if pushed:
                    progressed = True
                    self._account_window(i, lane, seq0 + next2 // 2,
                                         pushed // 2)
                    next2 += pushed
                    if next2 >= stop2:
                        pending.remove(entry)
                    else:
                        entry[1] = next2
            if not pending:
                return
            if not progressed:
                if rebalance and self._rebalance_pending(flat, pending, seq0):
                    continue
                if spins == 0:
                    # Advisory hints must not deadlock a burst: a parked
                    # assistant is a stalled lane's only possible drain.
                    for i, _, _ in pending:
                        lanes[i]._awake.set()
                lanes[pending[0][0]].stats.producer_full_spins += 1
                spins += 1
                if spins % pause_every == 0:
                    time.sleep(0)
                if supervise and spins % _PROBE_EVERY_SPINS == 0 \
                        and self.check_lanes():
                    if not self._respawn and not rebalance and any(
                            e[0] not in self._live for e in pending):
                        raise LaneFailedError(tuple(self._failures))

    def _rebalance_pending(self, flat: list, pending: List[list],
                           seq0: int) -> bool:
        """Re-stripe stuck remainders (producer-side dynamic load
        balancing). For each remainder whose own lane has no room, move a
        head window to another lane: first into primary rings with free
        slots, then — when every primary is full — into handoff rings.
        Returns True when any task moved (the sweep then retries instead
        of spinning).

        Every push here remains strictly single-producer (this thread is
        the only pusher of every primary *and* handoff ring) and sized by
        ``SpscRing.free_slots()``, a producer-side lower bound — so a
        window never partially pushes and accounting can follow each push
        exactly. Lanes that themselves have a stuck remainder are skipped
        as destinations: their rings are full by definition, and skipping
        them keeps this pass O(lanes) per remainder."""
        lanes = self._lanes
        stuck = {entry[0] for entry in pending}
        order = sorted((j for j in self._live if j not in stuck),
                       key=lambda j: len(lanes[j]._ring))
        moved = False
        for entry in list(pending):
            i, next2, stop2 = entry
            for j in order:
                want = (stop2 - next2) // 2
                if want <= 0:
                    break
                lane = lanes[j]
                room = lane._ring.free_slots() // 2
                if room > 0:
                    m = min(want, room)
                    pushed = lane._ring.push_many(flat, next2, next2 + 2 * m)
                    self._account_window(j, lane, seq0 + next2 // 2,
                                         pushed // 2)
                    next2 += pushed
                    entry[1] = next2
                    moved = True
                    continue
                oring = lane._oring
                if oring is None:
                    continue
                room = oring.free_slots() // 2
                if room <= 0:
                    continue
                m = min(want, room)
                pushed = oring.push_many(flat, next2, next2 + 2 * m)
                self._account_handoff_window(j, lane, seq0 + next2 // 2,
                                             pushed // 2)
                next2 += pushed
                entry[1] = next2
                moved = True
            if next2 >= stop2:
                pending.remove(entry)
        return moved

    def wait(self) -> None:
        """Barrier across every lane; first-error-wins by submission order.

        Each lane is barriered (its spin loop, no raise), its pending
        first error — if any — is mapped to the pool-global submission
        seq *while the error state is still set* (the seq logs need the
        index fields), and only then consumed via ``_take_error`` (which
        clears the error and its index fields as one unit — the PR 6
        stale-index bugfix). The earliest-submitted error re-raises; all
        other errors from this window are dropped from the error channel
        (they remain counted in ``stats.task_errors``) — the same
        later-failures-only-bump rule the pair applies within one lane.

        Lane deaths outrank task errors (PR 8): a barrier that finds a
        dead assistant (its bounded-wait probe raises ``RelicDeadError``)
        quarantines the lane — respawning into the slot when enabled —
        and ``wait()`` raises :class:`LaneFailedError` carrying every
        queued :class:`LaneFailure` (including ones detected earlier by
        ``check_lanes`` or a submit path). The window's earliest pending
        *task* error, if any, rides along as ``first_task_error``."""
        self._check_main("wait()")
        errors: List[Tuple[int, BaseException]] = []
        for i in range(self._n):
            if i not in self._live:
                continue    # quarantined: frozen, nothing will ever drain it
            lane = self._lanes[i]
            try:
                lane._barrier()
            except RelicDeadError:
                self._quarantine_lane(i, lane)
                continue
            if lane.stats.last_error is not None:
                seq = self._pending_error_seq(i, lane.stats)
                err = lane._take_error()
                if err is not None:
                    errors.append((seq, err))
        for i in range(self._n):
            # base + len(runs) == tasks ever pushed to that ring: the next
            # window's local indexes continue from there. (Not the lane's
            # ``submitted`` — with rebalancing that counter spans both
            # rings, while each log is per-ring.)
            self._base[i] += len(self._runs[i])
            self._runs[i].clear()
            self._obase[i] += len(self._oruns[i])
            self._oruns[i].clear()
        errors.sort(key=lambda pair: pair[0])
        if self._failures:
            failures = tuple(self._failures)
            self._failures.clear()
            raise LaneFailedError(
                failures,
                first_task_error=errors[0][1] if errors else None)
        if not self._live:
            # Permanently dead pool (every lane quarantined, respawn off):
            # each wait() keeps raising — a silent return here would let
            # post-death submissions into dead rings pass as "completed".
            raise LaneFailedError(
                tuple(self._failure_history),
                first_task_error=errors[0][1] if errors else None)
        if errors:
            raise errors[0][1]

    # ------------------------------------------------- lane supervision (PR 8)

    def _rebuild_hot(self) -> None:
        """Regenerate the submit pre-binds from the live-lane set (called
        at construction and after every quarantine/respawn)."""
        self._hot = [
            (self._lanes[i]._push2, self._lanes[i].stats, self._runs[i], i)
            for i in self._live
        ]
        self._nl = len(self._hot)
        if self._rr >= self._nl:
            self._rr = 0
        if self._nl == 0:
            # Every lane dead, respawn off: fail fast on the submit path.
            # The sentinel hot entry keeps *pre-bound* callers (the
            # scheduler adapter binds the class ``_submit2`` once) raising
            # too: its "push" is the raise itself.
            self._submit2 = self._submit2_dead
            self._hot = [(self._raise_pool_dead_push, None, [], -1)]
            self._nl = 1

    def _raise_pool_dead_push(self, fn: Callable[..., Any],
                              args: tuple) -> bool:
        self._raise_pool_dead()
        return False               # pragma: no cover - unreachable

    def _raise_pool_dead(self) -> None:
        raise LaneFailedError(tuple(self._failures or self._failure_history))

    def _quarantine_lane(self, li: int, dead: Relic) -> LaneFailure:
        """Remove a dead lane from striping (pool-owner thread only),
        account its in-flight tasks as lost, and — with ``respawn=True`` —
        put a fresh lane in the slot.

        The lost count is final arithmetic, not an estimate: the
        completion counter's only writer is the dead thread, so
        ``submitted - completed`` is exactly the tasks stranded across the
        lane's primary and handoff rings. SPSC invariants survive by
        construction — nothing ever pops a quarantined ring again (its
        single consumer is the dead thread), and a respawned slot gets a
        brand-new :class:`Relic` with fresh rings, so every ring keeps
        exactly one producer and one consumer for its whole lifetime."""
        self._live.remove(li)
        submitted = dead.stats.submitted
        completed = dead._completed
        dead.stats.completed = completed  # final snapshot for the stats view
        lost = submitted - completed
        self._lost_tasks += lost
        failure = LaneFailure(
            lane_index=li, lane_name=dead._name, submitted=submitted,
            completed=completed, lost=lost, error=dead.stats.last_error,
            respawned=self._respawn)
        self._failures.append(failure)
        self._failure_history.append(failure)
        if self._respawn:
            # Retire the dead lane's final counters into the aggregate so
            # the pool stats stay monotonic across the swap, then rebuild
            # the slot: fresh Relic (fresh rings), reset seq logs, reset
            # the supervisor's memory of the slot.
            r, s = self._retired, dead.stats
            r.submitted += submitted
            r.completed += completed
            r.task_errors += s.task_errors
            r.producer_full_spins += s.producer_full_spins
            r.assistant_empty_spins += s.assistant_empty_spins
            r.parks += s.parks
            self._gen[li] += 1
            fresh = Relic(capacity=self._capacity,
                          start_awake=self._start_awake,
                          name=f"relic-pool-lane{li}-r{self._gen[li]}",
                          handoff=self._rebalance)
            fresh._probe_every = (_PROBE_EVERY_SPINS if self._supervise
                                  else 0)
            self._lanes[li] = fresh
            self._runs[li] = []
            self._base[li] = 0
            self._oruns[li] = []
            self._obase[li] = 0
            if self._supervisor is not None:
                self._supervisor.reset_lane(li)
            if self._started:
                fresh.start()
            self._live.append(li)
            self._live.sort()
        self._rebuild_hot()
        return failure

    def check_lanes(self) -> List[LaneFailure]:
        """Supervision sweep (pool-owner thread only): quarantine lanes
        whose assistant thread died — respawning when enabled — and feed
        the :class:`LaneSupervisor` one progress-heartbeat sample. Cheap
        to call often (the supervisor samples once per heartbeat period).
        Returns the *new* failures; they also stay queued for the next
        ``wait()`` unless drained with ``take_lane_failures``."""
        if not self._supervise:
            return []
        new: List[LaneFailure] = []
        for li in list(self._live):
            lane = self._lanes[li]
            if not lane.is_alive():
                new.append(self._quarantine_lane(li, lane))
        sup = self._supervisor
        if sup is not None:
            completed: List[int] = []
            outstanding: List[int] = []
            for li, lane in enumerate(self._lanes):
                done = lane._completed
                completed.append(done)
                # A quarantined slot reads as idle, not stalled: nothing
                # is outstanding that supervision could still save.
                outstanding.append(
                    (lane.stats.submitted - done) if li in self._live else 0)
            sup.observe(completed, outstanding)
        return new

    def take_lane_failures(self) -> Tuple[LaneFailure, ...]:
        """Drain the queued quarantine records without a barrier
        (pool-owner thread only) — the serve loop's fire-and-observe
        supervision read. Once drained, ``wait()`` no longer raises for
        these failures."""
        if not self._failures:
            return ()
        out = tuple(self._failures)
        self._failures.clear()
        return out

    def in_flight_estimate(self) -> int:
        """Racy-but-monotone estimate of tasks admitted to live rings and
        not yet executed: total submitted minus total completed minus the
        tasks written off as lost. Reads each lane's live completion
        counter directly (the per-lane ``stats.completed`` snapshot only
        refreshes at barriers, which a serving loop never runs). Reaches
        exactly 0 once the live lanes drain — the serve layer's quiesce
        predicate after a lane death."""
        submitted = self._retired.submitted
        completed = self._retired.completed
        for lane in self._lanes:
            submitted += lane.stats.submitted
            completed += lane._completed
        est = submitted - completed - self._lost_tasks
        return est if est > 0 else 0

    def stalled_lanes(self) -> List[int]:
        """Advisory: slots with outstanding work and no completion
        progress for ~2 heartbeat periods (see ``LaneSupervisor``)."""
        return [] if self._supervisor is None else self._supervisor.stalled()

    def straggler_lanes(self) -> List[int]:
        """Advisory: slots persistently slower than their peers."""
        return ([] if self._supervisor is None
                else self._supervisor.stragglers())

    @property
    def live_lanes(self) -> Tuple[int, ...]:
        return tuple(self._live)

    @property
    def lost_tasks(self) -> int:
        return self._lost_tasks

    def _trim_runs(self, lane_idx: int) -> None:
        """Drop seq-log entries for tasks the lane has already completed,
        keeping a pending first error's entry mappable. Called from the
        submit paths when a lane's log reaches ``_trim_at`` (amortized
        O(1) per task): between barriers the log then stays O(capacity) —
        the in-flight bound — instead of one entry per task ever
        submitted, so fire-and-observe-by-handle consumers that never
        call ``wait()`` cannot grow it without bound. The completion
        estimate is a racy cross-thread read, but it only ever
        undercounts (``_completed_main_estimate``): trimming too little
        is safe, and an error recorded at-or-after it is by construction
        still in the log."""
        lane = self._lanes[lane_idx]
        base = self._base[lane_idx]
        keep_from = lane._completed_main_estimate()
        if lane.stats.last_error is not None:
            fei = lane.stats.first_error_index
            if fei is not None and fei < keep_from:
                keep_from = fei        # the pending error must stay mappable
        drop = keep_from - base
        if drop > 0:
            del self._runs[lane_idx][:drop]
            self._base[lane_idx] = base + drop

    def _trim_oruns(self, lane_idx: int) -> None:
        """Handoff-ring twin of ``_trim_runs``: keyed off the lane's
        handoff-completion counter (monotonic; a stale read undercounts,
        so over-retention is the only failure mode) and the pending
        error's handoff index when it rode this ring."""
        lane = self._lanes[lane_idx]
        base = self._obase[lane_idx]
        keep_from = lane._completed_ovf
        if lane.stats.last_error is not None:
            fei = lane.stats.first_error_handoff_index
            if fei is not None and fei < keep_from:
                keep_from = fei
        drop = keep_from - base
        if drop > 0:
            del self._oruns[lane_idx][:drop]
            self._obase[lane_idx] = base + drop

    def _seq_of(self, lane_idx: int, local_idx: Optional[int]) -> int:
        """Pool-global submission seq of lane ``lane_idx``'s ``local_idx``-th
        *primary-ring* task (this window). Out-of-window indexes
        (defensive: should not happen — errors are cleared per window)
        order last."""
        if local_idx is None:
            return self._seq
        off = local_idx - self._base[lane_idx]
        runs = self._runs[lane_idx]
        if 0 <= off < len(runs):
            try:
                return runs[off]
            except IndexError:
                # Racy observer (the stats view's last_error getter runs on
                # any thread): the producer's wait() may clear the window
                # log between the bounds check and the index. Fall through.
                pass
        return self._seq

    def _oseq_of(self, lane_idx: int, local_idx: Optional[int]) -> int:
        """``_seq_of`` for the lane's *handoff* ring (its own log/base)."""
        if local_idx is None:
            return self._seq
        off = local_idx - self._obase[lane_idx]
        oruns = self._oruns[lane_idx]
        if 0 <= off < len(oruns):
            try:
                return oruns[off]
            except IndexError:
                pass                   # racy observer, as in _seq_of
        return self._seq

    def _pending_error_seq(self, lane_idx: int, stats: RelicStats) -> int:
        """Submission seq of a lane's pending first error, whichever ring
        carried the failed task (exactly one index field is set while
        ``last_error`` is pending)."""
        hidx = stats.first_error_handoff_index
        if hidx is not None:
            return self._oseq_of(lane_idx, hidx)
        return self._seq_of(lane_idx, stats.first_error_index)

    # ------------------------------------------------------- hints (broadcast)

    def wake_up_hint(self) -> None:
        """Broadcast §VI-B wake hint: unpark every lane's assistant."""
        for lane in self._lanes:
            lane.wake_up_hint()

    def sleep_hint(self) -> None:
        """Broadcast §VI-B sleep hint: every lane's assistant may park."""
        for lane in self._lanes:
            lane.sleep_hint()

    # -------------------------------------------------------------- lifecycle

    def shutdown(self, timeout: float = 5.0) -> None:
        """Shut down every lane. If any lane's assistant is wedged past its
        join timeout the pool (like the pair) becomes non-restartable: the
        first such error re-raises after *all* lanes were attempted."""
        self._shutdown = True
        first_err: Optional[RelicUsageError] = None
        for lane in self._lanes:
            try:
                lane.shutdown(timeout)
            except RelicUsageError as e:
                if first_err is None:
                    first_err = e
        if first_err is not None:
            raise first_err

    def __enter__(self) -> "RelicPool":
        return self.start()

    def __exit__(self, exc_type: Any, *exc: Any) -> None:
        try:
            self.shutdown()
        except RelicUsageError:
            if exc_type is None:
                raise
