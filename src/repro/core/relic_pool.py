"""RelicPool: the paper's SMT pair scaled to N lanes (one producer, N assistants).

The paper's Relic is deliberately a *two*-thread runtime — one producer and
one assistant on SMT sibling contexts, joined by a single bounded SPSC ring
(§VI). This module is the repo's first step past that ceiling, following
the FastFlow construction (Aldinucci et al., 2009): lock-free SPSC queues
*compose* into larger networks without giving up the single-producer /
single-consumer fast path. A ``RelicPool`` is N independent **lanes**, each
a full :class:`repro.core.relic.Relic` (its own ``SpscRing`` + assistant
thread + hints + stats), so every lane preserves the exact SPSC invariants
and cached-index/batch fast paths of the pair — no MPMC queue anywhere, no
lock on the submit path.

What the pool adds on top of the lanes:

* **Lane-striped submission.** ``submit()`` round-robins a cursor over the
  lanes; when the target lane's ring is full it tries the other lanes,
  least-loaded first (by the ring's racy-but-monotonic ``len()`` — a
  stale read costs balance, never correctness), and busy-waits *sweeping
  all lanes* only while every ring is full — so a lane wedged behind a
  long task can never block a submission another lane has room for
  (bounded backpressure engages pool-wide, not per-lane).
  ``submit_batch()`` flattens the burst once and deals contiguous shards
  across the lanes — each lane ``push_many``-ing its window of the
  *shared* flattened list (no per-lane slicing) — in two phases: a
  non-blocking pass hands every lane what its ring has room for, then
  the remainders are swept round-robin, so here too a wedged lane never
  starves the shards the other lanes already have room to run.
* **Skew resistance (dynamic load balancing, PR 6).** Static striping
  pins a task to its lane forever — exactly where irregular (power-law
  cost) workloads bleed speedup when one lane wedges behind a long task.
  With ``rebalance=True`` (the default for multi-lane pools) two
  mechanisms fix that without touching any hot path or SPSC invariant:
  (1) *re-striping* — a burst remainder the sweep cannot place in its
  own lane is re-dealt, producer-side, to lanes with room; (2) a
  *victim-cooperative handoff ring* per lane — a second bounded SPSC
  ring the producer fills only when primaries are backed up and the
  lane's assistant drains only when its primary is idle. Every ring
  stays strictly one-producer/one-consumer (the pool's single producer
  pushes, that lane's single assistant pops); there is still no MPMC
  structure and no lock anywhere. ``rebalance=False`` reproduces the
  static PR 5 pool bit-for-bit.
* **Broadcast hints.** ``sleep_hint()`` / ``wake_up_hint()`` fan out to
  every lane (paper §VI-B, now meaning "park/unpark the whole pool").
* **Aggregated stats.** ``stats`` is a live view summing the per-lane
  ``RelicStats`` counters; ``stats.lanes`` exposes the per-lane detail
  (striping tests and benchmarks read it).
* **First-error-wins across lanes.** Each lane already keeps its *own*
  first error plus the submission index it happened at; ``wait()`` barriers
  every lane, maps those lane-local indexes back to the pool-global
  submission order (a per-window seq log the producer appends to), and
  re-raises the error of the **earliest-submitted** failed task — the SPI
  contract, extended across lanes. Later failures only bump
  ``task_errors``, exactly as in the pair.

The pair's usage rules apply unchanged: submission and waiting are
main-thread-only, assistants cannot submit (no recursive spawn, §VI-A),
and hints are advisory (they may never deadlock a barrier or a full-ring
submit). A ``lanes=1`` pool is semantically the pair with striping
bookkeeping on top — the ``scaling`` benchmark section records what that
bookkeeping costs (it must stay within a few percent of raw Relic).

Ordering caveat: the pool preserves FIFO *per lane*, not globally — two
tasks striped onto different lanes may complete in either order. Callers
needing global FIFO use a single-lane runtime (``workers <= 1`` on the
scheduler SPI).
"""

from __future__ import annotations

import functools
import threading
import time
from typing import Any, Callable, Iterable, List, Optional, Tuple

from repro.core.relic import (Relic, RelicStats, RelicUsageError,
                              flatten_tasks)
from repro.core.spsc import DEFAULT_CAPACITY

__all__ = ["RelicPool", "RelicPoolStats"]


class RelicPoolStats:
    """Live aggregate view over the per-lane :class:`RelicStats`.

    Duck-compatible with ``SchedulerStats`` (``submitted``/``completed``/
    ``task_errors``/``last_error``) plus the Relic telemetry counters, all
    computed on read by summing the lanes — there is no second set of hot
    counters to keep coherent on the submit path. ``lanes`` exposes the
    underlying per-lane stats objects.
    """

    __slots__ = ("_pool",)

    def __init__(self, pool: "RelicPool"):
        self._pool = pool

    def _sum(self, attr: str) -> int:
        return sum(getattr(lane.stats, attr) for lane in self._pool._lanes)

    @property
    def submitted(self) -> int:
        return self._sum("submitted")

    @property
    def completed(self) -> int:
        return self._sum("completed")

    @property
    def task_errors(self) -> int:
        return self._sum("task_errors")

    @property
    def producer_full_spins(self) -> int:
        return self._sum("producer_full_spins")

    @property
    def assistant_empty_spins(self) -> int:
        return self._sum("assistant_empty_spins")

    @property
    def parks(self) -> int:
        return self._sum("parks")

    @property
    def last_error(self) -> Optional[BaseException]:
        """The stashed error (a ``close()``-time capture) if any, else the
        earliest-submitted pending lane error (the one ``wait()`` would
        raise). Observability only — reading it clears nothing."""
        if self._pool._stashed_error is not None:
            return self._pool._stashed_error
        best: Tuple[int, Optional[BaseException]] = (0, None)
        for i, lane in enumerate(self._pool._lanes):
            err = lane.stats.last_error
            if err is None:
                continue
            seq = self._pool._pending_error_seq(i, lane.stats)
            if best[1] is None or seq < best[0]:
                best = (seq, err)
        return best[1]

    @last_error.setter
    def last_error(self, value: Optional[BaseException]) -> None:
        # SchedulerStats duck-compat: the pool adapter stashes a close()-time
        # error here so it stays observable after shutdown.
        self._pool._stashed_error = value

    @property
    def lanes(self) -> Tuple[RelicStats, ...]:
        return tuple(lane.stats for lane in self._pool._lanes)

    def __repr__(self) -> str:
        return (f"RelicPoolStats(lanes={len(self._pool._lanes)}, "
                f"submitted={self.submitted}, completed={self.completed}, "
                f"task_errors={self.task_errors})")


class RelicPool:
    """N-lane Relic: one producer striping over N independent SPSC pairs.

    Usage mirrors :class:`Relic` exactly::

        pool = RelicPool(lanes=4)
        pool.start()
        pool.wake_up_hint()          # broadcast: a parallel section is imminent
        pool.submit(fn, a, b)        # main thread only; striped over the lanes
        ...                          # main thread does its own share
        pool.wait()                  # barrier across every lane
        pool.sleep_hint()            # broadcast park
        pool.shutdown()
    """

    def __init__(self, lanes: int = 2, capacity: int = DEFAULT_CAPACITY,
                 start_awake: bool = False, rebalance: bool = True):
        if lanes <= 0:
            raise ValueError(f"lanes must be positive, got {lanes}")
        self._n = lanes
        # Skew resistance (PR 6): with ``rebalance`` on, a burst remainder
        # stuck behind a wedged lane is re-dealt to lanes with room
        # (producer-side re-striping — see _rebalance_pending) and each
        # lane grows a victim-cooperative handoff ring its assistant
        # drains when idle. Off reproduces the PR 5 static striping
        # exactly. A single-lane pool has nowhere to re-deal to, so it
        # never pays for any of it (the degenerate pair path below).
        self._rebalance = bool(rebalance) and lanes > 1
        self._lanes = [
            Relic(capacity=capacity, start_awake=start_awake,
                  name=f"relic-pool-lane{i}", handoff=self._rebalance)
            for i in range(lanes)
        ]
        self._rr = 0                 # round-robin cursor (next lane to try)
        self._seq = 0                # pool-global submission counter
        # Per-window submission log: _runs[i][k] is the global seq of lane
        # i's (base[i]+k)-th task. Appended by the producer per submission,
        # cleared at every wait() — it exists so first-error-wins can be
        # ordered by *submission order* across lanes, and it is the whole
        # per-task cost of pooling beyond the lane push itself. Between
        # waits it is kept bounded by trimming entries for already-
        # completed tasks (see _trim_runs), so a long-lived scope that
        # never barriers (pipeline-style fire-and-observe-by-handle use)
        # holds O(capacity) ints per lane, not one per task ever submitted.
        self._runs: List[List[int]] = [[] for _ in range(lanes)]
        self._base = [0] * lanes     # lane-local index of _runs[i][0]
        self._trim_at = 4 * capacity  # in-flight bound is 2*capacity, so at
        #                               this length at least half is trimmable
        # Handoff-ring twin of the seq log: _oruns[i][k] is the global seq
        # of the (obase[i]+k)-th task the producer pushed into lane i's
        # handoff ring. Same trim discipline, keyed off the lane's
        # handoff-completion counter — so first-error-wins ordering
        # survives re-striping (the seq rides whichever log matches the
        # ring that carried the task).
        self._oruns: List[List[int]] = [[] for _ in range(lanes)]
        self._obase = [0] * lanes
        self._stashed_error: Optional[BaseException] = None
        self._shutdown = False
        self._started = False
        self._main_ident: Optional[int] = None
        # Hot-path pre-binds: one tuple load per submit instead of chasing
        # lane -> ring / lane -> stats chains per task.
        self._hot = [(lane._push2, lane.stats, self._runs[i])
                     for i, lane in enumerate(self._lanes)]
        if lanes == 1:
            # Degenerate pool == the pair, exactly: with one lane the
            # cursor never moves, every shard is the whole burst, and
            # cross-lane error ordering is the lane's own — so the
            # single-lane configuration pays for none of that bookkeeping
            # ("scaling must not tax the pair", measured by the scaling
            # benchmark's lanes1-vs-relic rows).
            self._lane0 = self._lanes[0]
            self._push2_0 = self._lane0._push2
            self._stats0 = self._lane0.stats
            self._submit2 = self._submit2_single
        self.stats = RelicPoolStats(self)

    @property
    def n_lanes(self) -> int:
        return self._n

    # ------------------------------------------------------------------ roles

    def start(self) -> "RelicPool":
        if self._started:
            raise RelicUsageError("RelicPool already started")
        self._started = True
        self._main_ident = threading.get_ident()
        for lane in self._lanes:
            lane.start()
        return self

    def _check_main(self, what: str) -> None:
        ident = threading.get_ident()
        for lane in self._lanes:
            if lane._assistant is not None and ident == lane._assistant.ident:
                # Same rule as the pair (§VI-A): assistants cannot submit.
                raise RelicUsageError(f"{what} called from an assistant thread")
        if self._main_ident is not None and ident != self._main_ident:
            raise RelicUsageError(
                f"{what} must be called from the main (producer) thread")

    # ------------------------------------------------------------- public API

    def submit(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> None:
        """Submit one task (main thread only), striped round-robin over the
        lanes with a least-loaded fallback. Busy-waits only when the
        fallback lane is full too (bounded backpressure)."""
        if threading.get_ident() != self._main_ident:
            self._check_main("submit()")   # slow path: classify the misuse
        if self._shutdown:
            raise RelicUsageError("submit() after shutdown")
        if kwargs:
            fn = functools.partial(fn, **kwargs)
        self._submit2(fn, args)

    def _submit2_single(self, fn: Callable[..., Any], args: tuple) -> None:
        """No-checks push for the lanes=1 degenerate pool (bound over
        ``_submit2`` at construction): the pair's own submit, nothing more.
        Accounts after the push like the pair (interrupt safety)."""
        if self._push2_0(fn, args):
            self._stats0.submitted += 1
            return
        self._lane0._push_spin(fn, args)
        self._stats0.submitted += 1

    def _submit2(self, fn: Callable[..., Any], args: tuple) -> None:
        """No-checks striped push (the scheduler adapter's fast path)."""
        i = self._rr
        nxt = i + 1
        self._rr = nxt if nxt < self._n else 0
        push2, lane_stats, runs = self._hot[i]
        if push2(fn, args):
            seq = self._seq
            self._seq = seq + 1
            lane_stats.submitted += 1
            runs.append(seq)
            if len(runs) >= self._trim_at:
                self._trim_runs(i)
            return
        self._submit_overflow(fn, args)

    def _submit_overflow(self, fn: Callable[..., Any], args: tuple) -> None:
        """Round-robin target full: try the other lanes least-loaded first
        (by the ring's racy-but-monotonic ``len()`` — reading another
        lane's ring from here is the observer case its clamp exists for; a
        stale read costs balance, never correctness) and busy-wait
        *sweeping* until some lane accepts. Sweeping — rather than
        committing to one fallback lane — keeps the pool live when a lane
        is wedged behind a long task: backpressure engages only while
        every ring is full. With rebalancing on, "every ring" includes the
        handoff rings: a pool whose primaries are all backed up hands the
        task to the least-loaded lane's handoff ring (its assistant pulls
        from it when its primary goes idle) before resigning to the spin."""
        lanes = self._lanes
        hot = self._hot
        n = self._n
        rebalance = self._rebalance
        spins = 0
        pause_every = lanes[0]._spin_pause_every
        while True:
            order = sorted(range(n), key=lambda j: len(lanes[j]._ring))
            for j in order:
                push2, lane_stats, runs = hot[j]
                if push2(fn, args):
                    seq = self._seq
                    self._seq = seq + 1
                    lane_stats.submitted += 1
                    runs.append(seq)
                    if len(runs) >= self._trim_at:
                        self._trim_runs(j)
                    return
            if rebalance:
                for j in order:
                    lane = lanes[j]
                    if lane._oring.push2(fn, args):
                        seq = self._seq
                        self._seq = seq + 1
                        lane.stats.submitted += 1
                        oruns = self._oruns[j]
                        oruns.append(seq)
                        if len(oruns) >= self._trim_at:
                            self._trim_oruns(j)
                        return
            if spins == 0:
                # Advisory hints must not deadlock a full pool: un-park
                # every assistant once (only this blocked thread could
                # re-park them).
                for lane in lanes:
                    lane._awake.set()
            lanes[order[0]].stats.producer_full_spins += 1
            spins += 1
            if spins % pause_every == 0:
                time.sleep(0)

    def submit_batch(
        self, tasks: Iterable[Tuple[Callable[..., Any], tuple, dict]]
    ) -> None:
        """Submit a burst of ``(fn, args, kwargs)`` tasks (main thread
        only), sharded across the lanes: the burst is flattened once into
        the ``fn, args`` stripe and split into contiguous near-equal
        shards dealt out from the round-robin cursor. Delivery is
        two-phase so a wedged lane cannot starve the others' shards: a
        first non-blocking pass hands every lane as much of its shard as
        its ring has room for (one ``push_many`` per lane), then the
        remainders are busy-wait *swept* round-robin under ring
        backpressure — every other lane's work is already flowing while
        the producer waits on a full one, and a cross-shard dependency
        (a lane-0 task blocking on a handle from lane 1's shard) can
        always make progress. With rebalancing on, a remainder the sweep
        cannot place at all is *re-striped* to lanes that do have room
        (see ``_rebalance_pending``) instead of waiting out its original
        lane.

        Accounting (``submitted``, the seq logs) is committed as each
        window is handed to a ring, never before: a ``BaseException``
        (KeyboardInterrupt) escaping the sweep therefore cannot strand
        ``submitted`` above what any assistant will ever pop — the
        pre-PR 6 failure mode where the next ``wait()`` busy-spun
        forever. The unaccounted residue of an interrupt is at most the
        tasks of one in-flight ``push_many`` window, which can only make
        a later barrier return *early*, never hang."""
        if threading.get_ident() != self._main_ident:
            self._check_main("submit_batch()")
        if self._shutdown:
            raise RelicUsageError("submit_batch() after shutdown")
        flat = flatten_tasks(tasks)
        k = len(flat) // 2
        if not k:
            return
        n = self._n
        if n == 1:
            # Degenerate pool: the whole burst is lane 0's shard, and the
            # seq log is pointless with nothing to order across.
            self._lanes[0]._push_flat(flat, account=True)
            return
        share, rem = divmod(k, n)
        seq0 = self._seq
        self._seq = seq0 + k
        cursor = self._rr
        pos = 0                       # task offset into the burst
        pending: List[list] = []      # [lane_idx, next_slot, stop_slot]
        for step in range(n):
            take = share + (1 if step < rem else 0)
            if take == 0:
                break                 # k < n: only the first k lanes get one
            i = cursor + step
            if i >= n:
                i -= n
            lane = self._lanes[i]
            start2, stop2 = 2 * pos, 2 * (pos + take)
            pushed = lane._ring.push_many(flat, start2, stop2)
            if pushed:
                self._account_window(i, lane, seq0 + pos, pushed // 2)
            if start2 + pushed < stop2:
                pending.append([i, start2 + pushed, stop2])
            pos += take
        # Advance the cursor by the burst remainder so the next burst's
        # +1 shards (and the next single submit) land on fresh lanes.
        self._rr = (cursor + rem) % n
        if pending:
            self._sweep_remainders(flat, pending, seq0)

    def _account_window(self, i: int, lane: Relic, seq_start: int,
                        p: int) -> None:
        """Record ``p`` tasks just pushed into lane ``i``'s *primary* ring,
        holding seqs ``seq_start..seq_start+p-1``. Called immediately after
        the push (never before — interrupt safety, see submit_batch)."""
        lane.stats.submitted += p
        runs = self._runs[i]
        runs.extend(range(seq_start, seq_start + p))
        if len(runs) >= self._trim_at:
            self._trim_runs(i)

    def _account_handoff_window(self, i: int, lane: Relic, seq_start: int,
                                p: int) -> None:
        """Same as ``_account_window`` for lane ``i``'s *handoff* ring."""
        lane.stats.submitted += p
        oruns = self._oruns[i]
        oruns.extend(range(seq_start, seq_start + p))
        if len(oruns) >= self._trim_at:
            self._trim_oruns(i)

    def _sweep_remainders(self, flat: list, pending: List[list],
                          seq0: int) -> None:
        """Phase 2 of a burst: drain shard remainders into their lanes,
        sweeping all of them each iteration (never committing to one full
        lane) and yielding under full-pool backpressure. Partial pushes
        are always pair-aligned: every publication is even-sized, so the
        free-slot count every ``push_many`` sees is even by induction.
        When a whole sweep makes no progress and rebalancing is on, the
        stuck remainders are re-striped to lanes with room before the
        producer resigns itself to spinning."""
        lanes = self._lanes
        rebalance = self._rebalance
        spins = 0
        pause_every = lanes[0]._spin_pause_every
        while pending:
            progressed = False
            for entry in list(pending):
                i, next2, stop2 = entry
                lane = lanes[i]
                pushed = lane._ring.push_many(flat, next2, stop2)
                if pushed:
                    progressed = True
                    self._account_window(i, lane, seq0 + next2 // 2,
                                         pushed // 2)
                    next2 += pushed
                    if next2 >= stop2:
                        pending.remove(entry)
                    else:
                        entry[1] = next2
            if not pending:
                return
            if not progressed:
                if rebalance and self._rebalance_pending(flat, pending, seq0):
                    continue
                if spins == 0:
                    # Advisory hints must not deadlock a burst: a parked
                    # assistant is a stalled lane's only possible drain.
                    for i, _, _ in pending:
                        lanes[i]._awake.set()
                lanes[pending[0][0]].stats.producer_full_spins += 1
                spins += 1
                if spins % pause_every == 0:
                    time.sleep(0)

    def _rebalance_pending(self, flat: list, pending: List[list],
                           seq0: int) -> bool:
        """Re-stripe stuck remainders (producer-side dynamic load
        balancing). For each remainder whose own lane has no room, move a
        head window to another lane: first into primary rings with free
        slots, then — when every primary is full — into handoff rings.
        Returns True when any task moved (the sweep then retries instead
        of spinning).

        Every push here remains strictly single-producer (this thread is
        the only pusher of every primary *and* handoff ring) and sized by
        ``SpscRing.free_slots()``, a producer-side lower bound — so a
        window never partially pushes and accounting can follow each push
        exactly. Lanes that themselves have a stuck remainder are skipped
        as destinations: their rings are full by definition, and skipping
        them keeps this pass O(lanes) per remainder."""
        lanes = self._lanes
        stuck = {entry[0] for entry in pending}
        order = sorted((j for j in range(self._n) if j not in stuck),
                       key=lambda j: len(lanes[j]._ring))
        moved = False
        for entry in list(pending):
            i, next2, stop2 = entry
            for j in order:
                want = (stop2 - next2) // 2
                if want <= 0:
                    break
                lane = lanes[j]
                room = lane._ring.free_slots() // 2
                if room > 0:
                    m = min(want, room)
                    pushed = lane._ring.push_many(flat, next2, next2 + 2 * m)
                    self._account_window(j, lane, seq0 + next2 // 2,
                                         pushed // 2)
                    next2 += pushed
                    entry[1] = next2
                    moved = True
                    continue
                oring = lane._oring
                if oring is None:
                    continue
                room = oring.free_slots() // 2
                if room <= 0:
                    continue
                m = min(want, room)
                pushed = oring.push_many(flat, next2, next2 + 2 * m)
                self._account_handoff_window(j, lane, seq0 + next2 // 2,
                                             pushed // 2)
                next2 += pushed
                entry[1] = next2
                moved = True
            if next2 >= stop2:
                pending.remove(entry)
        return moved

    def wait(self) -> None:
        """Barrier across every lane; first-error-wins by submission order.

        Each lane is barriered (its spin loop, no raise), its pending
        first error — if any — is mapped to the pool-global submission
        seq *while the error state is still set* (the seq logs need the
        index fields), and only then consumed via ``_take_error`` (which
        clears the error and its index fields as one unit — the PR 6
        stale-index bugfix). The earliest-submitted error re-raises; all
        other errors from this window are dropped from the error channel
        (they remain counted in ``stats.task_errors``) — the same
        later-failures-only-bump rule the pair applies within one lane."""
        self._check_main("wait()")
        errors: List[Tuple[int, BaseException]] = []
        for i, lane in enumerate(self._lanes):
            lane._barrier()
            if lane.stats.last_error is not None:
                seq = self._pending_error_seq(i, lane.stats)
                err = lane._take_error()
                if err is not None:
                    errors.append((seq, err))
        for i in range(self._n):
            # base + len(runs) == tasks ever pushed to that ring: the next
            # window's local indexes continue from there. (Not the lane's
            # ``submitted`` — with rebalancing that counter spans both
            # rings, while each log is per-ring.)
            self._base[i] += len(self._runs[i])
            self._runs[i].clear()
            self._obase[i] += len(self._oruns[i])
            self._oruns[i].clear()
        if errors:
            errors.sort(key=lambda pair: pair[0])
            raise errors[0][1]

    def _trim_runs(self, lane_idx: int) -> None:
        """Drop seq-log entries for tasks the lane has already completed,
        keeping a pending first error's entry mappable. Called from the
        submit paths when a lane's log reaches ``_trim_at`` (amortized
        O(1) per task): between barriers the log then stays O(capacity) —
        the in-flight bound — instead of one entry per task ever
        submitted, so fire-and-observe-by-handle consumers that never
        call ``wait()`` cannot grow it without bound. The completion
        estimate is a racy cross-thread read, but it only ever
        undercounts (``_completed_main_estimate``): trimming too little
        is safe, and an error recorded at-or-after it is by construction
        still in the log."""
        lane = self._lanes[lane_idx]
        base = self._base[lane_idx]
        keep_from = lane._completed_main_estimate()
        if lane.stats.last_error is not None:
            fei = lane.stats.first_error_index
            if fei is not None and fei < keep_from:
                keep_from = fei        # the pending error must stay mappable
        drop = keep_from - base
        if drop > 0:
            del self._runs[lane_idx][:drop]
            self._base[lane_idx] = base + drop

    def _trim_oruns(self, lane_idx: int) -> None:
        """Handoff-ring twin of ``_trim_runs``: keyed off the lane's
        handoff-completion counter (monotonic; a stale read undercounts,
        so over-retention is the only failure mode) and the pending
        error's handoff index when it rode this ring."""
        lane = self._lanes[lane_idx]
        base = self._obase[lane_idx]
        keep_from = lane._completed_ovf
        if lane.stats.last_error is not None:
            fei = lane.stats.first_error_handoff_index
            if fei is not None and fei < keep_from:
                keep_from = fei
        drop = keep_from - base
        if drop > 0:
            del self._oruns[lane_idx][:drop]
            self._obase[lane_idx] = base + drop

    def _seq_of(self, lane_idx: int, local_idx: Optional[int]) -> int:
        """Pool-global submission seq of lane ``lane_idx``'s ``local_idx``-th
        *primary-ring* task (this window). Out-of-window indexes
        (defensive: should not happen — errors are cleared per window)
        order last."""
        if local_idx is None:
            return self._seq
        off = local_idx - self._base[lane_idx]
        runs = self._runs[lane_idx]
        if 0 <= off < len(runs):
            try:
                return runs[off]
            except IndexError:
                # Racy observer (the stats view's last_error getter runs on
                # any thread): the producer's wait() may clear the window
                # log between the bounds check and the index. Fall through.
                pass
        return self._seq

    def _oseq_of(self, lane_idx: int, local_idx: Optional[int]) -> int:
        """``_seq_of`` for the lane's *handoff* ring (its own log/base)."""
        if local_idx is None:
            return self._seq
        off = local_idx - self._obase[lane_idx]
        oruns = self._oruns[lane_idx]
        if 0 <= off < len(oruns):
            try:
                return oruns[off]
            except IndexError:
                pass                   # racy observer, as in _seq_of
        return self._seq

    def _pending_error_seq(self, lane_idx: int, stats: RelicStats) -> int:
        """Submission seq of a lane's pending first error, whichever ring
        carried the failed task (exactly one index field is set while
        ``last_error`` is pending)."""
        hidx = stats.first_error_handoff_index
        if hidx is not None:
            return self._oseq_of(lane_idx, hidx)
        return self._seq_of(lane_idx, stats.first_error_index)

    # ------------------------------------------------------- hints (broadcast)

    def wake_up_hint(self) -> None:
        """Broadcast §VI-B wake hint: unpark every lane's assistant."""
        for lane in self._lanes:
            lane.wake_up_hint()

    def sleep_hint(self) -> None:
        """Broadcast §VI-B sleep hint: every lane's assistant may park."""
        for lane in self._lanes:
            lane.sleep_hint()

    # -------------------------------------------------------------- lifecycle

    def shutdown(self, timeout: float = 5.0) -> None:
        """Shut down every lane. If any lane's assistant is wedged past its
        join timeout the pool (like the pair) becomes non-restartable: the
        first such error re-raises after *all* lanes were attempted."""
        self._shutdown = True
        first_err: Optional[RelicUsageError] = None
        for lane in self._lanes:
            try:
                lane.shutdown(timeout)
            except RelicUsageError as e:
                if first_err is None:
                    first_err = e
        if first_err is not None:
            raise first_err

    def __enter__(self) -> "RelicPool":
        return self.start()

    def __exit__(self, exc_type: Any, *exc: Any) -> None:
        try:
            self.shutdown()
        except RelicUsageError:
            if exc_type is None:
                raise
