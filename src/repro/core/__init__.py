"""repro.core — the paper's contribution (Relic fine-grained tasking) at three
scales: host threads (relic), intra-chip DMA/MXU (lanes + kernels), and
inter-chip ICI rings (collective_matmul)."""

from repro.core.spsc import SpscRing, DEFAULT_CAPACITY
from repro.core.relic import Relic, RelicStats, RelicUsageError
from repro.core.relic_pool import RelicPool, RelicPoolStats
from repro.core.schedulers import (
    Scheduler,
    SchedulerStats,
    SchedulerUsageError,
    available_schedulers,
    make_scheduler,
)
from repro.core.lanes import two_lane_ring, two_lane_ring_db
from repro.core.pipeline import pipeline_apply, split_stages
from repro.core import collective_matmul

__all__ = [
    "SpscRing",
    "DEFAULT_CAPACITY",
    "Relic",
    "RelicStats",
    "RelicUsageError",
    "RelicPool",
    "RelicPoolStats",
    "Scheduler",
    "SchedulerStats",
    "SchedulerUsageError",
    "available_schedulers",
    "make_scheduler",
    "two_lane_ring",
    "two_lane_ring_db",
    "pipeline_apply",
    "split_stages",
    "collective_matmul",
]
