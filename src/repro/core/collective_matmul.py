"""Overlapped collective matmuls — the Relic SPSC ring on the ICI fabric.

Megatron-style tensor parallelism needs two collectives per block:

  * ``f``: all-gather sequence-sharded activations before a column-parallel
    matmul;
  * ``g``: reduce-scatter the row-parallel matmul's partial sums back to
    sequence shards.

The unoverlapped forms serialize ICI transfer and MXU compute. Following the
paper's producer/consumer specialization, we replace each with a **static
ring**: at every step one ``ppermute`` (transfer lane) moves the next chunk
while the MXU (compute lane) consumes the current one — a depth-1 SPSC queue
between two fixed-role lanes, no dynamic scheduling. This is the established
"collective matmul" decomposition (Wang et al., ASPLOS'23), which we adopt
here explicitly as the TPU translation of Relic's SPSC pipeline.

All functions below run **inside shard_map** (per-device views). Reference
(unoverlapped) implementations live alongside for A/B in §Perf and for the
numerical tests.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core.lanes import two_lane_ring


def _pvary(x: jax.Array, axis_name: str) -> jax.Array:
    """Mark a replicated value as device-varying along ``axis_name``.

    shard_map's vma type system requires loop carries that *become* varying
    (our ring buffers do, after the first ppermute) to start varying."""
    if hasattr(lax, "pvary"):
        return lax.pvary(x, (axis_name,))
    if hasattr(lax, "pcast"):
        return lax.pcast(x, (axis_name,), to="varying")  # older spelling
    return x  # pre-vma JAX: no replication types, nothing to declare


def _axis_size(axis_name: str) -> int:
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)  # pre-0.5 spelling


def _axis_index(axis_name: str) -> jax.Array:
    return lax.axis_index(axis_name)


# --------------------------------------------------------------------------
# Reference (unoverlapped) forms
# --------------------------------------------------------------------------

def allgather_matmul_ref(x: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    """y = allgather(x, seq axis) @ w   (x: [S/p, K], w: [K, N/p] local)."""
    x_full = lax.all_gather(x, axis_name, axis=0, tiled=True)  # [S, K]
    return x_full @ w  # [S, N/p]


def matmul_reducescatter_ref(y: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    """z = reduce_scatter(y @ w, seq axis)  (y: [S, N/p], w: [N/p, K] local)."""
    partial_z = y @ w  # [S, K] partial sum over the sharded N dimension
    return lax.psum_scatter(partial_z, axis_name, scatter_dimension=0, tiled=True)


# --------------------------------------------------------------------------
# Overlapped ring forms (two-lane)
# --------------------------------------------------------------------------

def allgather_matmul(
    x: jax.Array,
    w: jax.Array,
    axis_name: str,
    *,
    unroll: int = 1,
) -> jax.Array:
    """Ring all-gather-matmul: y[S, N/p] from x[S/p, K] and w[K, N/p].

    Step ``s``: device ``d`` holds the x-chunk originally from device
    ``(d + s) % p``; it computes that chunk's rows of y while ppermuting the
    chunk to neighbor ``d - 1`` (so everyone eventually sees every chunk).
    The ppermute for step ``s+1`` is issued before step ``s``'s matmul —
    transfer lane producing, compute lane consuming.
    """
    p = _axis_size(axis_name)
    d = _axis_index(axis_name)
    s_loc, k = x.shape
    n = w.shape[1]
    perm = [(i, (i - 1) % p) for i in range(p)]

    def transfer(step, buf):
        del step
        return lax.ppermute(buf, axis_name, perm)

    def compute(step, buf, acc):
        # buf holds the chunk of device (d + step) % p.
        src = (d + step) % p
        acc = lax.dynamic_update_slice(acc, buf @ w, (src * s_loc, jnp.int32(0)))
        return acc

    acc0 = _pvary(
        jnp.zeros((p * s_loc, n), dtype=jnp.promote_types(x.dtype, w.dtype)),
        axis_name,
    )
    acc = two_lane_ring(p, x, acc0, compute, transfer, unroll=unroll)
    return acc.astype(jnp.promote_types(x.dtype, w.dtype))


def matmul_reducescatter(
    y: jax.Array,
    w: jax.Array,
    axis_name: str,
    *,
    unroll: int = 1,
) -> jax.Array:
    """Ring matmul-reduce-scatter: z[S/p, K] from y[S, N/p] and w[N/p, K].

    The partial product for one sequence chunk is computed per step and added
    to the accumulator ring-permuting toward its home device: compute lane
    produces chunk partials, transfer lane (ppermute) is the consumer carrying
    the running sum — the same SPSC ring with the roles mirrored.
    """
    p = _axis_size(axis_name)
    d = _axis_index(axis_name)
    s = y.shape[0]
    s_loc = s // p
    k = w.shape[1]
    perm = [(i, (i + 1) % p) for i in range(p)]

    # Chunk schedule: the in-flight buffer that will finally land on device
    # ``h`` sits on device ``(h + t) % p`` at step ``t``; a device holding it
    # must therefore contribute its partial for chunk ``(d - t) % p``. After
    # the add, the buffer permutes one hop toward home. The buffer *is* the
    # SPSC slot; compute lane produces partials, transfer lane consumes them.
    buf0 = _pvary(jnp.zeros((s_loc, k), dtype=jnp.float32), axis_name)  # f32 ring acc

    def body(step, buf):
        c = (d - step) % p
        y_chunk = lax.dynamic_slice(y, (c * s_loc, jnp.int32(0)), (s_loc, y.shape[1]))
        buf = buf + (y_chunk @ w).astype(buf.dtype)
        buf = lax.ppermute(buf, axis_name, perm)
        return buf

    buf = lax.fori_loop(0, p, body, buf0, unroll=unroll)
    return buf.astype(jnp.promote_types(y.dtype, w.dtype))


def allgather_matmul_gated(
    x: jax.Array,       # [S/p, K]   sequence-sharded activations (local)
    w_gate: jax.Array,  # [K, N/p]   column-sharded (local)
    w_up: jax.Array,    # [K, N/p]
    axis_name: str,
    *,
    act: str = "silu",
    unroll: int = 1,
) -> jax.Array:
    """Fused two-lane ring: one x-chunk transfer feeds BOTH gate and up
    matmuls (halves ring traffic vs two separate AG-matmuls); elementwise
    act(g)*u happens on the consumer lane. Output: [S, N/p]."""
    p = _axis_size(axis_name)
    d = _axis_index(axis_name)
    s_loc, k = x.shape
    n = w_gate.shape[1]
    perm = [(i, (i - 1) % p) for i in range(p)]

    def transfer(step, buf):
        del step
        return lax.ppermute(buf, axis_name, perm)

    def compute(step, buf, acc):
        src = (d + step) % p
        g = buf @ w_gate
        u = buf @ w_up
        if act == "silu":
            g = jax.nn.silu(g.astype(jnp.float32)).astype(g.dtype)
        elif act == "gelu":
            g = jax.nn.gelu(g.astype(jnp.float32)).astype(g.dtype)
        h = g * u
        return lax.dynamic_update_slice(acc, h, (src * s_loc, jnp.int32(0)))

    acc0 = _pvary(
        jnp.zeros((p * s_loc, n), dtype=jnp.promote_types(x.dtype, w_gate.dtype)),
        axis_name,
    )
    return two_lane_ring(p, x, acc0, compute, transfer, unroll=unroll)


def mlp_ring(cfg_act: str, x: jax.Array, w_gate, w_up, w_down,
             mesh, axis_name: str = "model", *, full_unroll: bool = False):
    """Relic-ring TP MLP over a sequence-sharded residual stream.

    x: [B, S(model-sharded), D]; weights Megatron column/row sharded on the
    model axis. One AG ring (fused gate+up) + one RS ring; every transfer
    overlaps the previous chunk's MXU work. Returns [B, S(model-sharded), D].

    full_unroll statically expands the ring (dry-run cost lowerings: XLA's
    HloCostAnalysis counts a rolled loop body once).
    """
    P = jax.sharding.PartitionSpec
    unroll = mesh.shape[axis_name] if full_unroll else 1

    def local(xl, wg, wu, wd):
        b, s_loc, k = xl.shape
        x2 = xl.reshape(b * s_loc, k)
        h = allgather_matmul_gated(x2, wg, wu, axis_name, act=cfg_act,
                                   unroll=unroll)
        out = matmul_reducescatter(h, wd, axis_name, unroll=unroll)
        return out.reshape(b, s_loc, wd.shape[1]).astype(xl.dtype)

    return compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, axis_name, None), P(None, axis_name),
                  P(None, axis_name), P(axis_name, None)),
        out_specs=P(None, axis_name, None),
        axis_names={axis_name},
    )(x, w_gate, w_up, w_down)


# --------------------------------------------------------------------------
# shard_map front-ends (mesh-level API used by the model code)
# --------------------------------------------------------------------------

def tp_allgather_matmul(
    x_sharded: jax.Array,
    w_col: jax.Array,
    mesh: jax.sharding.Mesh,
    axis_name: str = "model",
    *,
    overlapped: bool = True,
):
    """Mesh-level f-layer: x [.., S(model-sharded), K] @ w [K, N(model-sharded)]."""
    P = jax.sharding.PartitionSpec
    fn = allgather_matmul if overlapped else allgather_matmul_ref

    def local(x, w):
        return fn(x, w, axis_name)

    return compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis_name, None), P(None, axis_name)),
        out_specs=P(None, axis_name),
    )(x_sharded, w_col)


def tp_matmul_reducescatter(
    y: jax.Array,
    w_row: jax.Array,
    mesh: jax.sharding.Mesh,
    axis_name: str = "model",
    *,
    overlapped: bool = True,
):
    """Mesh-level g-layer: y [S, N(model-sharded)] @ w [N(model-sharded), K]."""
    P = jax.sharding.PartitionSpec
    fn = matmul_reducescatter if overlapped else matmul_reducescatter_ref

    def local(y, w):
        return fn(y, w, axis_name)

    return compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, axis_name), P(axis_name, None)),
        out_specs=P(axis_name, None),
    )(y, w_row)
