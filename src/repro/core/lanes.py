"""Two-lane static ring schedules — the device-scale Relic pattern.

The paper's runtime is a *static-role* producer/consumer pair connected by a
bounded queue. On a TPU chip the same shape appears wherever one engine feeds
another:

  * ICI ring:   ppermute (transfer lane) feeds the MXU (compute lane)
  * HBM ring:   DMA copies (transfer lane) feed VMEM tiles (compute lane)
  * host ring:  the Relic assistant thread feeds the main thread

``two_lane_ring`` encodes the schedule once: at ring step ``s`` the *transfer*
for step ``s+1`` is issued **before** the *compute* for step ``s`` consumes its
buffer, so a latency-hiding scheduler (TPU async collectives / DMA) can run
both lanes concurrently. The in-flight buffer is the SPSC queue with depth 1;
a depth-2 variant (``double buffered``) mirrors the paper's capacity>1 ring.

Everything is `jax.lax` control flow so it lowers under jit/shard_map.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


def two_lane_ring(
    n_steps: int,
    init_buffer: Any,
    init_acc: Any,
    compute: Callable[[int, Any, Any], Any],
    transfer: Callable[[int, Any], Any],
    *,
    unroll: int = 1,
) -> Any:
    """Run an ``n_steps`` static producer/consumer ring.

    Args:
      n_steps: ring length (e.g. number of devices along the sharded axis).
      init_buffer: the lane-shared buffer at step 0 (the "queue slot").
      init_acc: accumulator pytree.
      compute: ``(step, buffer, acc) -> acc`` — consumer lane.
      transfer: ``(step, buffer) -> next_buffer`` — producer lane (e.g. a
        ``ppermute`` or an async copy). Issued *before* compute of the same
        step so the two lanes overlap; its result is consumed at step+1.
      unroll: forwarded to ``lax.fori_loop`` for schedule-unrolling
        experiments (§Perf).

    Returns: final accumulator.
    """

    def body(step, carry):
        buf, acc = carry
        # Producer lane: issue the transfer for the *next* step first. The
        # value is independent of `acc`, so the scheduler may overlap it with
        # the consumer lane below (async collective / DMA start).
        nxt = transfer(step, buf)
        # Consumer lane: use the current buffer.
        acc = compute(step, buf, acc)
        return nxt, acc

    _, acc = jax.lax.fori_loop(0, n_steps, body, (init_buffer, init_acc), unroll=unroll)
    return acc


def two_lane_ring_db(
    n_steps: int,
    init_buffers: Tuple[Any, Any],
    init_acc: Any,
    compute: Callable[[int, Any, Any], Any],
    transfer: Callable[[int, Any], Any],
) -> Any:
    """Depth-2 (double-buffered) variant: transfer writes slot ``s+2``.

    Matches the paper's capacity>1 SPSC ring — the producer may run up to two
    steps ahead, which tolerates one full step of transfer latency jitter
    (the ICI/DMA analogue of scheduling-latency absorption).
    """

    def body(step, carry):
        (cur, ahead), acc = carry
        nxt = transfer(step, ahead)  # produce for step s+2
        acc = compute(step, cur, acc)
        return (ahead, nxt), acc

    _, acc = jax.lax.fori_loop(0, n_steps, body, (init_buffers, init_acc))
    return acc
