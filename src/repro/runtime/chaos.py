"""Chaos harness: seeded, deterministic fault injection for the runtime.

Robustness claims are only as good as the faults they were tested against,
and ad-hoc "kill a thread in a test" coverage rots. This module makes fault
injection a first-class, *reproducible* input to the existing machinery:

* :class:`ChaosSpec` — one frozen, validated description of a fault mix
  (task-raise rate, task-stall rate/duration, assistant-kill point,
  admission-burst intensity), parseable from the ``RELIC_CHAOS`` env var so
  CI can re-run a whole suite under a pinned fault plan.
* :class:`FaultPlan` — the seeded per-task decision stream. Decorating a
  task draws once from a private ``random.Random(seed)``: same spec, same
  submission order ⇒ byte-identical fault placement, every run.
* :class:`ChaosScheduler` — a scheduler-SPI *wrapper* substrate registered
  as ``"chaos"``: it decorates every submitted task per the plan and
  delegates everything else to an inner substrate from the registry
  (``spec.inner``, default ``relic``). Because registration makes it a
  peer of the real substrates, the conformance suite picks it up
  automatically (tests/test_schedulers_conformance.py's registry tripwire)
  and re-runs the *entire* observable contract under injected faults — the
  default spec is therefore semantics-preserving (stall-only: stalls delay
  a task but still run it; ``raise_rate`` defaults to 0 because a raise
  replaces the task's effect and only dedicated tests opt into that).
* :class:`KillSwitch` — arms the assistant-kill hook ``Relic`` exposes for
  tests (``_chaos_kill``, a ``None``-checked callable off the hot path):
  the assistant thread exits mid-loop after a chosen number of drained
  bursts, losing the popped burst — the deterministic "lane died with
  in-flight work" scenario the supervision layer must account for exactly.
* :class:`StageKillSwitch` — the same idea one stratum up, for *stream
  loop tasks*: a ``repro.stream.Stage`` consults its own ``_chaos_kill``
  hook once per popped item, and a fired switch kills the stage loop with
  that item popped but unprocessed — the deterministic "dead farm worker
  with in-flight tags" scenario the stream recovery layer (quarantine +
  re-emit, ``stream/farm.py``) must account for exactly.
* :class:`FsFaultInjector` — deterministic filesystem faults for the
  persistence layer: crash a ``CheckpointManager`` save at a *named*
  point (before serialization, between entry files, mid-``manifest.json``
  write — leaving a torn manifest — or between serialize and publish) by
  raising :class:`FsCrash`, a ``BaseException`` so it models a process
  death, not a handleable task error. Every crash-consistency path in
  ``checkpoint/manager.py`` is testable without timing games.

No module-level import of ``repro.core.schedulers`` (it imports the relic
family, which must stay importable without this module): the registry is
resolved lazily inside ``ChaosScheduler.__init__``, and registration of
the ``"chaos"`` name happens at the bottom of ``schedulers.py`` so the
registry is complete the moment it is importable.
"""

from __future__ import annotations

import os
import random
import time
from dataclasses import dataclass, replace
from typing import Any, Callable, Iterable, List, Optional, Tuple

__all__ = [
    "ChaosInjectedError",
    "ChaosSpec",
    "FaultPlan",
    "KillSwitch",
    "StageKillSwitch",
    "FsCrash",
    "FsFaultInjector",
    "ChaosScheduler",
    "plan_bursts",
]


class ChaosInjectedError(RuntimeError):
    """The error an injected task-raise fault throws. Its own type so
    assertions can distinguish injected faults from real bugs."""


@dataclass(frozen=True)
class ChaosSpec:
    """One validated, frozen fault mix.

    ``raise_rate`` / ``stall_rate`` are per-task probabilities (drawn from
    one seeded stream — see :class:`FaultPlan`); ``stall_s`` is the
    straggler stall duration; ``kill_after`` arms a :class:`KillSwitch`
    (``None`` = never kill); ``burst`` is the admission-burst intensity
    (max requests per burst for ``plan_bursts``); ``inner`` names the
    wrapped substrate for :class:`ChaosScheduler`.

    The defaults are deliberately *semantics-preserving* (mild stall-only)
    so the full conformance suite passes under them: a stalled task still
    runs, in order, with its real result and its real exception.
    """

    seed: int = 0
    raise_rate: float = 0.0
    stall_rate: float = 1.0 / 64.0
    stall_s: float = 0.0002
    kill_after: Optional[int] = None
    burst: int = 0
    inner: str = "relic"

    def __post_init__(self) -> None:
        for name in ("raise_rate", "stall_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v!r}")
        if self.raise_rate + self.stall_rate > 1.0:
            raise ValueError(
                "raise_rate + stall_rate must not exceed 1 "
                f"(got {self.raise_rate} + {self.stall_rate})")
        if self.stall_s < 0:
            raise ValueError(f"stall_s must be >= 0, got {self.stall_s!r}")
        if self.kill_after is not None and self.kill_after < 0:
            raise ValueError(
                f"kill_after must be None or >= 0, got {self.kill_after!r}")
        if self.burst < 0:
            raise ValueError(f"burst must be >= 0, got {self.burst!r}")

    @classmethod
    def from_env(cls) -> "ChaosSpec":
        """Parse ``RELIC_CHAOS`` (``key=value`` pairs, comma-separated,
        e.g. ``"seed=7,stall_rate=0.05,stall_s=0.001,inner=relic-pool"``).
        Unset/empty yields the defaults; unknown keys or malformed values
        raise ``ValueError`` (same discipline as every knob in
        ``repro.runtime.config``)."""
        raw = os.environ.get("RELIC_CHAOS")
        if not raw:
            return cls()
        spec = cls()
        for part in raw.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(
                    f"RELIC_CHAOS entries must be key=value, got {part!r}")
            key, _, value = part.partition("=")
            key = key.strip()
            value = value.strip()
            try:
                if key in ("seed", "burst"):
                    spec = replace(spec, **{key: int(value)})
                elif key in ("raise_rate", "stall_rate", "stall_s"):
                    spec = replace(spec, **{key: float(value)})
                elif key == "kill_after":
                    spec = replace(
                        spec,
                        kill_after=None if value == "none" else int(value))
                elif key == "inner":
                    spec = replace(spec, inner=value)
                else:
                    raise ValueError(
                        f"RELIC_CHAOS: unknown key {key!r}")
            except ValueError as e:
                if "unknown key" in str(e) or "must be" in str(e):
                    raise
                raise ValueError(
                    f"RELIC_CHAOS: bad value for {key!r}: {value!r}"
                ) from None
        return spec


class FaultPlan:
    """The seeded per-task fault stream for one scheduler instance.

    ``decorate(fn)`` draws exactly one uniform variate per task — in
    submission order, from a private ``Random(spec.seed)`` — and returns
    either ``fn`` itself (the common case: zero wrapping, zero overhead
    downstream), a *stall* wrapper (sleeps ``stall_s`` then runs ``fn``,
    preserving its result and exceptions), or a *raise* stub (replaces the
    task with :class:`ChaosInjectedError`; only specs that opted into
    ``raise_rate > 0`` see these). Counters record what was injected so
    tests can assert against the plan rather than re-deriving it.
    """

    def __init__(self, spec: ChaosSpec):
        self.spec = spec
        self._rng = random.Random(spec.seed)
        self.injected_raises = 0
        self.injected_stalls = 0

    def decorate(self, fn: Callable[..., Any]) -> Callable[..., Any]:
        r = self._rng.random()
        spec = self.spec
        if r < spec.raise_rate:
            self.injected_raises += 1
            idx = self.injected_raises

            def chaos_raise(*args: Any, **kwargs: Any) -> Any:
                raise ChaosInjectedError(f"injected task fault #{idx}")

            return chaos_raise
        if r < spec.raise_rate + spec.stall_rate:
            self.injected_stalls += 1
            stall = spec.stall_s

            def chaos_stall(*args: Any, **kwargs: Any) -> Any:
                time.sleep(stall)
                return fn(*args, **kwargs)

            return chaos_stall
        return fn


def plan_bursts(spec: ChaosSpec, total: int) -> List[int]:
    """Deterministic admission-burst sizes summing to ``total``: the
    seeded shape a bursty client drives the serve layer with (each burst
    uniform in ``[1, spec.burst]``; ``burst=0`` degrades to one-by-one).
    A separate stream from :class:`FaultPlan` (``seed + 1``) so bursting a
    workload does not shift its task-fault placement."""
    if total < 0:
        raise ValueError(f"total must be >= 0, got {total}")
    if spec.burst <= 1:
        return [1] * total
    rng = random.Random(spec.seed + 1)
    out: List[int] = []
    left = total
    while left > 0:
        n = min(left, rng.randint(1, spec.burst))
        out.append(n)
        left -= n
    return out


class KillSwitch:
    """Arms ``Relic``'s opt-in assistant-kill hook (``_chaos_kill``).

    The hook is a ``None``-checked callable the assistant loop consults
    once per drained burst, *after* popping it and *before* executing it —
    so firing kills the thread with the popped burst unexecuted and the
    deterministic lost count is exactly ``submitted - completed`` at the
    moment of death (what :class:`repro.core.relic_pool.LaneFailure`
    asserts). ``after_bursts`` bursts are allowed through first; the
    switch records what it did (``fired``, ``lost_tasks``) for tests."""

    def __init__(self, after_bursts: int = 0):
        if after_bursts < 0:
            raise ValueError(
                f"after_bursts must be >= 0, got {after_bursts}")
        self.after_bursts = after_bursts
        self.fired = False
        self.lost_tasks = 0
        self._seen = 0

    def __call__(self, batch_tasks: int) -> bool:
        if self.fired:
            return True
        if self._seen >= self.after_bursts:
            self.fired = True
            self.lost_tasks = batch_tasks
            return True
        self._seen += 1
        return False

    def arm(self, relic: Any) -> "KillSwitch":
        """Attach to a ``Relic`` (or a pool lane). The hook field is part
        of the runtime's test surface: a plain attribute, ``None`` in
        production, checked once per drained burst off the hot path."""
        relic._chaos_kill = self
        return self


class StageKillSwitch:
    """Arms a stream stage's opt-in loop-kill hook (``Stage._chaos_kill``).

    The :class:`KillSwitch` analogue for stream loop tasks: the stage's
    ``_run_loop`` consults the hook once per popped data item, *before*
    applying ``fn`` and before counting the item — so firing kills the
    loop (via ``SystemExit``, the "assistant died" escape class) with the
    popped item unprocessed, and the lost in-flight set is exactly what
    the dealt-minus-released accounting in ``stream/farm.py`` reports.
    ``after_items`` items are allowed through first. Records what it did
    (``fired``, ``fired_t``, ``killed_after``) for detection-latency
    measurements and test assertions.
    """

    def __init__(self, after_items: int = 0):
        if after_items < 0:
            raise ValueError(
                f"after_items must be >= 0, got {after_items}")
        self.after_items = after_items
        self.fired = False
        self.fired_t = 0.0
        self.killed_after = 0

    def __call__(self, items_seen: int) -> bool:
        if self.fired:
            return True
        if items_seen >= self.after_items:
            self.fired = True
            self.fired_t = time.perf_counter()
            self.killed_after = items_seen
            return True
        return False

    def arm(self, stage: Any) -> "StageKillSwitch":
        """Attach to a ``repro.stream.Stage`` (e.g. a farm worker). Same
        surface discipline as ``KillSwitch.arm``: a plain attribute,
        ``None`` in production, checked once per popped item."""
        stage._chaos_kill = self
        return self


class FsCrash(BaseException):
    """A simulated process death during a filesystem write.

    Deliberately **not** an ``Exception``: a real crash does not unwind
    into a task-level error handler, so the injector's escape must take
    the same route a killed thread takes — through a stream stage it kills
    the loop task ("save worker died mid-write"), through a synchronous
    save it propagates to the caller, and in both cases it leaves whatever
    partial on-disk state the chosen crash point implies.
    """


class FsFaultInjector:
    """Deterministic filesystem fault injection for ``CheckpointManager``.

    Armed via ``arm(mgr)`` (sets the manager's ``None``-checked
    ``_chaos_fs`` hook), the injector counts saves as they serialize and
    crashes the ``at_save``-th one (0-based) at a named point:

    * ``"serialize-start"`` — before anything is written (tmp dir empty);
    * ``"entry"`` — after ``at_index`` entry files are fully written, with
      the last one optionally truncated to ``torn_bytes`` (a mid-file
      kill) — tmp dir partially populated, no manifest;
    * ``"manifest"`` — mid-``manifest.json`` write: the first
      ``torn_bytes`` bytes land (default: half), then the crash — the
      torn-manifest case ``latest_step`` must skip-and-warn on;
    * ``"pre-publish"`` — serialization complete, crash before the atomic
      rename: a fully-formed ``.tmp`` dir that never becomes a step.

    Records ``fired`` / ``fired_at`` ``(point, save_index, step)`` so
    tests assert against what actually happened, not the plan.
    """

    POINTS = ("serialize-start", "entry", "manifest", "pre-publish")

    def __init__(self, crash_point: Optional[str] = None, at_save: int = 0,
                 at_index: int = 0, torn_bytes: Optional[int] = None):
        if crash_point is not None and crash_point not in self.POINTS:
            raise ValueError(
                f"crash_point must be one of {self.POINTS}, "
                f"got {crash_point!r}")
        if at_save < 0:
            raise ValueError(f"at_save must be >= 0, got {at_save}")
        if at_index < 0:
            raise ValueError(f"at_index must be >= 0, got {at_index}")
        if torn_bytes is not None and torn_bytes < 0:
            raise ValueError(
                f"torn_bytes must be None or >= 0, got {torn_bytes}")
        self.crash_point = crash_point
        self.at_save = at_save
        self.at_index = at_index
        self.torn_bytes = torn_bytes
        self.fired = False
        self.fired_at: Optional[Tuple[str, int, int]] = None
        self._save = -1       # bumped at each serialize-start
        self._entries = 0

    def arm(self, mgr: Any) -> "FsFaultInjector":
        """Attach to a ``CheckpointManager``. Same test-surface discipline
        as the kill switches: a plain ``_chaos_fs`` attribute, ``None`` in
        production, consulted at the named write points."""
        mgr._chaos_fs = self
        return self

    def _fire(self, point: str, step: int) -> None:
        self.fired = True
        self.fired_at = (point, self._save, step)
        raise FsCrash(
            f"chaos: simulated crash at {point!r} (save #{self._save}, "
            f"step {step})")

    def _armed(self, point: str) -> bool:
        return (not self.fired and self.crash_point == point
                and self._save == self.at_save)

    def at(self, point: str, step: int) -> None:
        """Crash-point probe (called by the manager's write path)."""
        if point == "serialize-start":
            self._save += 1
            self._entries = 0
        if self._armed(point):
            self._fire(point, step)

    def entry_written(self, path: Any, step: int) -> None:
        """Per-entry-file probe; fires after ``at_index`` complete files,
        truncating the last one to ``torn_bytes`` first (mid-file kill)."""
        if not self._armed("entry"):
            self._entries += 1
            return
        if self._entries < self.at_index:
            self._entries += 1
            return
        if self.torn_bytes is not None:
            data = path.read_bytes()
            path.write_bytes(data[: self.torn_bytes])
        self._fire("entry", step)

    def write_manifest(self, path: Any, text: str, step: int) -> None:
        """Manifest write-through; a ``"manifest"`` crash writes the torn
        prefix and dies, anything else writes the full text."""
        if self._armed("manifest"):
            keep = (len(text) // 2 if self.torn_bytes is None
                    else self.torn_bytes)
            path.write_text(text[:keep])
            self._fire("manifest", step)
        path.write_text(text)


class ChaosScheduler:
    """The ``"chaos"`` substrate: an SPI wrapper injecting a seeded fault
    plan into every task before delegating to an inner registry substrate.

    Pure delegation — lifecycle, misuse classification, stats, hints,
    ``workers``, bounded backpressure are all the inner substrate's own
    (so the conformance suite exercises *its* contract under faults, not a
    re-implementation). Only ``submit``/``submit_many`` add work: one RNG
    draw and (rarely) one closure per task.
    """

    def __init__(self, capacity: Optional[int] = None,
                 spec: Optional[ChaosSpec] = None, **inner_kwargs: Any):
        # Late import: the registry lives in schedulers.py, which imports
        # the relic family; importing it at module level here would cycle
        # through the registration at its bottom.
        from repro.core.schedulers import make_scheduler
        self.spec = spec if spec is not None else ChaosSpec.from_env()
        self.plan = FaultPlan(self.spec)
        if capacity is not None:
            inner_kwargs.setdefault("capacity", capacity)
        self._inner = make_scheduler(self.spec.inner, **inner_kwargs)

    @property
    def workers(self) -> int:
        return getattr(self._inner, "workers", 1)

    @property
    def _started(self) -> bool:
        # Lifecycle state must stay visible through the wrapper: callers
        # (e.g. run_wavefronts) duck-type on this before borrowing a
        # scheduler, and hiding it would let them adopt an unstarted one.
        return getattr(self._inner, "_started", True)

    @property
    def stats(self) -> Any:
        return self._inner.stats

    def start(self) -> "ChaosScheduler":
        self._inner.start()
        return self

    def submit(self, fn: Callable[..., Any], *args: Any,
               **kwargs: Any) -> None:
        self._inner.submit(self.plan.decorate(fn), *args, **kwargs)

    def submit_many(self, tasks: Iterable[Tuple[Callable[..., Any],
                                                tuple, dict]]) -> None:
        self._inner.submit_many(
            [(self.plan.decorate(fn), args, kwargs)
             for fn, args, kwargs in tasks])

    def wait(self) -> None:
        self._inner.wait()

    def sleep_hint(self) -> None:
        self._inner.sleep_hint()

    def wake_up_hint(self) -> None:
        self._inner.wake_up_hint()

    def close(self) -> None:
        self._inner.close()

    def __enter__(self) -> "ChaosScheduler":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.close()
