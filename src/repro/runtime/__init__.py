from repro.runtime.fault import (  # noqa: F401
    ElasticPlan,
    HeartbeatTracker,
    StragglerMonitor,
    plan_elastic_remesh,
)
