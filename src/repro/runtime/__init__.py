from repro.runtime.fault import (  # noqa: F401
    ElasticPlan,
    HeartbeatTracker,
    LaneSupervisor,
    StragglerMonitor,
    plan_elastic_remesh,
)
