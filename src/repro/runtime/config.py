"""Central resolver for env-var runtime knobs (alpa ``global_env.py`` shape).

Every tunable that the runtime reads from the environment lives here, in one
place, with one discipline: knobs are **re-read per instance** (a new
``Relic``/``RelicPool``/``ServeScheduler`` picks up the current environment),
never frozen at import time, so a CI container and a local SMT host can run
the same code path by exporting a variable instead of editing a module.

Two families:

``RELIC_SPIN_PAUSE_EVERY``
    The spin/yield cadence for the busy-wait loops (paper §VI-B). Moved here
    from ``repro.core.relic`` (which still re-exports it for back-compat).

``RELIC_SERVE_*``
    Knobs for the ``repro.serve`` request-serving subsystem:

    - ``RELIC_SERVE_ADMISSION``: ``block`` (default) or ``reject`` — what a
      client submit does when its SPSC request ring is full.
    - ``RELIC_SERVE_QUEUE_DEPTH``: per-client request-ring capacity
      (default 64).
    - ``RELIC_SERVE_BATCH_MAX``: max in-flight requests the continuous
      batcher keeps admitted at once (default 8).
    - ``RELIC_SERVE_DEADLINE_MS``: default per-request deadline in
      milliseconds; unset/empty means no deadline.
    - ``RELIC_SERVE_RETRIES``: max *extra* attempts the server grants an
      idempotent-marked request whose task erred or whose lane died
      (default 2; ``0`` disables retry).

``RELIC_SUPERVISE`` / ``RELIC_HEARTBEAT_MS``
    The liveness/supervision knobs (docs/robustness.md):

    - ``RELIC_SUPERVISE``: ``1`` (default) arms the bounded-wait liveness
      probes (every producer spin loop periodically checks
      ``assistant.is_alive()`` and raises ``RelicDeadError`` instead of
      hanging) and the pool's ``LaneSupervisor``; ``0`` restores the
      pre-supervision behaviour exactly (unbounded spins).
    - ``RELIC_HEARTBEAT_MS``: cadence (milliseconds, default 100) at which
      the ``LaneSupervisor`` samples per-lane progress heartbeats into
      ``HeartbeatTracker``/``StragglerMonitor``; a lane with outstanding
      work and no progress for one full period is flagged as stalled.

``RELIC_CKPT_CHECKSUM``
    Crash-consistency knob for ``repro.checkpoint``: ``1`` (default) makes
    ``CheckpointManager`` record a CRC32 per entry in the manifest and
    verify it on restore (falling back to the next-latest valid step on a
    mismatch); ``0`` skips both (the pre-PR-10 format, still restorable —
    entries without a checksum are simply not verified).

``resolve_serve_config()`` / ``resolve_supervise_config()`` /
``resolve_checkpoint_config()`` return frozen snapshots recorded in BENCH
meta alongside the spin cadence, so a recorded run's knob state is
reproducible.
"""

from __future__ import annotations

import os
from dataclasses import asdict, dataclass
from typing import Optional


def _default_spin_yield() -> int:
    """`pause`-cadence adaptation: the paper assumes two hardware contexts
    (SMT, §VI) — producer + assistant fit exactly one SMT core. Yield hot
    (every iteration) only when the two runtime threads actually outnumber
    the host's contexts, i.e. on a 1-context host, where spin-waiting
    starves the partner thread across the GIL. With 2+ contexts — the
    paper's own target shape included — spin mostly-hot and yield rarely.
    (The old threshold ``< 2 + 1`` misclassified a 2-context host as
    oversubscribed, forcing the paper's §VI scenario onto the
    yield-every-iteration cadence: the PR 6 bugfix.)"""
    return 1 if (os.cpu_count() or 1) < 2 else 64


def _positive_int(name: str, raw: str) -> int:
    try:
        value = int(raw)
    except ValueError:
        raise ValueError(
            f"{name} must be a positive int, got {raw!r}") from None
    if value <= 0:
        raise ValueError(f"{name} must be a positive int, got {raw!r}")
    return value


def resolve_spin_pause_every() -> int:
    """The spin/yield cadence for a *new* runtime instance: the
    ``RELIC_SPIN_PAUSE_EVERY`` env var when set (a positive int), else the
    cpu-count heuristic. Re-read per ``Relic``/``RelicPool``/worker
    instance — not frozen at import — so a 2-cpu CI container and a local
    SMT host can be benchmarked against the same code path by exporting
    one variable instead of editing the module."""
    raw = os.environ.get("RELIC_SPIN_PAUSE_EVERY")
    if raw is None or raw == "":
        return _default_spin_yield()
    return _positive_int("RELIC_SPIN_PAUSE_EVERY", raw)


_ADMISSION_POLICIES = ("block", "reject")


@dataclass(frozen=True)
class ServeConfig:
    """Resolved ``RELIC_SERVE_*`` knob snapshot for one serving instance."""

    admission: str = "block"
    queue_depth: int = 64
    batch_max: int = 8
    deadline_ms: Optional[float] = None
    retries: int = 2

    def asdict(self) -> dict:
        return asdict(self)


def resolve_serve_config(
    *,
    admission: Optional[str] = None,
    queue_depth: Optional[int] = None,
    batch_max: Optional[int] = None,
    deadline_ms: Optional[float] = None,
    retries: Optional[int] = None,
) -> ServeConfig:
    """Resolve the serving knobs for a *new* ``ServeScheduler``/``Ingest``.

    Explicit keyword arguments (from code or CLI flags) win over the
    environment; the environment wins over the defaults. Like
    ``resolve_spin_pause_every`` this is re-read per instance.
    """
    if admission is None:
        raw = os.environ.get("RELIC_SERVE_ADMISSION")
        admission = raw if raw else "block"
    if admission not in _ADMISSION_POLICIES:
        raise ValueError(
            "RELIC_SERVE_ADMISSION must be one of "
            f"{_ADMISSION_POLICIES}, got {admission!r}")

    if queue_depth is None:
        raw = os.environ.get("RELIC_SERVE_QUEUE_DEPTH")
        queue_depth = _positive_int(
            "RELIC_SERVE_QUEUE_DEPTH", raw) if raw else 64

    if batch_max is None:
        raw = os.environ.get("RELIC_SERVE_BATCH_MAX")
        batch_max = _positive_int(
            "RELIC_SERVE_BATCH_MAX", raw) if raw else 8

    if deadline_ms is None:
        raw = os.environ.get("RELIC_SERVE_DEADLINE_MS")
        if raw:
            try:
                deadline_ms = float(raw)
            except ValueError:
                raise ValueError(
                    "RELIC_SERVE_DEADLINE_MS must be a positive number, "
                    f"got {raw!r}") from None
    if deadline_ms is not None and deadline_ms <= 0:
        raise ValueError(
            "RELIC_SERVE_DEADLINE_MS must be a positive number, "
            f"got {deadline_ms!r}")

    if retries is None:
        raw = os.environ.get("RELIC_SERVE_RETRIES")
        retries = _non_negative_int(
            "RELIC_SERVE_RETRIES", raw) if raw else 2
    elif not isinstance(retries, int) or retries < 0:
        raise ValueError(
            f"RELIC_SERVE_RETRIES must be a non-negative int, got {retries!r}")

    return ServeConfig(
        admission=admission,
        queue_depth=queue_depth,
        batch_max=batch_max,
        deadline_ms=deadline_ms,
        retries=retries,
    )


def _non_negative_int(name: str, raw: str) -> int:
    try:
        value = int(raw)
    except ValueError:
        raise ValueError(
            f"{name} must be a non-negative int, got {raw!r}") from None
    if value < 0:
        raise ValueError(f"{name} must be a non-negative int, got {raw!r}")
    return value


_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


@dataclass(frozen=True)
class SuperviseConfig:
    """Resolved ``RELIC_SUPERVISE``/``RELIC_HEARTBEAT_MS`` knob snapshot
    for one runtime instance (a ``Relic``, a ``RelicPool``, a
    ``ServeScheduler``)."""

    supervise: bool = True
    heartbeat_ms: float = 100.0

    def asdict(self) -> dict:
        return asdict(self)


def resolve_supervise_config(
    *,
    supervise: Optional[bool] = None,
    heartbeat_ms: Optional[float] = None,
) -> SuperviseConfig:
    """Resolve the liveness-supervision knobs for a *new* runtime instance.

    Same discipline as ``resolve_serve_config``: explicit keyword arguments
    win over the environment, the environment wins over the defaults,
    invalid values raise ``ValueError``, and the result is re-read per
    instance (never frozen at import).
    """
    if supervise is None:
        raw = os.environ.get("RELIC_SUPERVISE")
        if raw is None or raw == "":
            supervise = True
        elif raw.strip().lower() in _TRUTHY:
            supervise = True
        elif raw.strip().lower() in _FALSY:
            supervise = False
        else:
            raise ValueError(
                f"RELIC_SUPERVISE must be one of {_TRUTHY + _FALSY}, "
                f"got {raw!r}")

    if heartbeat_ms is None:
        raw = os.environ.get("RELIC_HEARTBEAT_MS")
        if raw:
            try:
                heartbeat_ms = float(raw)
            except ValueError:
                raise ValueError(
                    "RELIC_HEARTBEAT_MS must be a positive number, "
                    f"got {raw!r}") from None
        else:
            heartbeat_ms = 100.0
    if heartbeat_ms <= 0:
        raise ValueError(
            "RELIC_HEARTBEAT_MS must be a positive number, "
            f"got {heartbeat_ms!r}")

    return SuperviseConfig(supervise=bool(supervise),
                           heartbeat_ms=float(heartbeat_ms))


@dataclass(frozen=True)
class CheckpointConfig:
    """Resolved ``RELIC_CKPT_CHECKSUM`` knob snapshot for one
    ``CheckpointManager`` instance."""

    checksum: bool = True

    def asdict(self) -> dict:
        return asdict(self)


def resolve_checkpoint_config(
    *,
    checksum: Optional[bool] = None,
) -> CheckpointConfig:
    """Resolve the checkpoint crash-consistency knobs for a *new* manager.

    Same discipline as the other resolvers: explicit keyword arguments win
    over the environment, the environment wins over the defaults, invalid
    values raise ``ValueError``, re-read per instance.
    """
    if checksum is None:
        raw = os.environ.get("RELIC_CKPT_CHECKSUM")
        if raw is None or raw == "":
            checksum = True
        elif raw.strip().lower() in _TRUTHY:
            checksum = True
        elif raw.strip().lower() in _FALSY:
            checksum = False
        else:
            raise ValueError(
                f"RELIC_CKPT_CHECKSUM must be one of {_TRUTHY + _FALSY}, "
                f"got {raw!r}")
    return CheckpointConfig(checksum=bool(checksum))
