"""Shared single-writer metrics primitives (percentiles, series, gauges).

Home of the nearest-rank percentile helpers, :class:`LatencySeries` and
:class:`Gauge`, moved here from ``repro.serve.metrics`` (PR 9) so the
streaming executor's per-stage latency/occupancy rows reuse them instead of
duplicating — the same move pattern as ``resolve_spin_pause_every``
migrating into ``repro.runtime.config`` (PR 7). ``repro.serve.metrics``
re-exports every name, identity-pinned by ``tests/test_runtime_metrics.py``,
so existing imports keep working unchanged.

Single-writer discipline mirrors ``RelicStats``/``RelicPoolStats``: every
mutator is called from exactly one thread (a scheduler loop, a stream-stage
loop), readers take racy-but-monotonic snapshots from any thread.
Percentiles use the **nearest-rank** definition (rank ``ceil(q/100 * n)``,
1-based into the sorted sample) — the classical textbook estimator, equal
to ``numpy.percentile(..., method="inverted_cdf")``, pinned against it by
``tests/test_serve.py`` on adversarial sizes (n=1, n=2, ties, all-equal).
Nearest-rank always returns an *observed* sample, which is what an SLO
report wants: "p99 = 4.1 ms" names a request that actually took 4.1 ms,
not an interpolation between two that didn't.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence

__all__ = ["nearest_rank", "percentiles", "LatencySeries", "Gauge"]


def nearest_rank(sorted_values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of an already-sorted non-empty sample.

    ``q`` in (0, 100]. Rank is ``ceil(q/100 * n)`` (1-based); q=0 is mapped
    to rank 1 so ``nearest_rank(xs, 0) == min(xs)``.
    """
    n = len(sorted_values)
    if n == 0:
        raise ValueError("nearest_rank of an empty sample")
    if not 0 <= q <= 100:
        raise ValueError(f"percentile must be in [0, 100], got {q!r}")
    rank = max(1, math.ceil(q / 100.0 * n))
    return sorted_values[rank - 1]


def percentiles(
    values: Sequence[float], qs: Sequence[float] = (50, 95, 99)
) -> Dict[float, float]:
    """Nearest-rank percentiles of an (unsorted) non-empty sample."""
    ordered = sorted(values)
    return {q: nearest_rank(ordered, q) for q in qs}


class LatencySeries:
    """Append-only latency sample series (seconds). Single writer; readers
    call ``snapshot()`` which copies before sorting so the writer is never
    blocked and a concurrent append can at worst be missed, not torn."""

    __slots__ = ("_values",)

    def __init__(self) -> None:
        self._values: List[float] = []

    def add(self, value: float) -> None:
        self._values.append(value)

    def __len__(self) -> int:
        return len(self._values)

    def snapshot(self) -> List[float]:
        return list(self._values)

    def percentiles(
        self, qs: Sequence[float] = (50, 95, 99)
    ) -> Dict[float, float]:
        return percentiles(self.snapshot(), qs)


@dataclass
class Gauge:
    """Last/min/max/mean of a sampled quantity (queue depth, batch
    occupancy, stage input-ring depth). Single writer; ``mean`` is
    total/samples."""

    last: float = 0.0
    min: float = math.inf
    max: float = -math.inf
    total: float = 0.0
    samples: int = 0

    def observe(self, value: float) -> None:
        self.last = value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        self.total += value
        self.samples += 1

    @property
    def mean(self) -> float:
        return self.total / self.samples if self.samples else 0.0

    def asdict(self) -> dict:
        if not self.samples:
            return {"last": 0.0, "min": 0.0, "max": 0.0, "mean": 0.0}
        return {
            "last": self.last, "min": self.min,
            "max": self.max, "mean": self.mean,
        }
