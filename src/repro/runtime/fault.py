"""Failure detection, straggler mitigation, and elastic planning.

At 1000+ nodes the failure model is: slow hosts (stragglers) degrade every
step (synchronous SPMD waits for the slowest); dead hosts stall the job until
it is re-gauged onto a smaller mesh from the last checkpoint. This module is
the host-side control plane for both, designed to run identically under
simulation (tests feed synthetic timings) and in production (hosts report
real step durations / heartbeats).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class StepStats:
    median: float
    mad: float
    worst_host: int
    worst_ratio: float


class StragglerMonitor:
    """Robust per-host step-time tracking (median/MAD z-scores).

    A host is flagged when its step time exceeds median + z*1.4826*MAD for
    `patience` consecutive windows — transient GC/network blips don't trip
    it, persistent slow HBM/thermal throttling does.
    """

    def __init__(self, n_hosts: int, window: int = 32, z: float = 4.0,
                 patience: int = 3):
        self.n_hosts = n_hosts
        self.window = window
        self.z = z
        self.patience = patience
        self._hist: List[deque] = [deque(maxlen=window) for _ in range(n_hosts)]
        self._strikes = [0] * n_hosts

    def record(self, host: int, seconds: float) -> None:
        self._hist[host].append(seconds)

    def record_step(self, durations: Sequence[float]) -> None:
        assert len(durations) == self.n_hosts
        for h, d in enumerate(durations):
            self.record(h, d)

    def _median(self, xs):
        s = sorted(xs)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def stats(self) -> Optional[StepStats]:
        means = [self._median(h) for h in self._hist if len(h)]
        if len(means) < self.n_hosts:
            return None
        med = self._median(means)
        mad = self._median([abs(m - med) for m in means]) or 1e-9
        worst = max(range(self.n_hosts), key=lambda h: means[h])
        return StepStats(median=med, mad=mad, worst_host=worst,
                         worst_ratio=means[worst] / med)

    def stragglers(self) -> List[int]:
        st = self.stats()
        if st is None:
            return []
        med, mad = st.median, st.mad
        out = []
        for h in range(self.n_hosts):
            m = self._median(self._hist[h])
            if m > med + self.z * 1.4826 * mad:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
            if self._strikes[h] >= self.patience:
                out.append(h)
        return out


class HeartbeatTracker:
    """Dead-host detection by heartbeat timeout."""

    def __init__(self, n_hosts: int, timeout_s: float = 60.0, clock=time.time):
        self.timeout = timeout_s
        self._clock = clock
        now = clock()
        self._last: Dict[int, float] = {h: now for h in range(n_hosts)}

    def beat(self, host: int, when: Optional[float] = None) -> None:
        self._last[host] = self._clock() if when is None else when

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = self._clock() if now is None else now
        return [h for h, t in self._last.items() if now - t > self.timeout]


@dataclass(frozen=True)
class ElasticPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_hosts: Tuple[int, ...]
    restore_step: Optional[int]


def plan_elastic_remesh(mesh_shape: Tuple[int, ...], axes: Tuple[str, ...],
                        dead_hosts: Sequence[int], chips_per_host: int,
                        restore_step: Optional[int]) -> ElasticPlan:
    """Shrink the outermost data-ish axis by whole host groups.

    Policy: the model axes ('model', and 'pod' topology) are fixed by the
    physical wiring; capacity is shed from the 'data' axis in units of hosts
    (each host contributes chips_per_host chips along 'data'). Training
    resumes from the last checkpoint resharded onto the new mesh
    (`repro.checkpoint.elastic_restore`)."""
    if not dead_hosts:
        return ElasticPlan(mesh_shape, mesh_shape, axes, (), restore_step)
    if "data" not in axes:
        raise ValueError("no data axis to shrink")
    di = axes.index("data")
    lost = len(set(dead_hosts))
    new = list(mesh_shape)
    # each lost host removes chips_per_host rows from the data axis
    new[di] = mesh_shape[di] - lost * chips_per_host
    if new[di] <= 0:
        raise RuntimeError("not enough surviving capacity for the model axes")
    return ElasticPlan(tuple(mesh_shape), tuple(new), tuple(axes),
                       tuple(sorted(set(dead_hosts))), restore_step)
