"""Failure detection, straggler mitigation, and elastic planning.

At 1000+ nodes the failure model is: slow hosts (stragglers) degrade every
step (synchronous SPMD waits for the slowest); dead hosts stall the job until
it is re-gauged onto a smaller mesh from the last checkpoint. This module is
the host-side control plane for both, designed to run identically under
simulation (tests feed synthetic timings) and in production (hosts report
real step durations / heartbeats).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class StepStats:
    median: float
    mad: float
    worst_host: int
    worst_ratio: float


class StragglerMonitor:
    """Robust per-host step-time tracking (median/MAD z-scores).

    A host is flagged when its step time exceeds median + z*1.4826*MAD for
    `patience` consecutive windows — transient GC/network blips don't trip
    it, persistent slow HBM/thermal throttling does.
    """

    def __init__(self, n_hosts: int, window: int = 32, z: float = 4.0,
                 patience: int = 3):
        self.n_hosts = n_hosts
        self.window = window
        self.z = z
        self.patience = patience
        self._hist: List[deque] = [deque(maxlen=window) for _ in range(n_hosts)]
        self._strikes = [0] * n_hosts

    def record(self, host: int, seconds: float) -> None:
        self._hist[host].append(seconds)

    def record_step(self, durations: Sequence[float]) -> None:
        assert len(durations) == self.n_hosts
        for h, d in enumerate(durations):
            self.record(h, d)

    def _median(self, xs):
        s = sorted(xs)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def stats(self) -> Optional[StepStats]:
        means = [self._median(h) for h in self._hist if len(h)]
        if len(means) < self.n_hosts:
            return None
        med = self._median(means)
        mad = self._median([abs(m - med) for m in means]) or 1e-9
        worst = max(range(self.n_hosts), key=lambda h: means[h])
        # A zero median (e.g. synthetic all-zero timings, or sub-resolution
        # clocks) must not divide: equal-zero means ratio 1.0 (nothing is
        # slower than anything), a nonzero worst over a zero median is
        # "infinitely slower".
        if med:
            ratio = means[worst] / med
        else:
            ratio = 1.0 if means[worst] == 0 else float("inf")
        return StepStats(median=med, mad=mad, worst_host=worst,
                         worst_ratio=ratio)

    def stragglers(self) -> List[int]:
        st = self.stats()
        if st is None:
            return []
        med, mad = st.median, st.mad
        out = []
        for h in range(self.n_hosts):
            m = self._median(self._hist[h])
            if m > med + self.z * 1.4826 * mad:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
            if self._strikes[h] >= self.patience:
                out.append(h)
        return out


class HeartbeatTracker:
    """Dead-host detection by heartbeat timeout."""

    def __init__(self, n_hosts: int, timeout_s: float = 60.0, clock=time.time):
        self.timeout = timeout_s
        self._clock = clock
        now = clock()
        self._last: Dict[int, float] = {h: now for h in range(n_hosts)}

    def beat(self, host: int, when: Optional[float] = None) -> None:
        self._last[host] = self._clock() if when is None else when

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = self._clock() if now is None else now
        return [h for h, t in self._last.items() if now - t > self.timeout]


@dataclass(frozen=True)
class ElasticPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_hosts: Tuple[int, ...]
    restore_step: Optional[int]


def plan_elastic_remesh(mesh_shape: Tuple[int, ...], axes: Tuple[str, ...],
                        dead_hosts: Sequence[int], chips_per_host: int,
                        restore_step: Optional[int]) -> ElasticPlan:
    """Shrink the outermost data-ish axis by whole host groups.

    Policy: the model axes ('model', and 'pod' topology) are fixed by the
    physical wiring; capacity is shed from the 'data' axis in units of hosts
    (each host contributes chips_per_host chips along 'data'). Training
    resumes from the last checkpoint resharded onto the new mesh
    (`repro.checkpoint.elastic_restore`)."""
    if chips_per_host <= 0:
        raise ValueError(
            f"chips_per_host must be positive, got {chips_per_host}")
    dead = list(dead_hosts)
    if any(h < 0 for h in dead):
        raise ValueError(f"dead_hosts must be non-negative, got {dead}")
    if len(set(dead)) != len(dead):
        # A duplicated host id is a reporting bug upstream: silently
        # deduplicating would shed less capacity than the caller asked for.
        raise ValueError(f"dead_hosts contains duplicates: {dead}")
    if not dead_hosts:
        return ElasticPlan(mesh_shape, mesh_shape, axes, (), restore_step)
    if "data" not in axes:
        raise ValueError("no data axis to shrink")
    di = axes.index("data")
    lost = len(set(dead_hosts))
    new = list(mesh_shape)
    # each lost host removes chips_per_host rows from the data axis
    new[di] = mesh_shape[di] - lost * chips_per_host
    if new[di] <= 0:
        raise RuntimeError("not enough surviving capacity for the model axes")
    return ElasticPlan(tuple(mesh_shape), tuple(new), tuple(axes),
                       tuple(sorted(set(dead_hosts))), restore_step)


class LaneSupervisor:
    """Progress-heartbeat supervision for a set of Relic lanes.

    The host-scale fault control plane wired to the Relic substrate
    (ROADMAP: ``fault.py`` was seed code until PR 8): ``RelicPool`` feeds
    each lane's existing ``_completed`` counter through this on a
    ``RELIC_HEARTBEAT_MS`` cadence, and the two seed detectors do the rest —
    :class:`HeartbeatTracker` turns "outstanding work but no progress for a
    full period" into a *stalled* flag, :class:`StragglerMonitor` turns a
    persistently slow per-task pace into a *straggler* flag.

    Deliberately passive and lane-agnostic: it holds no lane references,
    takes plain counter sequences, and never quarantines anything itself —
    liveness (``Thread.is_alive``) is the pool's own check, because a
    stalled lane may just be running one long task (which this class flags
    but cannot distinguish from a wedge; see docs/robustness.md for the
    failure model). Runs identically under a fake clock in tests.
    """

    def __init__(self, n_lanes: int, heartbeat_s: float = 0.1,
                 clock=time.monotonic, window: int = 8, z: float = 4.0,
                 patience: int = 3,
                 names: Optional[Sequence[str]] = None):
        if n_lanes <= 0:
            raise ValueError(f"n_lanes must be positive, got {n_lanes}")
        if heartbeat_s <= 0:
            raise ValueError(
                f"heartbeat_s must be positive, got {heartbeat_s}")
        if names is not None and len(names) != n_lanes:
            raise ValueError(
                f"names has {len(names)} entries for {n_lanes} lanes")
        self.n_lanes = n_lanes
        self.heartbeat_s = heartbeat_s
        #: Optional human-readable lane labels. A wiring layer that knows
        #: what the lanes *are* (Pipeline: its stages) fills this in if the
        #: caller didn't, so flag readouts can name the culprit.
        self.names: Optional[List[str]] = list(names) if names else None
        self._clock = clock
        # Two periods of silence before a lane counts as stalled: the sweep
        # cadence equals the period, so a one-period timeout would flap on
        # sampling-phase boundaries.
        self.tracker = HeartbeatTracker(n_lanes, timeout_s=2 * heartbeat_s,
                                        clock=clock)
        self.monitor = StragglerMonitor(n_lanes, window=window, z=z,
                                        patience=patience)
        self._completed = [0] * n_lanes
        self._last_sample_t = clock()

    def observe(self, completed: Sequence[int],
                outstanding: Sequence[int]) -> bool:
        """One supervision sweep: given each lane's completion counter and
        outstanding-task count, feed heartbeats and per-lane pace. Cheap to
        call often — it samples only once per heartbeat period (returns
        False when the period has not elapsed)."""
        now = self._clock()
        dt = now - self._last_sample_t
        if dt < self.heartbeat_s:
            return False
        self._last_sample_t = now
        for i in range(self.n_lanes):
            delta = completed[i] - self._completed[i]
            self._completed[i] = completed[i]
            if delta > 0:
                # Progressing: beat, and record the period's per-task pace
                # (inverse throughput) for the straggler detector.
                self.tracker.beat(i, when=now)
                self.monitor.record(i, dt / delta)
            elif outstanding[i] <= 0:
                # Idle is not dead and not slow: beat, record nothing.
                self.tracker.beat(i, when=now)
            else:
                # Outstanding work, zero progress: no beat (the stall
                # signal), and the whole silent period is its "pace".
                self.monitor.record(i, dt)
        return True

    def reset_lane(self, i: int) -> None:
        """Forget lane ``i``'s history: a respawned lane starts fresh (its
        completion counter restarts at zero, and inherited strikes would
        smear the dead predecessor's record onto its replacement)."""
        self._completed[i] = 0
        self.tracker.beat(i)
        self.monitor._hist[i].clear()
        self.monitor._strikes[i] = 0

    def stalled(self) -> List[int]:
        """Lanes with outstanding work and no progress for ~2 periods.
        Advisory: a long task and a wedged assistant look identical here."""
        return self.tracker.dead()

    def stragglers(self) -> List[int]:
        """Lanes persistently slower than their peers (median/MAD z-score
        over per-period pace, ``patience`` consecutive strikes)."""
        return self.monitor.stragglers()

    def _name(self, i: int) -> str:
        return self.names[i] if self.names else f"lane{i}"

    def stalled_names(self) -> List[str]:
        """:meth:`stalled`, mapped through ``names`` (``lane<i>`` when
        unnamed) — the readout a log line wants."""
        return [self._name(i) for i in self.stalled()]

    def straggler_names(self) -> List[str]:
        """:meth:`stragglers`, mapped through ``names``."""
        return [self._name(i) for i in self.stragglers()]
