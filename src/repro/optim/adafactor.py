"""Adafactor (Shazeer & Stern, 2018) — factored second moments.

The memory lever cited in §Roofline for capacity-red training cells: for a
matrix parameter [n, m], Adam keeps n·m second-moment entries; Adafactor
keeps n + m (row/column RMS factors), cutting optimizer state from
8 B/param (Adam mu+nu f32) to ~4 B/param (mu f32) + O((n+m)/nm). For
llama3-405b that is ~1.6 TB of state removed fleet-wide.

Implemented subset: factored v for rank>=2 params, full v for vectors,
update clipping by RMS (d=1.0), optional momentum (beta1>0 keeps mu — set
beta1=0.0 for the full memory win), relative step sizing OFF (we reuse the
framework's lr schedule for comparability with AdamW).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    decay: float = 0.8          # \hat{beta2}_t = 1 - t^{-decay}
    eps: float = 1e-30
    clip_threshold: float = 1.0
    beta1: float = 0.0          # 0 => no first moment (max memory savings)
    weight_decay: float = 0.0


def _factored(shape) -> bool:
    return len(shape) >= 2


def init_adafactor_state(params) -> dict:
    def one(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),       # row factor
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    state: dict[str, Any] = {"v": jax.tree.map(one, params,
                                               is_leaf=lambda x: hasattr(x, "shape"))}
    return state


def adafactor_update(ac: AdafactorConfig, grads, opt_state: dict, params,
                     step: jax.Array, lr: jax.Array):
    """Returns (new_params, new_opt_state)."""
    t = step.astype(jnp.float32) + 1.0
    beta2 = 1.0 - t ** (-ac.decay)

    def one(g, v, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + ac.eps
        if _factored(p.shape):
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
            # rank-1 reconstruction of the second moment
            denom = (vr / jnp.maximum(vr.mean(axis=-1, keepdims=True),
                                      ac.eps))[..., None] * vc[..., None, :]
            update = gf / jnp.sqrt(jnp.maximum(denom, ac.eps))
            new_v = {"vr": vr, "vc": vc}
        else:
            vv = beta2 * v["v"] + (1 - beta2) * g2
            update = gf / jnp.sqrt(jnp.maximum(vv, ac.eps))
            new_v = {"v": vv}
        # update clipping by RMS (the Adafactor stabilizer)
        rms = jnp.sqrt(jnp.mean(update * update))
        update = update / jnp.maximum(1.0, rms / ac.clip_threshold)
        if ac.weight_decay:
            update = update + ac.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), new_v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [one(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_v = tdef.unflatten([o[1] for o in out])
    return new_p, {"v": new_v}


def state_bytes(params, *, adam: bool) -> int:
    """Optimizer state footprint comparison (for the capacity analysis)."""
    import math

    total = 0
    for p in jax.tree.leaves(params):
        n = math.prod(p.shape)
        if adam:
            total += 2 * 4 * n                      # mu + nu f32
        else:
            if _factored(p.shape):
                rows = n // p.shape[-1]
                total += 4 * (rows + p.shape[-1])   # vr + vc
            else:
                total += 4 * n
    return total
