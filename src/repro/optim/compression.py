"""Gradient compression for scarce cross-pod bandwidth.

int8 block-quantized gradients with error feedback (EF-SGD style): the
quantization residual is carried to the next step, so the scheme is unbiased
in the long run and converges at the uncompressed rate for smooth objectives.
Intended placement: the `pod` axis all-reduce (DP between pods) where ICI is
slowest; intra-pod reduce-scatter stays full precision.

`compressed_psum` is the shard_map building block; `wrap_compressed` bolts EF
compression onto any grad pytree before the optimizer.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to(x: jax.Array, multiple: int) -> Tuple[jax.Array, int]:
    n = x.size
    rem = (-n) % multiple
    flat = x.reshape(-1)
    if rem:
        flat = jnp.concatenate([flat, jnp.zeros((rem,), x.dtype)])
    return flat, n


def quantize(x: jax.Array, block: int = BLOCK):
    """-> (q int8 [nb, block], scale f32 [nb, 1], orig_size). Blockwise
    symmetric max-scaling."""
    flat, n = _pad_to(x.astype(jnp.float32), block)
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    return q, scale, n


def dequantize(q: jax.Array, scale: jax.Array, n: int, shape, dtype=jnp.float32):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)


def compress_with_feedback(grads, residual):
    """EF step: g' = Q(g + r); r' = (g + r) - g'. Returns (g', r')."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s, n = quantize(gf)
        gq = dequantize(q, s, n, g.shape)
        return gq.astype(g.dtype), gf - gq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([p[0] for p in pairs]),
            tdef.unflatten([p[1] for p in pairs]))


def init_residual(grads_template):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Quantize -> all-reduce int32 partial sums -> rescale.

    Inside shard_map: each member contributes int8 levels against its own
    block scale; scales are all-reduduced alongside (sum of per-member
    contributions = exact sum of the dequantized members). Wire bytes/member:
    1 byte/elt + scales, vs 4 (f32) or 2 (bf16)."""
    q, scale, n = quantize(x)
    # all-gather the int8 levels (1 B/elt on the wire vs 8 B/elt for a ring
    # f32 all-reduce at pod count 2) + the tiny per-block scales, then reduce
    # locally against each member's own scale — numerically exact w.r.t. the
    # quantized contributions; quantization error itself is absorbed by the
    # caller's error feedback. The int8 payload is visible to the roofline's
    # collective-byte parse.
    qs = jax.lax.all_gather(q, axis_name)          # [P, nb, BLOCK] int8
    ss = jax.lax.all_gather(scale, axis_name)      # [P, nb, 1] f32
    total = jnp.sum(qs.astype(jnp.float32) * ss, axis=0)
    return total.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)
