from repro.optim.adamw import (  # noqa: F401
    OptConfig,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
    schedule,
)
from repro.optim.adafactor import (  # noqa: F401
    AdafactorConfig,
    adafactor_update,
    init_adafactor_state,
    state_bytes,
)
