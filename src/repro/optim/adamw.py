"""AdamW with decoupled weight decay, global-norm clipping, and a
warmup+cosine schedule. State is a plain dict mirroring the param tree so the
sharding rules in `repro.sharding` apply to it verbatim (ZeRO: optimizer
state inherits every param's 2D shard)."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False   # int8+error-feedback gradient compression
    grad_accum: int = 1            # microbatches per optimizer step


def schedule(oc: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = (step + 1.0) / jnp.maximum(oc.warmup_steps, 1)  # step 0 trains
    t = (step - oc.warmup_steps) / jnp.maximum(oc.total_steps - oc.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = oc.min_lr_ratio + (1 - oc.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return oc.peak_lr * jnp.where(step < oc.warmup_steps, warm, cos)


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jax.Array]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw_update(oc: OptConfig, grads, opt_state: dict, params, step: jax.Array):
    """Returns (new_params, new_opt_state, lr)."""
    lr = schedule(oc, step)
    stepf = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - oc.b1 ** stepf
    bc2 = 1.0 - oc.b2 ** stepf

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m = oc.b1 * m + (1 - oc.b1) * gf
        v = oc.b2 * v + (1 - oc.b2) * gf * gf
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["mu"])
    flat_v = tdef.flatten_up_to(opt_state["nu"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v}, lr
