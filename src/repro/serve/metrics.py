"""Streaming latency/throughput accounting for the serving subsystem.

The generic primitives — ``nearest_rank``/``percentiles``, ``LatencySeries``
and ``Gauge`` — live in :mod:`repro.runtime.metrics` (moved there in PR 9 so
the streaming executor's stage-latency/occupancy rows share them); this
module re-exports them unchanged, identity-pinned by
``tests/test_runtime_metrics.py`` — the same compatibility pattern as
``resolve_spin_pause_every`` re-exported from ``repro.core.relic`` after its
move into ``repro.runtime.config`` (PR 7). Existing
``from repro.serve.metrics import ...`` call sites keep working.

What stays here is the serving-specific aggregate: ``ServeMetrics``.
Single-writer discipline mirrors ``RelicStats``/``RelicPoolStats``: every
mutator is called from exactly one thread (the scheduler loop), readers take
racy-but-monotonic snapshots from any thread.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

from repro.runtime.metrics import (  # noqa: F401  (re-exports, identity-pinned)
    Gauge,
    LatencySeries,
    nearest_rank,
    percentiles,
)


@dataclass
class ServeMetrics:
    """Live counters + series for one ``ServeScheduler`` instance.

    All mutators run on the scheduler loop thread except ``note_rejected``
    (incremented per *client* on the client's own thread inside
    ``ClientHandle``, summed here at snapshot time — no shared counter on
    the submit hot path).
    """

    completed: int = 0          # responses finished, any status
    ok: int = 0
    errors: int = 0
    deadline_exceeded: int = 0  # ran (or was shed) past its deadline
    cancelled: int = 0          # still queued/in-flight at stop()
    admitted: int = 0

    queue_depth: Gauge = field(default_factory=Gauge)
    batch_occupancy: Gauge = field(default_factory=Gauge)

    latency: LatencySeries = field(default_factory=LatencySeries)
    queue_delay: LatencySeries = field(default_factory=LatencySeries)
    ttfr: LatencySeries = field(default_factory=LatencySeries)  # first result

    first_arrival_t: Optional[float] = None
    last_complete_t: Optional[float] = None

    def note_arrival(self, t: float) -> None:
        if self.first_arrival_t is None or t < self.first_arrival_t:
            self.first_arrival_t = t

    def note_complete(self, resp) -> None:
        """Fold a finished Response into the counters (loop thread only)."""
        self.completed += 1
        status = resp.status
        if status == "ok":
            self.ok += 1
        elif status == "error":
            self.errors += 1
        elif status == "deadline_exceeded":
            self.deadline_exceeded += 1
        else:
            self.cancelled += 1
        req = resp.request
        self.note_arrival(req.arrival_t)
        t = resp.complete_t
        if t is not None:
            if self.last_complete_t is None or t > self.last_complete_t:
                self.last_complete_t = t
            self.latency.add(t - req.arrival_t)
        if req.admit_t is not None:
            self.queue_delay.add(req.admit_t - req.arrival_t)
        if resp.first_result_t is not None:
            self.ttfr.add(resp.first_result_t - req.arrival_t)

    @property
    def throughput(self) -> float:
        """Completed requests per second over the observed span."""
        if (
            self.first_arrival_t is None
            or self.last_complete_t is None
            or self.last_complete_t <= self.first_arrival_t
        ):
            return 0.0
        return self.completed / (self.last_complete_t - self.first_arrival_t)

    def snapshot(self, rejected: int = 0) -> dict:
        """RelicPoolStats-style live snapshot (racy reads are fine — every
        field is a single reference/int assignment)."""
        lat = self.latency.snapshot()
        out = {
            "completed": self.completed,
            "ok": self.ok,
            "errors": self.errors,
            "deadline_exceeded": self.deadline_exceeded,
            "cancelled": self.cancelled,
            "admitted": self.admitted,
            "rejected": rejected,
            "throughput_rps": self.throughput,
            "queue_depth": self.queue_depth.asdict(),
            "batch_occupancy": self.batch_occupancy.asdict(),
        }
        if lat:
            ordered = sorted(lat)
            out["latency_s"] = {
                "p50": nearest_rank(ordered, 50),
                "p95": nearest_rank(ordered, 95),
                "p99": nearest_rank(ordered, 99),
                "mean": sum(ordered) / len(ordered),
                "n": len(ordered),
            }
        return out


def now() -> float:
    """The one clock the serving subsystem stamps with (monotonic)."""
    return time.perf_counter()
