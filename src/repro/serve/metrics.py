"""Streaming latency/throughput accounting for the serving subsystem.

Single-writer discipline mirrors ``RelicStats``/``RelicPoolStats``: every
mutator is called from exactly one thread (the scheduler loop), readers take
racy-but-monotonic snapshots from any thread. Percentiles use the
**nearest-rank** definition (rank ``ceil(q/100 * n)``, 1-based into the
sorted sample) — the classical textbook estimator, equal to
``numpy.percentile(..., method="inverted_cdf")``, pinned against it by
``tests/test_serve.py`` on adversarial sizes (n=1, n=2, ties, all-equal).
Nearest-rank always returns an *observed* sample, which is what an SLO
report wants: "p99 = 4.1 ms" names a request that actually took 4.1 ms,
not an interpolation between two that didn't.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence


def nearest_rank(sorted_values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of an already-sorted non-empty sample.

    ``q`` in (0, 100]. Rank is ``ceil(q/100 * n)`` (1-based); q=0 is mapped
    to rank 1 so ``nearest_rank(xs, 0) == min(xs)``.
    """
    n = len(sorted_values)
    if n == 0:
        raise ValueError("nearest_rank of an empty sample")
    if not 0 <= q <= 100:
        raise ValueError(f"percentile must be in [0, 100], got {q!r}")
    rank = max(1, math.ceil(q / 100.0 * n))
    return sorted_values[rank - 1]


def percentiles(
    values: Sequence[float], qs: Sequence[float] = (50, 95, 99)
) -> Dict[float, float]:
    """Nearest-rank percentiles of an (unsorted) non-empty sample."""
    ordered = sorted(values)
    return {q: nearest_rank(ordered, q) for q in qs}


class LatencySeries:
    """Append-only latency sample series (seconds). Single writer; readers
    call ``snapshot()`` which copies before sorting so the writer is never
    blocked and a concurrent append can at worst be missed, not torn."""

    __slots__ = ("_values",)

    def __init__(self) -> None:
        self._values: List[float] = []

    def add(self, value: float) -> None:
        self._values.append(value)

    def __len__(self) -> int:
        return len(self._values)

    def snapshot(self) -> List[float]:
        return list(self._values)

    def percentiles(
        self, qs: Sequence[float] = (50, 95, 99)
    ) -> Dict[float, float]:
        return percentiles(self.snapshot(), qs)


@dataclass
class Gauge:
    """Last/min/max/mean of a sampled quantity (queue depth, batch
    occupancy). Single writer; ``mean`` is total/samples."""

    last: float = 0.0
    min: float = math.inf
    max: float = -math.inf
    total: float = 0.0
    samples: int = 0

    def observe(self, value: float) -> None:
        self.last = value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        self.total += value
        self.samples += 1

    @property
    def mean(self) -> float:
        return self.total / self.samples if self.samples else 0.0

    def asdict(self) -> dict:
        if not self.samples:
            return {"last": 0.0, "min": 0.0, "max": 0.0, "mean": 0.0}
        return {
            "last": self.last, "min": self.min,
            "max": self.max, "mean": self.mean,
        }


@dataclass
class ServeMetrics:
    """Live counters + series for one ``ServeScheduler`` instance.

    All mutators run on the scheduler loop thread except ``note_rejected``
    (incremented per *client* on the client's own thread inside
    ``ClientHandle``, summed here at snapshot time — no shared counter on
    the submit hot path).
    """

    completed: int = 0          # responses finished, any status
    ok: int = 0
    errors: int = 0
    deadline_exceeded: int = 0  # ran (or was shed) past its deadline
    cancelled: int = 0          # still queued/in-flight at stop()
    admitted: int = 0

    queue_depth: Gauge = field(default_factory=Gauge)
    batch_occupancy: Gauge = field(default_factory=Gauge)

    latency: LatencySeries = field(default_factory=LatencySeries)
    queue_delay: LatencySeries = field(default_factory=LatencySeries)
    ttfr: LatencySeries = field(default_factory=LatencySeries)  # first result

    first_arrival_t: Optional[float] = None
    last_complete_t: Optional[float] = None

    def note_arrival(self, t: float) -> None:
        if self.first_arrival_t is None or t < self.first_arrival_t:
            self.first_arrival_t = t

    def note_complete(self, resp) -> None:
        """Fold a finished Response into the counters (loop thread only)."""
        self.completed += 1
        status = resp.status
        if status == "ok":
            self.ok += 1
        elif status == "error":
            self.errors += 1
        elif status == "deadline_exceeded":
            self.deadline_exceeded += 1
        else:
            self.cancelled += 1
        req = resp.request
        self.note_arrival(req.arrival_t)
        t = resp.complete_t
        if t is not None:
            if self.last_complete_t is None or t > self.last_complete_t:
                self.last_complete_t = t
            self.latency.add(t - req.arrival_t)
        if req.admit_t is not None:
            self.queue_delay.add(req.admit_t - req.arrival_t)
        if resp.first_result_t is not None:
            self.ttfr.add(resp.first_result_t - req.arrival_t)

    @property
    def throughput(self) -> float:
        """Completed requests per second over the observed span."""
        if (
            self.first_arrival_t is None
            or self.last_complete_t is None
            or self.last_complete_t <= self.first_arrival_t
        ):
            return 0.0
        return self.completed / (self.last_complete_t - self.first_arrival_t)

    def snapshot(self, rejected: int = 0) -> dict:
        """RelicPoolStats-style live snapshot (racy reads are fine — every
        field is a single reference/int assignment)."""
        lat = self.latency.snapshot()
        out = {
            "completed": self.completed,
            "ok": self.ok,
            "errors": self.errors,
            "deadline_exceeded": self.deadline_exceeded,
            "cancelled": self.cancelled,
            "admitted": self.admitted,
            "rejected": rejected,
            "throughput_rps": self.throughput,
            "queue_depth": self.queue_depth.asdict(),
            "batch_occupancy": self.batch_occupancy.asdict(),
        }
        if lat:
            ordered = sorted(lat)
            out["latency_s"] = {
                "p50": nearest_rank(ordered, 50),
                "p95": nearest_rank(ordered, 95),
                "p99": nearest_rank(ordered, 99),
                "mean": sum(ordered) / len(ordered),
                "n": len(ordered),
            }
        return out


def now() -> float:
    """The one clock the serving subsystem stamps with (monotonic)."""
    return time.perf_counter()
