"""Per-client SPSC ingest: the FastFlow construction applied to admission.

Every queue in the serving subsystem is strictly single-producer /
single-consumer — the same ``repro.core.spsc.SpscRing`` the Relic pair runs
on, composed into a fan-in network instead of replaced by a lock or an MPMC
queue (FastFlow's core claim, PAPERS.md):

    client thread ──SpscRing──▶ scheduler loop      (one ring per client)
    scheduler loop ──lane rings──▶ assistants       (RelicPool, existing)

The 1P1C contract is *enforced*, not just documented: a ``ClientHandle``
pins the first submitting thread's ident and raises ``ServeUsageError`` if
any other thread submits through the same handle (multi-threaded clients
open one handle per thread). The consumer side is single by construction —
only the ``ServeScheduler`` loop drains client rings.

Backpressure is bounded by the ring capacity (``RELIC_SERVE_QUEUE_DEPTH``)
with two admission policies (``RELIC_SERVE_ADMISSION``):

- ``block``  — the client spins (with ``sleep(0)`` yields at the Relic spin
  cadence) until a slot frees; closed-loop clients want this.
- ``reject`` — ``submit`` returns ``None`` immediately and the per-client
  ``rejected`` counter increments; open-loop load generators want this so
  offered load beyond capacity is *measured*, not silently queued.

Registration (``Ingest.open_client``) takes a lock; the submit/drain hot
paths never do.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.relic import _PROBE_EVERY_SPINS, RelicDeadError
from repro.core.spsc import SpscRing
from repro.runtime.config import (
    ServeConfig,
    resolve_serve_config,
    resolve_spin_pause_every,
)
from repro.serve.metrics import now
from repro.serve.request import Request, Response


class ServeUsageError(RuntimeError):
    """Raised on serving-API misuse (wrong-thread submit, closed ingest)."""


class RejectedError(RuntimeError):
    """Raised by ``submit(..., must_admit=True)`` when the ring is full
    under the ``reject`` policy."""


class ClientHandle:
    """One client's private lane into the server: a 1P1C ``SpscRing``.

    Producer: exactly one client thread (ident pinned on first submit).
    Consumer: the scheduler loop (via ``_drain``). The only shared state
    beyond the ring is the advisory parked-flag read used to wake a
    sleeping scheduler — same philosophy as ``Relic.wake_up_hint``.
    """

    def __init__(
        self,
        client_id: str,
        config: ServeConfig,
        wake: Callable[[], None],
        default_deadline_s: Optional[float],
        consumer_alive: Callable[[], bool] = lambda: True,
    ) -> None:
        self.client_id = client_id
        self._ring = SpscRing(config.queue_depth)
        self._admission = config.admission
        self._wake = wake
        self._consumer_alive = consumer_alive
        self._default_deadline_s = default_deadline_s
        self._spin_pause_every = resolve_spin_pause_every()
        self._producer_ident: Optional[int] = None
        self.rejected = 0          # written by the client thread only
        self.submitted = 0
        self._closed = False

    def _check_producer(self) -> None:
        ident = threading.get_ident()
        if self._producer_ident is None:
            self._producer_ident = ident
        elif ident != self._producer_ident:
            raise ServeUsageError(
                f"ClientHandle {self.client_id!r} is single-producer: "
                f"submit() called from thread {ident}, but the handle is "
                f"pinned to thread {self._producer_ident}. Open one handle "
                "per producing thread.")

    def submit(
        self,
        fn: Callable[..., Any],
        *args: Any,
        deadline_s: Optional[float] = None,
        must_admit: bool = False,
        idempotent: bool = False,
    ) -> Optional[Response]:
        """Enqueue one request; returns its ``Response`` future.

        Under the ``reject`` policy a full ring returns ``None`` (or raises
        ``RejectedError`` if ``must_admit``) and counts the rejection.
        Under ``block`` the call spins until a slot frees — a *bounded*
        wait: the spin probes the consumer's liveness at the same cadence
        as the Relic producer slow paths and raises ``RelicDeadError`` if
        the scheduler loop died (otherwise a full ring plus a dead server
        would hang the client forever).
        ``deadline_s`` is seconds-from-now; defaults to the configured
        ``RELIC_SERVE_DEADLINE_MS``. ``idempotent=True`` marks the request
        safe to re-run, opting it into server-side retry.
        """
        self._check_producer()
        if self._closed:
            raise ServeUsageError(
                f"ClientHandle {self.client_id!r} submitted after close")
        arrival = now()
        if deadline_s is None:
            deadline_s = self._default_deadline_s
        req = Request(
            rid=Request.next_rid(),
            client_id=self.client_id,
            fn=fn,
            args=args,
            arrival_t=arrival,
            deadline_t=None if deadline_s is None else arrival + deadline_s,
            idempotent=idempotent,
        )
        resp = Response(req)
        ring = self._ring
        if not ring.push(resp):
            if self._admission == "reject":
                self.rejected += 1
                if must_admit:
                    raise RejectedError(
                        f"client {self.client_id!r} ring full "
                        f"(depth {ring.capacity})")
                return None
            # block: bounded by the consumer making progress *or* dying.
            spins = 0
            pause_every = self._spin_pause_every
            while not ring.push(resp):
                spins += 1
                if spins % pause_every == 0:
                    time.sleep(0)
                if (spins % _PROBE_EVERY_SPINS == 0
                        and not self._consumer_alive()):
                    pending = len(self._ring)
                    raise RelicDeadError(
                        lane=f"serve:{self.client_id}",
                        submitted=self.submitted,
                        completed=self.submitted - pending,
                        lost=pending,
                    )
                self._wake()
        self.submitted += 1
        self._wake()
        return resp

    def close(self) -> None:
        self._closed = True

    # -- consumer side (scheduler loop only) ------------------------------

    def _drain(self, max_items: int) -> List[Response]:
        """Pop up to ``max_items`` pending responses (scheduler loop only)."""
        return self._ring.pop_many(max_items)

    def _pending(self) -> int:
        return len(self._ring)


class Ingest:
    """The fan-in network: all client handles for one scheduler.

    ``open_client`` is the only locked operation; the scheduler loop reads
    ``self._clients`` (a list, appended-to under the lock, never mutated in
    place) without locking — Python list append is atomic and the loop
    tolerates seeing a handle one poll late.
    """

    def __init__(
        self,
        config: Optional[ServeConfig] = None,
        wake: Callable[[], None] = lambda: None,
        consumer_alive: Callable[[], bool] = lambda: True,
    ) -> None:
        self.config = config or resolve_serve_config()
        self._wake = wake
        self._consumer_alive = consumer_alive
        self._default_deadline_s = (
            None if self.config.deadline_ms is None
            else self.config.deadline_ms / 1000.0)
        self._lock = threading.Lock()
        self._clients: List[ClientHandle] = []
        self._by_id: Dict[str, ClientHandle] = {}

    def open_client(self, client_id: Optional[str] = None) -> ClientHandle:
        with self._lock:
            if client_id is None:
                client_id = f"client-{len(self._clients)}"
            if client_id in self._by_id:
                raise ServeUsageError(
                    f"client id {client_id!r} already registered")
            handle = ClientHandle(
                client_id, self.config, self._wake,
                self._default_deadline_s,
                consumer_alive=self._consumer_alive)
            self._by_id[client_id] = handle
            # Publish last: the scheduler iterates self._clients lock-free.
            self._clients.append(handle)
            return handle

    @property
    def clients(self) -> Tuple[ClientHandle, ...]:
        return tuple(self._clients)

    def total_rejected(self) -> int:
        return sum(c.rejected for c in self._clients)

    def pending(self) -> int:
        """Racy total of requests sitting in client rings (observability)."""
        return sum(c._pending() for c in self._clients)

    def poll(self, budget: int) -> List[Response]:
        """Scheduler-loop-only: round-robin drain up to ``budget`` requests
        across client rings (at most a fair share per client per poll, so
        one hot client cannot starve the rest)."""
        clients = self._clients
        if not clients or budget <= 0:
            return []
        out: List[Response] = []
        share = max(1, budget // len(clients))
        for handle in clients:
            if len(out) >= budget:
                break
            out.extend(handle._drain(min(share, budget - len(out))))
        return out

    def close(self) -> None:
        for handle in self._clients:
            handle.close()
