"""Retry policy for the serving layer: bounded, backed-off, deterministic.

Serving on a fallible substrate needs a re-admission story: a request can
fail because *it* is buggy (retrying is wasted work) or because the lane
under it died (retrying is exactly right). The policy here is the standard
production shape — bounded attempts, exponential backoff, jitter — with two
repo-specific disciplines:

* **Opt-in by idempotency.** Only requests submitted with
  ``idempotent=True`` are ever retried: the server cannot know whether
  re-running a side-effecting thunk is safe, so the client declares it.
  Everything else fails fast on the first error (the PR 7 behaviour,
  unchanged).
* **Deterministic jitter.** The jitter term is seeded from
  ``(policy.seed, rid, attempt)``, not wall-clock entropy — two runs of
  the same workload back off identically, so fault-injection tests and the
  ``faults`` benchmark section are reproducible (the same discipline as
  ``repro.runtime.chaos``).

``max_attempts`` counts *total* executions (first try included), so the
``RELIC_SERVE_RETRIES`` knob — "how many extra attempts" — maps to
``max_attempts = retries + 1`` via :meth:`RetryPolicy.from_config`.
"""

from __future__ import annotations

import random
from dataclasses import dataclass

from repro.runtime.config import ServeConfig

__all__ = ["RetryPolicy"]


@dataclass(frozen=True)
class RetryPolicy:
    """Frozen retry parameters for one server instance.

    ``delay(rid, attempt)`` gives the backoff before re-admission
    ``attempt + 1`` of request ``rid`` (``attempt`` is the number of
    executions already spent, so the first retry passes ``attempt=1``):
    ``base_backoff_s * multiplier**(attempt-1)`` capped at
    ``max_backoff_s``, then scaled by a deterministic jitter factor in
    ``[1 - jitter, 1 + jitter]``.
    """

    max_attempts: int = 3
    base_backoff_s: float = 0.001
    multiplier: float = 2.0
    max_backoff_s: float = 0.050
    jitter: float = 0.5
    seed: int = 0

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.base_backoff_s < 0:
            raise ValueError(
                f"base_backoff_s must be >= 0, got {self.base_backoff_s}")
        if self.multiplier < 1.0:
            raise ValueError(
                f"multiplier must be >= 1, got {self.multiplier}")
        if self.max_backoff_s < self.base_backoff_s:
            raise ValueError(
                "max_backoff_s must be >= base_backoff_s "
                f"(got {self.max_backoff_s} < {self.base_backoff_s})")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(
                f"jitter must be in [0, 1), got {self.jitter}")

    @classmethod
    def from_config(cls, config: ServeConfig, seed: int = 0) -> "RetryPolicy":
        """Map the resolved ``RELIC_SERVE_RETRIES`` knob (extra attempts)
        onto a policy (total attempts)."""
        return cls(max_attempts=config.retries + 1, seed=seed)

    @property
    def retries(self) -> int:
        """Extra attempts beyond the first (the knob's unit)."""
        return self.max_attempts - 1

    def allows(self, attempts_spent: int) -> bool:
        """May a request that has already executed ``attempts_spent``
        times be re-admitted?"""
        return attempts_spent < self.max_attempts

    def delay(self, rid: int, attempt: int) -> float:
        """Seconds to wait before re-admission; deterministic per
        ``(seed, rid, attempt)``."""
        if attempt < 1:
            raise ValueError(f"attempt must be >= 1, got {attempt}")
        back = self.base_backoff_s * self.multiplier ** (attempt - 1)
        if back > self.max_backoff_s:
            back = self.max_backoff_s
        if self.jitter:
            # Mix the identifiers into one int seed (tuple hashes vary
            # less portably than plain arithmetic).
            mixed = (self.seed * 1_000_003 + rid) * 1_000_003 + attempt
            rng = random.Random(mixed)
            back *= 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
        return back
