"""Request/Response types for the serving subsystem.

``Response`` is a future with the same lazy-``Event`` publication pattern as
``repro.tasks.api.TaskHandle``: the completing thread writes the payload
fields *then* flips ``_done = True`` (the single publication point — CPython
guarantees the preceding writes are visible once the flag read returns
True), and a ``threading.Event`` is only allocated when someone actually
blocks in ``wait()``. A serving loop that polls ``done()`` on thousands of
in-flight responses therefore allocates zero synchronization objects.

Timestamps are ``time.perf_counter()`` seconds (see ``metrics.now``):

- ``arrival_t``   — stamped by the client at ``submit()`` time
- ``admit_t``     — stamped by the scheduler when the request leaves its
  client ring and joins the in-flight batch
- ``first_result_t`` — first streamed item for generator work (TTFT for the
  token-serving demo); equals completion for scalar work
- ``complete_t``  — stamped when the work function returns/raises
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

_rid_counter = itertools.count()

#: Terminal Response statuses.
STATUS_OK = "ok"
STATUS_ERROR = "error"
STATUS_DEADLINE = "deadline_exceeded"
STATUS_CANCELLED = "cancelled"


@dataclass
class Request:
    """One unit of client work: a blocking thunk plus its envelope.

    ``idempotent=True`` declares that re-running ``fn`` is safe; only such
    requests are eligible for server-side retry (task error or lane death
    — see ``repro.serve.retry``). The server cannot infer this, so the
    default is the conservative ``False``: fail fast, never re-run.
    """

    rid: int
    client_id: str
    fn: Callable[..., Any]
    args: Tuple = ()
    arrival_t: float = 0.0
    deadline_t: Optional[float] = None   # absolute perf_counter deadline
    admit_t: Optional[float] = None      # stamped by the scheduler
    idempotent: bool = False             # safe to re-run on failure

    @staticmethod
    def next_rid() -> int:
        return next(_rid_counter)


class Response:
    """Future for one request. Written by the scheduler side, read anywhere.

    ``status`` is one of ``"ok" | "error" | "deadline_exceeded" |
    "cancelled"`` once ``done()`` is True, else ``None``.
    """

    __slots__ = (
        "request", "_done", "status", "value", "error",
        "first_result_t", "complete_t", "_event", "_event_init_lock",
        "attempts", "_retry_pending", "_retry_error", "_retry_at",
    )

    def __init__(self, request: Request) -> None:
        self.request = request
        self._done = False
        self.status: Optional[str] = None
        self.value: Any = None
        self.error: Optional[BaseException] = None
        self.first_result_t: Optional[float] = None
        self.complete_t: Optional[float] = None
        self._event: Optional[threading.Event] = None
        self._event_init_lock = threading.Lock()
        # Retry bookkeeping (repro.serve.retry). A retry-eligible failure
        # is never published: _execute stores the error and flips
        # _retry_pending instead of calling _finish, so external waiters
        # keep waiting on the *same* future across attempts — there is no
        # reset race because done() never goes True-then-False. attempts
        # counts executions spent; the loop thread owns these fields.
        self.attempts = 0
        self._retry_pending = False
        self._retry_error: Optional[BaseException] = None
        self._retry_at = 0.0

    # -- completion side (scheduler/assistant threads) --------------------

    def _finish(
        self,
        status: str,
        value: Any = None,
        error: Optional[BaseException] = None,
        complete_t: Optional[float] = None,
    ) -> None:
        """Publish the result. Payload writes precede the ``_done`` flip;
        the flag is the publication point, the Event (if any waiter
        installed one) is only an advisory wake-up."""
        self.status = status
        self.value = value
        self.error = error
        self.complete_t = complete_t
        self._done = True
        event = self._event
        if event is not None:
            event.set()

    # -- consumer side ----------------------------------------------------

    def done(self) -> bool:
        return self._done

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until finished (or timeout). Returns ``done()``."""
        if self._done:
            return True
        if self._event is None:
            with self._event_init_lock:
                if self._event is None:
                    self._event = threading.Event()
        # Re-check *after* the event is visible: if _finish ran before the
        # install it saw no event to set, but it already flipped _done —
        # checking the flag after installing closes the lost-wakeup window
        # (same ordering as TaskHandle._wait).
        if self._done:
            return True
        self._event.wait(timeout)
        return self._done

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block for the value; raise the task's error / SLO violation."""
        if not self.wait(timeout):
            raise TimeoutError(
                f"request {self.request.rid} not done within {timeout}s")
        if self.status == STATUS_OK:
            return self.value
        if self.status == STATUS_ERROR:
            assert self.error is not None
            raise self.error
        raise RuntimeError(
            f"request {self.request.rid} finished with status "
            f"{self.status!r}")

    @property
    def latency(self) -> Optional[float]:
        """Arrival-to-complete seconds, once done."""
        if self.complete_t is None:
            return None
        return self.complete_t - self.request.arrival_t

    @property
    def queue_delay(self) -> Optional[float]:
        """Arrival-to-admission seconds, once admitted."""
        if self.request.admit_t is None:
            return None
        return self.request.admit_t - self.request.arrival_t

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = self.status if self._done else "pending"
        return (
            f"Response(rid={self.request.rid}, "
            f"client={self.request.client_id!r}, {state})")
