"""Closed-loop and open-loop (Poisson) load generators.

Two canonical load models (see docs/serving.md):

**Closed loop** — N clients, each submit → wait → repeat. Offered load
adapts to service rate, so it measures best-case latency and saturation
throughput; it cannot expose queueing collapse. Uses ``block`` admission.

**Open loop** — arrivals follow a schedule *independent* of completions
(here: Poisson, i.e. exponential inter-arrival gaps), the model that
surfaces tail latency under overload. Uses ``reject`` admission so offered
load beyond capacity is *measured* (rejected counter) rather than silently
deferred — the open-loop-waiting pitfall.

Schedules are generated from a seeded ``numpy`` Generator: same seed ⇒
byte-identical arrival schedule (pinned by tests), so a latency-vs-load
curve is reproducible run to run.

Each client thread owns exactly one ``ClientHandle`` — the 1P1C contract.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.request import Response
from repro.serve.scheduler import ServeScheduler


def poisson_arrivals(
    rate_rps: float, n: int, seed: int = 0
) -> np.ndarray:
    """Absolute arrival offsets (seconds from t0) for a Poisson process of
    ``rate_rps`` requests/second. Deterministic per seed."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be positive, got {rate_rps}")
    if n < 0:
        raise ValueError(f"n must be non-negative, got {n}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=1.0 / rate_rps, size=n)
    return np.cumsum(gaps)


@dataclass
class LoadResult:
    """What one load-generation run produced (responses + offered load)."""

    responses: List[Response] = field(default_factory=list)
    offered: int = 0
    rejected: int = 0
    wall_s: float = 0.0

    @property
    def completed(self) -> List[Response]:
        return [r for r in self.responses if r.done()]


def run_closed_loop(
    server: ServeScheduler,
    work: Callable[[], Tuple[Callable[..., Any], Tuple]],
    clients: int = 2,
    requests_per_client: int = 16,
    deadline_s: Optional[float] = None,
) -> LoadResult:
    """N closed-loop clients: submit → wait → repeat. ``work()`` is called
    per request (on the client thread) and returns the ``(fn, args)`` to
    submit — a factory, so generators/closures aren't shared across
    threads."""
    result = LoadResult()
    lock = threading.Lock()   # collects responses; never on the submit path
    t0 = time.perf_counter()

    def client_body(idx: int) -> None:
        handle = server.open_client(f"closed-{idx}")
        mine: List[Response] = []
        for _ in range(requests_per_client):
            fn, args = work()
            resp = handle.submit(fn, *args, deadline_s=deadline_s)
            assert resp is not None  # closed loop uses block admission
            resp.wait()
            mine.append(resp)
        with lock:
            result.responses.extend(mine)

    threads = [
        threading.Thread(target=client_body, args=(i,), daemon=True)
        for i in range(clients)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    result.offered = clients * requests_per_client
    result.wall_s = time.perf_counter() - t0
    return result


def run_open_loop(
    server: ServeScheduler,
    work: Callable[[], Tuple[Callable[..., Any], Tuple]],
    rate_rps: float,
    n_requests: int,
    seed: int = 0,
    deadline_s: Optional[float] = None,
    wait_for_all: bool = True,
) -> LoadResult:
    """One open-loop client submitting on a seeded Poisson schedule.

    The submit thread sleeps to each absolute arrival offset and fires
    regardless of completions. A full ring rejects (counted), it does not
    block — blocking would silently convert the open loop into a closed
    one and hide the overload it exists to measure.
    """
    schedule = poisson_arrivals(rate_rps, n_requests, seed)
    result = LoadResult()
    handle = server.open_client(f"open-{seed}")
    t0 = time.perf_counter()
    for offset in schedule:
        sleep_for = t0 + float(offset) - time.perf_counter()
        if sleep_for > 0:
            time.sleep(sleep_for)
        fn, args = work()
        resp = handle.submit(fn, *args, deadline_s=deadline_s)
        result.offered += 1
        if resp is None:
            continue
        result.responses.append(resp)
    if wait_for_all:
        for resp in result.responses:
            resp.wait()
    result.rejected = handle.rejected
    result.wall_s = time.perf_counter() - t0
    return result
