"""repro.serve: continuous-batching request serving on the Relic substrate.

The production form of the paper's "latency-critical" framing: a request
server whose every queue is the same lock-free SPSC ring the Relic pair
runs on (FastFlow's composition claim), whose batcher admits mid-stream
with no barrier between batches, and whose SLO accounting (nearest-rank
p50/p95/p99, deadlines surfaced as ``deadline_exceeded``) is first-class.

See docs/serving.md for the architecture and ``benchmarks/run.py --only
serve`` for the latency-vs-offered-load measurement.
"""

from repro.serve.ingest import (
    ClientHandle,
    Ingest,
    RejectedError,
    ServeUsageError,
)
from repro.serve.loadgen import (
    LoadResult,
    poisson_arrivals,
    run_closed_loop,
    run_open_loop,
)
from repro.serve.metrics import (
    Gauge,
    LatencySeries,
    ServeMetrics,
    nearest_rank,
    percentiles,
)
from repro.serve.request import (
    Request,
    Response,
    STATUS_CANCELLED,
    STATUS_DEADLINE,
    STATUS_ERROR,
    STATUS_OK,
)
from repro.serve.retry import RetryPolicy
from repro.serve.scheduler import ServeScheduler

__all__ = [
    "ClientHandle",
    "Gauge",
    "Ingest",
    "LatencySeries",
    "LoadResult",
    "RejectedError",
    "Request",
    "Response",
    "RetryPolicy",
    "STATUS_CANCELLED",
    "STATUS_DEADLINE",
    "STATUS_ERROR",
    "STATUS_OK",
    "ServeMetrics",
    "ServeScheduler",
    "ServeUsageError",
    "nearest_rank",
    "percentiles",
    "poisson_arrivals",
    "run_closed_loop",
    "run_open_loop",
]
