"""Continuous-batching scheduler loop on the Relic tasking substrate.

The serving shape of the paper's runtime: a single **scheduler loop thread**
owns a ``RelicPool``-backed scheduler (creates it, submits to it, closes it
— the pool's owner-thread contract) and runs the admit/dispatch/finalize
cycle:

1. **finalize** — observe ``Response.done()`` on in-flight requests (the
   assistant lanes publish via the lazy-Event flag; the loop never blocks
   on a barrier) and fold finished responses into ``ServeMetrics``;
2. **admit** — drain client SPSC rings up to the free batch budget
   (``RELIC_SERVE_BATCH_MAX`` minus in-flight), stamp ``admit_t``, shed
   requests whose deadline already passed (surfaced as
   ``deadline_exceeded``, never silently dropped), and submit the rest to
   the pool lanes via ``submit_many`` (lane striping + rebalance are the
   existing RelicPool machinery);
3. **park** — when idle long enough, publish a parked flag and sleep on an
   Event that ``ClientHandle.submit`` sets only when it observes the flag —
   the same advisory-hint philosophy as ``Relic.sleep_hint`` /
   ``wake_up_hint`` (paper §VI-B), so the submit hot path under load never
   touches the Event.

**Continuous batching** means there is no barrier between "batches": the
in-flight set is a sliding window. A request admitted while others are
running completes as soon as a lane finishes it — ``wait()`` is never
called on the pool while serving (RelicPool's fire-and-observe mode, whose
per-window error logs stay bounded by ring capacity).

Task errors are contained in ``_execute`` (the Response carries them);
a failed request never becomes a failed pool task, so the pool's
first-error-wins machinery stays quiet and serving continues.

**Retry & lane supervision (PR 8).** Requests submitted with
``idempotent=True`` are retried on failure under a deterministic
``RetryPolicy`` (bounded attempts, exponential backoff, seeded jitter): a
retry-eligible failure is never published — ``_execute`` marks the response
retry-pending and the loop re-admits it after the backoff, so the client
keeps waiting on the same future across attempts. On a ``RELIC_HEARTBEAT_MS``
cadence the loop polls the pool for dead lanes (``poll_lane_failures``);
when one died, recovery is *quiesce-then-diff*: stop admitting, let the
surviving lanes drain (``in_flight_estimate() → 0``, bounded), and the
in-flight responses that are neither finished nor retry-marked are exactly
the tasks the dead ring lost — idempotent ones are re-admitted, the rest
finish ``STATUS_ERROR`` carrying the ``LaneFailedError``. The pool itself
is constructed with ``respawn=True`` so capacity recovers. With
``RELIC_SUPERVISE=0`` all of this is off and the loop is byte-identical to
the PR 7 cycle.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

from repro.core.relic import RelicDeadError
from repro.core.relic_pool import LaneFailedError, LaneFailure
from repro.core.schedulers import make_scheduler
from repro.runtime.config import (
    ServeConfig,
    resolve_serve_config,
    resolve_spin_pause_every,
    resolve_supervise_config,
)
from repro.serve.ingest import ClientHandle, Ingest, ServeUsageError
from repro.serve.metrics import ServeMetrics, now
from repro.serve.request import (
    Response,
    STATUS_CANCELLED,
    STATUS_DEADLINE,
    STATUS_ERROR,
    STATUS_OK,
)
from repro.serve.retry import RetryPolicy

# Idle loop iterations (no finalize, no admit) before the loop parks on the
# wake Event. Large enough that a loaded server never parks; small enough
# that an idle one stops burning the host within ~a millisecond.
_PARK_AFTER_IDLE_SPINS = 256
# Park timeout: an advisory-hint backstop, not the wake mechanism (the
# Event is); bounds stop() latency if every hint is missed.
_PARK_TIMEOUT_S = 0.05


class ServeScheduler:
    """Request server: per-client SPSC ingest → continuous batcher → lanes.

    Usage::

        with ServeScheduler(lanes=2) as server:
            client = server.open_client()
            resp = client.submit(fn, arg)
            value = resp.result()

    ``lanes=0`` runs a degenerate inline mode (admit → execute on the loop
    thread) used for tests that want serving semantics without threads.
    """

    def __init__(
        self,
        lanes: int = 2,
        capacity: Optional[int] = None,
        config: Optional[ServeConfig] = None,
        scheduler: str = "relic-pool",
        retry_policy: Optional[RetryPolicy] = None,
    ) -> None:
        if lanes < 0:
            raise ValueError(f"lanes must be >= 0, got {lanes}")
        self.lanes = lanes
        self._capacity = capacity
        self._scheduler_name = scheduler
        self.config = config or resolve_serve_config()
        self.retry_policy = retry_policy or RetryPolicy.from_config(
            self.config)
        sup = resolve_supervise_config()
        self._supervise = sup.supervise
        self._sweep_period_s = sup.heartbeat_ms / 1000.0
        self.metrics = ServeMetrics()
        self._wake_event = threading.Event()
        self._parked = False
        self.ingest = Ingest(self.config, wake=self._wake_from_client,
                             consumer_alive=self._loop_alive)
        # Robustness counters: loop-thread written, read by stats().
        self._retry_count = 0
        self._lane_failure_count = 0
        self._lost_requests = 0
        self._lane_health: Dict[str, tuple] = {
            "stalled": (), "stragglers": ()}
        self._in_flight: Dict[int, Response] = {}
        self._stop_requested = False
        self._drain_on_stop = True
        self._started = False
        self._closed = False
        self._loop_thread: Optional[threading.Thread] = None
        self._loop_error: Optional[BaseException] = None
        self._ready = threading.Event()
        # The loop thread's scheduler, exposed for fault-injection tests
        # and the faults benchmark (kill-a-lane needs a handle on the live
        # pool). Owned by the loop thread: foreign threads may only arm
        # chaos hooks / read telemetry through it, never submit.
        self._sched: Optional[Any] = None

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "ServeScheduler":
        if self._started:
            raise ServeUsageError("ServeScheduler.start() called twice")
        self._started = True
        self._loop_thread = threading.Thread(
            target=self._loop, name="serve-scheduler", daemon=True)
        self._loop_thread.start()
        self._ready.wait()
        if self._loop_error is not None:
            raise self._loop_error
        return self

    def stop(self, drain: bool = True) -> None:
        """Shut down. ``drain=True`` finishes everything already submitted
        or queued; ``drain=False`` cancels queued requests (in-flight work
        still completes — lanes cannot be preempted)."""
        if not self._started or self._closed:
            return
        self._closed = True
        self.ingest.close()
        self._drain_on_stop = drain
        self._stop_requested = True
        self._wake_event.set()
        assert self._loop_thread is not None
        self._loop_thread.join()
        if self._loop_error is not None:
            raise self._loop_error

    def __enter__(self) -> "ServeScheduler":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    # -- client side -------------------------------------------------------

    def open_client(self, client_id: Optional[str] = None) -> ClientHandle:
        return self.ingest.open_client(client_id)

    def stats(self) -> dict:
        """Live snapshot (callable from any thread, racy-but-consistent)."""
        snap = self.metrics.snapshot(rejected=self.ingest.total_rejected())
        snap["lanes"] = self.lanes
        snap["in_flight"] = len(self._in_flight)
        snap["pending"] = self.ingest.pending()
        snap["config"] = self.config.asdict()
        # Robustness telemetry (PR 8): retry volume, lane failures observed
        # and requests they lost, plus the latest supervision sweep's lane
        # health (stalled/straggler lane indexes — cached by the loop
        # thread so foreign readers never touch the supervisor's state).
        snap["retries"] = self._retry_count
        snap["lane_failures"] = self._lane_failure_count
        snap["lost_requests"] = self._lost_requests
        snap["stalled_lanes"] = list(self._lane_health["stalled"])
        snap["straggler_lanes"] = list(self._lane_health["stragglers"])
        snap["supervise"] = self._supervise
        return snap

    def _loop_alive(self) -> bool:
        """Is the scheduler loop still able to drain client rings? Used by
        the bounded block-admission wait in ``ClientHandle.submit``."""
        if not self._started:
            return False
        t = self._loop_thread
        return t is not None and t.is_alive()

    # -- wake hint (client threads) ---------------------------------------

    def _wake_from_client(self) -> None:
        # One flag read per submit; Event.set only on park transitions —
        # the loaded hot path never touches the Event.
        if self._parked:
            self._wake_event.set()

    # -- execution (assistant lanes) --------------------------------------

    def _execute(self, resp: Response) -> None:
        """Run one request on a pool lane. Never raises: the Response is
        the error channel, so a failing request cannot poison the lane."""
        req = resp.request
        first_t: Optional[float] = None
        try:
            value = req.fn(*req.args)
            if hasattr(value, "__next__"):
                # Streaming work: the first yielded item stamps
                # first-result time (TTFT for token serving); the
                # response value is the collected stream.
                items = []
                for item in value:
                    if first_t is None:
                        first_t = now()
                        resp.first_result_t = first_t
                    items.append(item)
                value = items
            t = now()
            if first_t is None:
                resp.first_result_t = t
            status = STATUS_OK
            if req.deadline_t is not None and t > req.deadline_t:
                status = STATUS_DEADLINE
            resp._finish(status, value=value, complete_t=t)
        except BaseException as exc:  # noqa: BLE001 - the future carries it
            if (req.idempotent
                    and self.retry_policy.allows(resp.attempts)
                    and (req.deadline_t is None or now() <= req.deadline_t)):
                # Retry-eligible: do NOT publish. Store the error, flip the
                # retry flag (in that order — the flag is the publication
                # point for the loop thread), and let the loop re-admit
                # after backoff. The client keeps waiting on this future.
                resp._retry_error = exc
                resp._retry_pending = True
            else:
                resp._finish(STATUS_ERROR, error=exc, complete_t=now())

    # -- scheduler loop ----------------------------------------------------

    def _dispatch(self, sched: Any, submits: List[tuple],
                  supervised: bool) -> bool:
        """Push a batch at the substrate. Returns True if the substrate
        reported lane death mid-dispatch (recoverable when supervised: the
        quiesce-then-diff sweep classifies every in-flight response,
        including any of this batch that never reached a ring)."""
        if sched is None:
            for fn, args, _ in submits:
                fn(*args)
            return False
        try:
            sched.submit_many(submits)
        except RelicDeadError:
            if not supervised:
                raise
            return True
        return False

    def _recover_lane_failures(
        self,
        sched: Any,
        failures: List[LaneFailure],
        in_flight: Dict[int, Response],
        retry_queue: List[Response],
        metrics: ServeMetrics,
    ) -> None:
        """Quiesce-then-diff lane-death recovery (loop thread only).

        Stop admitting, let the surviving lanes drain everything still
        live (``in_flight_estimate()`` counts submitted-but-unfinished
        tasks pool-wide, with the quarantined ring's losses already
        subtracted — it reaches zero exactly when every *surviving* task
        has published). The in-flight responses that are then neither
        finished nor retry-marked are precisely the ones the dead ring
        lost: idempotent ones re-enter via the retry queue, the rest
        finish ``STATUS_ERROR`` carrying the ``LaneFailedError``.
        """
        self._lane_failure_count += len(failures)
        deadline = now() + 5.0
        while sched.in_flight_estimate() > 0 and now() < deadline:
            more = sched.poll_lane_failures()
            if more:
                self._lane_failure_count += len(more)
                failures.extend(more)
            time.sleep(0)
        err = LaneFailedError(tuple(failures))
        policy = self.retry_policy
        t = now()
        for resp in list(in_flight.values()):
            if resp.done() or resp._retry_pending:
                continue
            req = resp.request
            del in_flight[req.rid]
            self._lost_requests += 1
            if (req.idempotent and policy.allows(resp.attempts)
                    and (req.deadline_t is None or t <= req.deadline_t)):
                resp._retry_error = err
                resp._retry_at = t + policy.delay(req.rid, resp.attempts)
                retry_queue.append(resp)
                self._retry_count += 1
            else:
                resp._finish(STATUS_ERROR, error=err, complete_t=t)
                metrics.note_complete(resp)

    def _loop(self) -> None:
        sched = None
        try:
            if self.lanes > 0:
                kwargs: Dict[str, Any] = {"lanes": self.lanes}
                if self._capacity is not None:
                    kwargs["capacity"] = self._capacity
                try:
                    # Pool-family substrates grow capacity back after a
                    # lane death; substrates without the kwarg (the plain
                    # pair, thread pools) reject it and are built as-is.
                    sched = make_scheduler(
                        self._scheduler_name, respawn=True, **kwargs)
                except TypeError:
                    sched = make_scheduler(self._scheduler_name, **kwargs)
                sched.start()
                self._sched = sched
        except BaseException as exc:  # noqa: BLE001 - surface via start()
            self._loop_error = exc
            self._ready.set()
            return
        self._ready.set()

        metrics = self.metrics
        ingest = self.ingest
        in_flight = self._in_flight
        batch_max = self.config.batch_max
        pause_every = resolve_spin_pause_every()
        policy = self.retry_policy
        retry_queue: List[Response] = []
        supervised = (self._supervise and sched is not None
                      and hasattr(sched, "poll_lane_failures"))
        next_sweep_t = now() + self._sweep_period_s if supervised else 0.0
        idle_spins = 0
        try:
            while True:
                progressed = False

                # 1. finalize: observe completions without any barrier, and
                # collect retry-marked failures for backed-off re-admission.
                if in_flight:
                    done: List[Response] = []
                    marked: List[Response] = []
                    for r in in_flight.values():
                        if r.done():
                            done.append(r)
                        elif r._retry_pending:
                            marked.append(r)
                    for resp in done:
                        del in_flight[resp.request.rid]
                        metrics.note_complete(resp)
                    if marked:
                        t = now()
                        for resp in marked:
                            resp._retry_pending = False
                            del in_flight[resp.request.rid]
                            resp._retry_at = t + policy.delay(
                                resp.request.rid, resp.attempts)
                            retry_queue.append(resp)
                            self._retry_count += 1
                    if done or marked:
                        progressed = True

                # 2a. re-admit: due retries rejoin the window ahead of new
                # arrivals (they have already burned queue + lane time).
                if retry_queue:
                    t = now()
                    budget = batch_max - len(in_flight)
                    if budget > 0 and any(
                            r._retry_at <= t for r in retry_queue):
                        due: List[Response] = []
                        later: List[Response] = []
                        for r in retry_queue:
                            if r._retry_at <= t and len(due) < budget:
                                due.append(r)
                            else:
                                later.append(r)
                        retry_queue[:] = later
                        progressed = True
                        submits = []
                        for resp in due:
                            req = resp.request
                            if (req.deadline_t is not None
                                    and t > req.deadline_t):
                                # Out of time: surface the *failure* (more
                                # informative than the deadline it caused).
                                resp._finish(STATUS_ERROR,
                                             error=resp._retry_error,
                                             complete_t=t)
                                metrics.note_complete(resp)
                                continue
                            resp.attempts += 1
                            resp.first_result_t = None
                            in_flight[req.rid] = resp
                            submits.append((self._execute, (resp,), {}))
                        if submits and self._dispatch(
                                sched, submits, supervised):
                            next_sweep_t = 0.0

                # 2b. admit: fill the sliding window mid-stream.
                budget = batch_max - len(in_flight)
                if budget > 0:
                    batch = ingest.poll(budget)
                    if batch:
                        progressed = True
                        t = now()
                        submits = []
                        for resp in batch:
                            req = resp.request
                            req.admit_t = t
                            metrics.admitted += 1
                            if (req.deadline_t is not None
                                    and t > req.deadline_t):
                                # Shed without running: the SLO violation
                                # is surfaced, the lane time is not spent.
                                resp._finish(STATUS_DEADLINE, complete_t=t)
                                metrics.note_complete(resp)
                                continue
                            resp.attempts += 1
                            in_flight[req.rid] = resp
                            submits.append((self._execute, (resp,), {}))
                        if submits and self._dispatch(
                                sched, submits, supervised):
                            next_sweep_t = 0.0
                        metrics.queue_depth.observe(ingest.pending())
                        metrics.batch_occupancy.observe(len(in_flight))

                # 2c. supervise: poll lane liveness/health on the heartbeat
                # cadence; dead lanes trigger quiesce-then-diff recovery.
                if supervised and now() >= next_sweep_t:
                    next_sweep_t = now() + self._sweep_period_s
                    failures = sched.poll_lane_failures()
                    self._lane_health = {
                        "stalled": tuple(sched.stalled_lanes()),
                        "stragglers": tuple(sched.straggler_lanes()),
                    }
                    if failures:
                        self._recover_lane_failures(
                            sched, list(failures), in_flight, retry_queue,
                            metrics)
                        progressed = True

                if self._stop_requested:
                    if not self._drain_on_stop:
                        break
                    if (not in_flight and not ingest.pending()
                            and not retry_queue):
                        break

                if progressed:
                    idle_spins = 0
                    continue

                # 3. idle: spin briefly, then park on the wake Event.
                idle_spins += 1
                if idle_spins % pause_every == 0:
                    time.sleep(0)
                if (idle_spins >= _PARK_AFTER_IDLE_SPINS and not in_flight
                        and not retry_queue):
                    self._wake_event.clear()
                    self._parked = True
                    try:
                        # Double-check after publishing the flag: a submit
                        # that missed it must be visible in the rings now.
                        if not ingest.pending() and not self._stop_requested:
                            if sched is not None:
                                sched.sleep_hint()
                            self._wake_event.wait(_PARK_TIMEOUT_S)
                            if sched is not None:
                                sched.wake_up_hint()
                    finally:
                        self._parked = False
                    idle_spins = 0
        except BaseException as exc:  # noqa: BLE001 - surface via stop()
            self._loop_error = exc
        finally:
            # Cancel whatever the stop mode left behind (queued requests on
            # drain=False, everything on a loop error).
            for resp in ingest.poll(1 << 30):
                resp._finish(STATUS_CANCELLED, complete_t=now())
                metrics.note_complete(resp)
            # Pending retries are not re-run once the loop is exiting: they
            # finish with the failure that queued them (drain=True never
            # reaches here with a non-empty queue — the stop condition
            # waits it out).
            for resp in retry_queue:
                resp._finish(STATUS_ERROR, error=resp._retry_error,
                             complete_t=now())
                metrics.note_complete(resp)
            retry_queue.clear()
            deadline = now() + 5.0
            for resp in list(in_flight.values()):
                # In-flight work cannot be preempted; wait for the lanes to
                # publish, then account. Bounded: if the pool broke mid-run
                # the stragglers are force-cancelled after the deadline. A
                # response that goes retry-pending during shutdown will
                # never be re-admitted — publish its stored failure now
                # rather than burning the whole drain deadline on it.
                while (not resp.done() and not resp._retry_pending
                       and now() < deadline):
                    time.sleep(0)
                if resp._retry_pending:
                    resp._finish(STATUS_ERROR, error=resp._retry_error,
                                 complete_t=now())
                elif not resp.done():
                    resp._finish(STATUS_CANCELLED, complete_t=now())
                del in_flight[resp.request.rid]
                metrics.note_complete(resp)
            if sched is not None:
                try:
                    sched.close()
                except BaseException as exc:  # noqa: BLE001
                    if self._loop_error is None:
                        self._loop_error = exc
