"""Continuous-batching scheduler loop on the Relic tasking substrate.

The serving shape of the paper's runtime: a single **scheduler loop thread**
owns a ``RelicPool``-backed scheduler (creates it, submits to it, closes it
— the pool's owner-thread contract) and runs the admit/dispatch/finalize
cycle:

1. **finalize** — observe ``Response.done()`` on in-flight requests (the
   assistant lanes publish via the lazy-Event flag; the loop never blocks
   on a barrier) and fold finished responses into ``ServeMetrics``;
2. **admit** — drain client SPSC rings up to the free batch budget
   (``RELIC_SERVE_BATCH_MAX`` minus in-flight), stamp ``admit_t``, shed
   requests whose deadline already passed (surfaced as
   ``deadline_exceeded``, never silently dropped), and submit the rest to
   the pool lanes via ``submit_many`` (lane striping + rebalance are the
   existing RelicPool machinery);
3. **park** — when idle long enough, publish a parked flag and sleep on an
   Event that ``ClientHandle.submit`` sets only when it observes the flag —
   the same advisory-hint philosophy as ``Relic.sleep_hint`` /
   ``wake_up_hint`` (paper §VI-B), so the submit hot path under load never
   touches the Event.

**Continuous batching** means there is no barrier between "batches": the
in-flight set is a sliding window. A request admitted while others are
running completes as soon as a lane finishes it — ``wait()`` is never
called on the pool while serving (RelicPool's fire-and-observe mode, whose
per-window error logs stay bounded by ring capacity).

Task errors are contained in ``_execute`` (the Response carries them);
a failed request never becomes a failed pool task, so the pool's
first-error-wins machinery stays quiet and serving continues.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, Optional

from repro.core.schedulers import make_scheduler
from repro.runtime.config import (
    ServeConfig,
    resolve_serve_config,
    resolve_spin_pause_every,
)
from repro.serve.ingest import ClientHandle, Ingest, ServeUsageError
from repro.serve.metrics import ServeMetrics, now
from repro.serve.request import (
    Response,
    STATUS_CANCELLED,
    STATUS_DEADLINE,
    STATUS_ERROR,
    STATUS_OK,
)

# Idle loop iterations (no finalize, no admit) before the loop parks on the
# wake Event. Large enough that a loaded server never parks; small enough
# that an idle one stops burning the host within ~a millisecond.
_PARK_AFTER_IDLE_SPINS = 256
# Park timeout: an advisory-hint backstop, not the wake mechanism (the
# Event is); bounds stop() latency if every hint is missed.
_PARK_TIMEOUT_S = 0.05


class ServeScheduler:
    """Request server: per-client SPSC ingest → continuous batcher → lanes.

    Usage::

        with ServeScheduler(lanes=2) as server:
            client = server.open_client()
            resp = client.submit(fn, arg)
            value = resp.result()

    ``lanes=0`` runs a degenerate inline mode (admit → execute on the loop
    thread) used for tests that want serving semantics without threads.
    """

    def __init__(
        self,
        lanes: int = 2,
        capacity: Optional[int] = None,
        config: Optional[ServeConfig] = None,
        scheduler: str = "relic-pool",
    ) -> None:
        if lanes < 0:
            raise ValueError(f"lanes must be >= 0, got {lanes}")
        self.lanes = lanes
        self._capacity = capacity
        self._scheduler_name = scheduler
        self.config = config or resolve_serve_config()
        self.metrics = ServeMetrics()
        self._wake_event = threading.Event()
        self._parked = False
        self.ingest = Ingest(self.config, wake=self._wake_from_client)
        self._in_flight: Dict[int, Response] = {}
        self._stop_requested = False
        self._drain_on_stop = True
        self._started = False
        self._closed = False
        self._loop_thread: Optional[threading.Thread] = None
        self._loop_error: Optional[BaseException] = None
        self._ready = threading.Event()

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "ServeScheduler":
        if self._started:
            raise ServeUsageError("ServeScheduler.start() called twice")
        self._started = True
        self._loop_thread = threading.Thread(
            target=self._loop, name="serve-scheduler", daemon=True)
        self._loop_thread.start()
        self._ready.wait()
        if self._loop_error is not None:
            raise self._loop_error
        return self

    def stop(self, drain: bool = True) -> None:
        """Shut down. ``drain=True`` finishes everything already submitted
        or queued; ``drain=False`` cancels queued requests (in-flight work
        still completes — lanes cannot be preempted)."""
        if not self._started or self._closed:
            return
        self._closed = True
        self.ingest.close()
        self._drain_on_stop = drain
        self._stop_requested = True
        self._wake_event.set()
        assert self._loop_thread is not None
        self._loop_thread.join()
        if self._loop_error is not None:
            raise self._loop_error

    def __enter__(self) -> "ServeScheduler":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    # -- client side -------------------------------------------------------

    def open_client(self, client_id: Optional[str] = None) -> ClientHandle:
        return self.ingest.open_client(client_id)

    def stats(self) -> dict:
        """Live snapshot (callable from any thread, racy-but-consistent)."""
        snap = self.metrics.snapshot(rejected=self.ingest.total_rejected())
        snap["lanes"] = self.lanes
        snap["in_flight"] = len(self._in_flight)
        snap["pending"] = self.ingest.pending()
        snap["config"] = self.config.asdict()
        return snap

    # -- wake hint (client threads) ---------------------------------------

    def _wake_from_client(self) -> None:
        # One flag read per submit; Event.set only on park transitions —
        # the loaded hot path never touches the Event.
        if self._parked:
            self._wake_event.set()

    # -- execution (assistant lanes) --------------------------------------

    def _execute(self, resp: Response) -> None:
        """Run one request on a pool lane. Never raises: the Response is
        the error channel, so a failing request cannot poison the lane."""
        req = resp.request
        first_t: Optional[float] = None
        try:
            value = req.fn(*req.args)
            if hasattr(value, "__next__"):
                # Streaming work: the first yielded item stamps
                # first-result time (TTFT for token serving); the
                # response value is the collected stream.
                items = []
                for item in value:
                    if first_t is None:
                        first_t = now()
                        resp.first_result_t = first_t
                    items.append(item)
                value = items
            t = now()
            if first_t is None:
                resp.first_result_t = t
            status = STATUS_OK
            if req.deadline_t is not None and t > req.deadline_t:
                status = STATUS_DEADLINE
            resp._finish(status, value=value, complete_t=t)
        except BaseException as exc:  # noqa: BLE001 - the future carries it
            resp._finish(STATUS_ERROR, error=exc, complete_t=now())

    # -- scheduler loop ----------------------------------------------------

    def _loop(self) -> None:
        sched = None
        try:
            if self.lanes > 0:
                kwargs: Dict[str, Any] = {"lanes": self.lanes}
                if self._capacity is not None:
                    kwargs["capacity"] = self._capacity
                sched = make_scheduler(self._scheduler_name, **kwargs)
                sched.start()
        except BaseException as exc:  # noqa: BLE001 - surface via start()
            self._loop_error = exc
            self._ready.set()
            return
        self._ready.set()

        metrics = self.metrics
        ingest = self.ingest
        in_flight = self._in_flight
        batch_max = self.config.batch_max
        pause_every = resolve_spin_pause_every()
        idle_spins = 0
        try:
            while True:
                progressed = False

                # 1. finalize: observe completions without any barrier.
                if in_flight:
                    done = [r for r in in_flight.values() if r.done()]
                    for resp in done:
                        del in_flight[resp.request.rid]
                        metrics.note_complete(resp)
                    if done:
                        progressed = True

                # 2. admit: fill the sliding window mid-stream.
                budget = batch_max - len(in_flight)
                if budget > 0:
                    batch = ingest.poll(budget)
                    if batch:
                        progressed = True
                        t = now()
                        submits = []
                        for resp in batch:
                            req = resp.request
                            req.admit_t = t
                            metrics.admitted += 1
                            if (req.deadline_t is not None
                                    and t > req.deadline_t):
                                # Shed without running: the SLO violation
                                # is surfaced, the lane time is not spent.
                                resp._finish(STATUS_DEADLINE, complete_t=t)
                                metrics.note_complete(resp)
                                continue
                            in_flight[req.rid] = resp
                            submits.append((self._execute, (resp,), {}))
                        if submits:
                            if sched is not None:
                                sched.submit_many(submits)
                            else:
                                for fn, args, _ in submits:
                                    fn(*args)
                        metrics.queue_depth.observe(ingest.pending())
                        metrics.batch_occupancy.observe(len(in_flight))

                if self._stop_requested:
                    if not self._drain_on_stop:
                        break
                    if not in_flight and not ingest.pending():
                        break

                if progressed:
                    idle_spins = 0
                    continue

                # 3. idle: spin briefly, then park on the wake Event.
                idle_spins += 1
                if idle_spins % pause_every == 0:
                    time.sleep(0)
                if idle_spins >= _PARK_AFTER_IDLE_SPINS and not in_flight:
                    self._wake_event.clear()
                    self._parked = True
                    try:
                        # Double-check after publishing the flag: a submit
                        # that missed it must be visible in the rings now.
                        if not ingest.pending() and not self._stop_requested:
                            if sched is not None:
                                sched.sleep_hint()
                            self._wake_event.wait(_PARK_TIMEOUT_S)
                            if sched is not None:
                                sched.wake_up_hint()
                    finally:
                        self._parked = False
                    idle_spins = 0
        except BaseException as exc:  # noqa: BLE001 - surface via stop()
            self._loop_error = exc
        finally:
            # Cancel whatever the stop mode left behind (queued requests on
            # drain=False, everything on a loop error).
            for resp in ingest.poll(1 << 30):
                resp._finish(STATUS_CANCELLED, complete_t=now())
                metrics.note_complete(resp)
            deadline = now() + 5.0
            for resp in list(in_flight.values()):
                # In-flight work cannot be preempted; wait for the lanes to
                # publish, then account. Bounded: if the pool broke mid-run
                # the stragglers are force-cancelled after the deadline.
                while not resp.done() and now() < deadline:
                    time.sleep(0)
                if not resp.done():
                    resp._finish(STATUS_CANCELLED, complete_t=now())
                del in_flight[resp.request.rid]
                metrics.note_complete(resp)
            if sched is not None:
                try:
                    sched.close()
                except BaseException as exc:  # noqa: BLE001
                    if self._loop_error is None:
                        self._loop_error = exc
