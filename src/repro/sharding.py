"""Partitioning rules: param-path patterns -> PartitionSpec, plus activation
sharding constraints.

Mesh axes:
  single-pod: ("data", "model")          = (16, 16)
  multi-pod:  ("pod", "data", "model")   = (2, 16, 16)

Layout (2D "FSDP + TP"):
  * `model` carries tensor/expert parallelism (Megatron column/row, vocab-
    parallel embeddings, expert sharding).
  * `data` carries the batch AND a ZeRO-3-style shard of every weight's
    non-model dimension.
  * `pod` carries batch only (pure DP between pods); gradients all-reduce
    over it. This is what the multi-pod dry-run proves out.

Activation constraints are applied through ``shard_act`` which is a no-op
unless a mesh context has been installed via ``use_sharding_rules`` — smoke
tests on 1 CPU device run the same model code without any mesh.
"""

from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _axes() -> Optional[dict]:
    return getattr(_state, "axes", None)


@contextlib.contextmanager
def use_sharding_rules(mesh: Mesh):
    """Install mesh axes for activation constraints within the trace."""
    names = mesh.axis_names
    axes = {
        "batch": tuple(n for n in ("pod", "data") if n in names) or None,
        "model": "model" if "model" in names else None,
        "mesh": mesh,
    }
    prev = _axes()
    _state.axes = axes
    try:
        # NamedShardings carry their mesh explicitly, so no global mesh
        # context is required; constraints resolve against axes[...] here.
        yield
    finally:
        _state.axes = prev


def _resolve(token: Optional[str]):
    axes = _axes()
    if token is None or axes is None:
        return None
    if token == "batch":
        return axes["batch"]
    if token == "model":
        return axes["model"]
    raise ValueError(f"unknown logical axis {token!r}")


def _axis_prod(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    out = 1
    for n in names:
        out *= mesh.shape[n]
    return out


def fit_spec(mesh: Mesh, entries, shape) -> P:
    """Drop axis names whose size does not divide the dim (replicate instead).

    jit argument shardings must divide exactly; where a logical rule doesn't
    (e.g. 20 or 40 or 56 attention heads over model=16), we fall back to
    replication for that dim and the roofline records the cost. A tuple entry
    degrades to its longest prefix that divides.
    """
    fitted = []
    for d, entry in enumerate(entries):
        if entry is None or d >= len(shape):
            fitted.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        names = tuple(n for n in names if n in mesh.axis_names)
        while names and shape[d] % _axis_prod(mesh, names) != 0:
            names = names[:-1]
        if not names:
            fitted.append(None)
        elif len(names) == 1:
            fitted.append(names[0])
        else:
            fitted.append(tuple(names))
    return P(*fitted)


# --- experiment knobs (set by use_sharding_rules / hillclimb driver) -------
#
# activation layout for the RESIDUAL STREAM [B, S, D] (kind="resid"):
#   "tp"         — D sharded over model (baseline 2D layout)
#   "replicated" — residuals full per device (classic Megatron f/g)
#   "seq"        — S sharded over model (Megatron sequence parallelism)
_ACT_LAYOUTS = ("tp", "replicated", "seq", "mixed")


def set_activation_layout(mode: str) -> None:
    assert mode in _ACT_LAYOUTS, mode
    _state.act_layout = mode


def get_activation_layout() -> str:
    return getattr(_state, "act_layout", "tp")


def set_param_rule_overrides(rules) -> None:
    """Prepend (regex, logical-entries) rules; [] clears. Hillclimb only."""
    _state.rule_overrides = list(rules)


def _rule_overrides():
    return getattr(_state, "rule_overrides", [])


def current_mesh() -> Optional[Mesh]:
    axes = _axes()
    return axes["mesh"] if axes else None


def shard_act(x: jax.Array, *logical: Optional[str], kind: str = "act") -> jax.Array:
    """Constrain an activation, e.g. shard_act(h, 'batch', None, 'model').

    kind="resid" marks residual-stream constraints [B, S, D]; their layout is
    swappable via set_activation_layout for the §Perf experiments."""
    axes = _axes()
    if axes is None:
        return x
    mesh = axes["mesh"]
    tokens = list(logical)
    layout = get_activation_layout()
    if kind == "resid" and len(tokens) == 3:
        if layout == "replicated":
            tokens = [tokens[0], None, None]
        elif layout == "seq":
            tokens = [tokens[0], "model", None]
    elif kind == "blockin":
        # "mixed" layout: residuals stay model-sharded (memory), but block
        # inputs are replicated right AFTER the bf16 cast, so the per-block
        # all-gather moves bf16 — not the f32 the CPU dot upcast would force
        # (§Perf it7).
        if layout != "mixed":
            return x
        tokens = [tokens[0]] + [None] * (len(tokens) - 1)
    spec = fit_spec(mesh, [_resolve(t) for t in tokens], x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter partitioning rules
# ---------------------------------------------------------------------------

# Ordered (regex over '/'-joined path, spec builder) — first match wins.
# `spec` entries are logical: "model", "data", or None, matched to the
# *trailing* dims of the array (leading scan/stack dims get None).
_PARAM_RULES: list[tuple[str, tuple]] = [
    # embeddings / output heads: vocab-parallel, ZeRO on d_model
    (r"(^|/)embed/table$",        ("model", "data")),       # [V, D]
    (r"(^|/)lm_head/kernel$",     ("data", "model")),       # [D, V]
    # attention: Q and O column/row-parallel over heads; KV replicated on
    # model (GQA kv<TP) but ZeRO'd on data
    (r"(^|/)attn/wq$",            ("data", "model", None)),  # [D, H, Dh]
    (r"(^|/)attn/wk$",            ("data", None, None)),     # [D, Hkv, Dh]
    (r"(^|/)attn/wv$",            ("data", None, None)),
    (r"(^|/)attn/wo$",            ("model", None, "data")),  # [H, Dh, D]
    # dense MLP: column then row parallel
    (r"(^|/)mlp/w_(gate|up)$",    ("data", "model")),        # [D, F]
    (r"(^|/)mlp/w_down$",         ("model", "data")),        # [F, D]
    # MoE: experts over model, ZeRO over data on d_model dim
    (r"(^|/)moe/router$",         ("data", None)),           # [D, E]
    (r"(^|/)moe/w_(gate|up)$",    ("model", "data", None)),  # [E, D, F]
    (r"(^|/)moe/w_down$",         ("model", None, "data")),  # [E, F, D]
    # mamba2 / rwkv6 big projections
    (r"(^|/)ssm/w_in$",           ("data", "model")),        # [D, d_inner*...]
    (r"(^|/)ssm/w_out$",          ("model", "data")),        # [d_inner, D]
    (r"(^|/)rwkv/w_(r|k|v|g)$",   ("data", "model")),
    (r"(^|/)rwkv/w_o$",           ("model", "data")),
    # decode caches: batch over data; KV time axis over model (flash-decoding
    # style split-T — GSPMD inserts the partial-softmax collectives)
    (r"(^|/)cache/(k|v)$",        ("data", "model", None, None)),  # [B,T,H,Dh]
    (r"(^|/)cache/(xk|xv)$",      ("data", "model", None, None)),  # cross-attn
    (r"(^|/)layers/(k|v|xk|xv)$", ("data", "model", None, None)),  # encdec cache
    (r"(^|/)shared_attn/(k|v)$",  ("data", "model", None, None)),  # zamba2 cache
    (r"(^|/)cache/ssm_state$",    ("data", "model", None, None)),  # [B,H,P,N]
    (r"(^|/)cache/wkv_state$",    ("data", "model", None, None)),  # [B,H,Dh,Dh]
    (r"(^|/)cache/conv_state$",   ("data", None, "model")),        # [B,K-1,C]
    (r"(^|/)cache/shift_state$",  ("data", "model")),              # [B,D]
    # everything small (norms, biases, decay vectors, conv kernels): replicate
    (r".*",                       ()),
]


def param_entries(path: str, ndim: int):
    """Logical axis entries for one param ('/'-joined path + rank)."""
    for pat, logical in list(_rule_overrides()) + _PARAM_RULES:
        if re.search(pat, path):
            pad = ndim - len(logical)
            if pad < 0:
                # rule written for the unstacked rank; stacked arrays only
                # ever ADD leading dims, so negative pad means a rank mismatch
                # from e.g. fused dims — fall back to replication.
                return (None,) * ndim
            return (None,) * pad + tuple(logical)
    return (None,) * ndim


def param_spec(path: str, ndim: int) -> P:
    return P(*param_entries(path, ndim))


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(tree, mesh: Optional[Mesh] = None):
    """Map a param pytree (arrays or ShapeDtypeStructs) to PartitionSpecs.

    With a mesh, specs are divisibility-checked against each leaf's shape."""
    def one(kp, x):
        entries = param_entries(_path_str(kp), x.ndim)
        if mesh is None:
            return P(*entries)
        return fit_spec(mesh, entries, x.shape)

    return jax.tree_util.tree_map_with_path(one, tree)


def named_shardings(tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(tree, mesh))


def batch_spec(mesh: Mesh, ndim: int, batch_dim: int = 0) -> NamedSharding:
    names = mesh.axis_names
    batch_axes = tuple(n for n in ("pod", "data") if n in names) or None
    entries = [None] * ndim
    entries[batch_dim] = batch_axes
    return NamedSharding(mesh, P(*entries))
