"""``repro.workloads`` — the paper's kernels as first-class workloads.

This package is the layer the speedup-over-serial headline table (paper
§IV/§VII) is produced from, and where every future workload PR lands. Each
workload is *n* identical instances of one fine-grained kernel plus an
independent oracle, exposing the same three execution variants through the
:mod:`repro.tasks.api` façade — ``serial()``, ``paired(scope)`` (the
paper's two-instance offload, producer runs one half) and
``chunked(scope, grain)`` (worksharing via ``parallel_for``). See
:mod:`repro.workloads.base` for the protocol and
``docs/EXPERIMENTS.md`` for the table recipe
(``python -m benchmarks.run --only paper``).

Registered workloads: the paper's seven (``bc``, ``bfs``, ``cc``, ``pr``,
``sssp``, ``tc``, ``json``) plus two scenario-diverse additions
(``stencil``, ``histogram``).
"""

from repro.workloads.base import (VARIANTS, Workload, WorkloadOracleError,
                                  available_workloads, make_workload,
                                  register_workload, results_agree)

# Importing the workload modules populates the registry.
from repro.workloads import graphs as _graphs          # noqa: F401
from repro.workloads import histogram as _histogram    # noqa: F401
from repro.workloads import jsondoc as _jsondoc        # noqa: F401
from repro.workloads import stencil as _stencil        # noqa: F401

# The subset reproducing the paper's own table (§IV), in paper order.
PAPER_WORKLOADS = ("bc", "bfs", "cc", "pr", "sssp", "tc", "json")

__all__ = [
    "Workload",
    "WorkloadOracleError",
    "VARIANTS",
    "PAPER_WORKLOADS",
    "available_workloads",
    "make_workload",
    "register_workload",
    "results_agree",
]
