"""Scenario growth beyond the paper: a 2-D Jacobi stencil sweep workload.

Eight 5-point Jacobi relaxation sweeps over a 64×64 float32 grid with
Dirichlet (frozen) boundaries — the classic fine-grained HPC loop nest the
worksharing-task line of work (Maroñas et al., 2020) targets, and µs-scale
on this input, matching the paper's 0.4–6.4 µs task-size regime. The
oracle is a NumPy reimplementation of the same sweep.

Like every workload, inherits the skewed power-law cost dimension
(``skew=``/``skew_seed=``) from :class:`repro.workloads.base.Workload`.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.workloads.base import Workload, register_workload

GRID = 64
SWEEPS = 8


@functools.partial(jax.jit, static_argnames=("sweeps",))
def stencil_sweep(grid: jax.Array, sweeps: int = SWEEPS) -> jax.Array:
    """``sweeps`` Jacobi iterations; the boundary ring stays fixed."""
    interior = jnp.zeros(grid.shape, bool).at[1:-1, 1:-1].set(True)

    def step(_, g):
        avg = 0.25 * (jnp.roll(g, 1, 0) + jnp.roll(g, -1, 0) +
                      jnp.roll(g, 1, 1) + jnp.roll(g, -1, 1))
        return jnp.where(interior, avg, g)

    return jax.lax.fori_loop(0, sweeps, step, grid)


def _np_stencil(grid: np.ndarray, sweeps: int = SWEEPS) -> np.ndarray:
    g = grid.astype(np.float32).copy()
    for _ in range(sweeps):
        avg = 0.25 * (np.roll(g, 1, 0) + np.roll(g, -1, 0) +
                      np.roll(g, 1, 1) + np.roll(g, -1, 1))
        new = g.copy()
        new[1:-1, 1:-1] = avg[1:-1, 1:-1]
        g = new.astype(np.float32)
    return g


@functools.lru_cache(maxsize=1)
def _base_grid() -> np.ndarray:
    rng = np.random.default_rng(7)
    return rng.standard_normal((GRID, GRID)).astype(np.float32)


@register_workload
class StencilWorkload(Workload):
    name = "stencil"

    def _input(self) -> np.ndarray:
        return _base_grid()

    def _kernel(self, grid: jax.Array) -> jax.Array:
        return stencil_sweep(grid)

    def _stream_stages(self, stages=None):
        """Stencil time-steps as a pipeline: the 8 Jacobi sweeps split into
        ``stages`` sweep-groups (default 4, so 2 sweeps per stage), each a
        stage; the per-instance *grids* flow through. While instance 0 is
        in sweep-group 2, instance 1 is in sweep-group 1 — the dependency
        chain a barriered wavefront cannot overlap. Sweep order is
        preserved per grid (linear pipelines are FIFO), so the final grids
        equal the serial 8-sweep result and the standard oracle applies.
        ``sweeps`` is a static jit arg, so each group size compiles once.
        Ignores ``skew`` (the decomposition replaces the repeat knob)."""
        s = 4 if stages is None else stages
        if s < 1 or SWEEPS % s:
            raise ValueError(
                f"stages must divide SWEEPS={SWEEPS}, got {stages}")
        per = SWEEPS // s

        def sweep_group(grid: jax.Array) -> jax.Array:
            return jax.block_until_ready(stencil_sweep(grid, sweeps=per))

        items = [jnp.array(self._input()) for _ in range(self.n_instances)]
        jax.block_until_ready(stencil_sweep(items[0], sweeps=per))  # warm
        return items, [sweep_group] * s

    def check_one(self, result: Any) -> None:
        np.testing.assert_allclose(np.asarray(result), _np_stencil(_base_grid()),
                                   rtol=1e-5, atol=1e-6)
