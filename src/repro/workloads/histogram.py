"""Scenario growth beyond the paper: a byte-histogram workload.

A 256-bin histogram over a 4 KiB pseudo-random byte buffer — the
scatter-add shape (simdjson/DB-filter adjacent) that complements the
matvec-shaped graph kernels and the scan-shaped JSON parse, and another
µs-scale body in the paper's task-size regime. The oracle is
``np.bincount`` on the same bytes.

Like every workload, inherits the skewed power-law cost dimension
(``skew=``/``skew_seed=``) from :class:`repro.workloads.base.Workload`.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.workloads.base import Workload, register_workload

BUF_BYTES = 4096
BINS = 256


@jax.jit
def byte_histogram(buf: jax.Array) -> jax.Array:
    """uint8[n] -> int32[256] bin counts."""
    return jnp.bincount(buf, length=BINS).astype(jnp.int32)


@functools.lru_cache(maxsize=1)
def _base_buffer() -> np.ndarray:
    rng = np.random.default_rng(23)
    return rng.integers(0, BINS, size=BUF_BYTES).astype(np.uint8)


@register_workload
class ByteHistogramWorkload(Workload):
    name = "histogram"

    def _input(self) -> np.ndarray:
        return _base_buffer()

    def _kernel(self, buf: jax.Array) -> jax.Array:
        return byte_histogram(buf)

    def check_one(self, result: Any) -> None:
        expected = np.bincount(_base_buffer(), minlength=BINS).astype(np.int32)
        np.testing.assert_array_equal(np.asarray(result), expected)
