"""The paper's JSON structural-parse workload (§IV-B).

Each instance runs :func:`repro.tasks.jsonparse.parse_structural` (the
simdjson-stage-1 translation) on its own copy of the json.org "widget"
document. The oracle cross-checks against
:func:`repro.tasks.jsonparse.oracle_counts` — Python's ``json`` module
plus a character walk, fully independent of the JAX kernel.

Like every workload, inherits the skewed power-law cost dimension
(``skew=``/``skew_seed=``) from :class:`repro.workloads.base.Workload`.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.tasks import jsonparse
from repro.workloads.base import Workload, WorkloadOracleError, register_workload


@register_workload
class JsonParseWorkload(Workload):
    name = "json"
    doc = jsonparse.WIDGET_JSON

    def _input(self) -> jax.Array:
        return jsonparse.to_bytes(self.doc)

    def _kernel(self, buf: jax.Array) -> Any:
        return jsonparse.parse_structural(buf)

    def check_one(self, result: Any) -> None:
        structural, depth, ok = result
        expected = jsonparse.oracle_counts(self.doc)
        if not bool(ok):
            raise WorkloadOracleError("json: kernel flagged a valid document")
        got_structural = int(np.asarray(structural).sum())
        if got_structural != expected["structural"]:
            raise WorkloadOracleError(
                f"json: {got_structural} structural chars, oracle says "
                f"{expected['structural']}")
        depth_np = np.asarray(depth)
        if int(depth_np.max()) != expected["max_depth"]:
            raise WorkloadOracleError(
                f"json: max depth {int(depth_np.max())}, oracle says "
                f"{expected['max_depth']}")
        if int(depth_np[-1]) != 0:
            raise WorkloadOracleError("json: document does not close at depth 0")
