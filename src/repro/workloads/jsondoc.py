"""The paper's JSON structural-parse workload (§IV-B).

Each instance runs :func:`repro.tasks.jsonparse.parse_structural` (the
simdjson-stage-1 translation) on its own copy of the json.org "widget"
document. The oracle cross-checks against
:func:`repro.tasks.jsonparse.oracle_counts` — Python's ``json`` module
plus a character walk, fully independent of the JAX kernel.

Like every workload, inherits the skewed power-law cost dimension
(``skew=``/``skew_seed=``) from :class:`repro.workloads.base.Workload`.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.tasks import jsonparse
from repro.workloads.base import Workload, WorkloadOracleError, register_workload


@register_workload
class JsonParseWorkload(Workload):
    name = "json"
    doc = jsonparse.WIDGET_JSON
    #: byte-chunk granularity of the streamed variant (~600-byte doc ->
    #: ~10 chunks per instance)
    stream_chunk = 64

    def _input(self) -> jax.Array:
        return jsonparse.to_bytes(self.doc)

    def _kernel(self, buf: jax.Array) -> Any:
        return jsonparse.parse_structural(buf)

    def _stream_stages(self, stages=None):
        """The jsondoc byte-chunk stream: each instance's document is cut
        into ``stream_chunk``-byte chunks flowing through two stages —

        1. **classify** (stateless, vectorized): per-byte class masks
           (quote / backslash / open / close / structural-char), NumPy on
           the chunk.
        2. **scan** (stateful, sequential): the simdjson stage-1 carries —
           backslash run parity, real-quote prefix parity, nesting depth,
           depth-nonnegativity — threaded across chunks exactly as
           :func:`repro.tasks.jsonparse.parse_structural` computes them on
           the whole buffer. The carry lives in the stage and resets at
           each instance's chunk 0, so correctness *requires* the linear
           pipeline's FIFO order — which is the property worth testing.

        Items are instance-major ``(instance, chunk_idx, n_chunks,
        bytes)``; ``_stream_collect`` concatenates each instance's chunks
        back into the ``(structural, depth, ok)`` triple the standard
        oracle checks. Ignores ``skew`` (the decomposition replaces the
        repeat knob). Never run the scan stage inside a Farm: workers
        would race the carry and break chunk order."""
        if stages not in (None, 2):
            raise ValueError(
                f"workload {self.name!r} streams as classify->scan (2 "
                f"stages); got stages={stages}")
        data = self.doc.encode("utf-8")
        chunk = self.stream_chunk
        chunks = [data[o:o + chunk] for o in range(0, len(data), chunk)]
        nc = len(chunks)
        items = [(i, c, nc, payload)
                 for i in range(self.n_instances)
                 for c, payload in enumerate(chunks)]

        def classify(item):
            i, c, nc, payload = item
            bs = np.frombuffer(payload, np.uint8)
            return (i, c, nc, {
                "quote": bs == ord('"'),
                "backslash": bs == ord("\\"),
                "opens": (bs == ord("{")) | (bs == ord("[")),
                "closes": (bs == ord("}")) | (bs == ord("]")),
                "structural_chars": ((bs == ord("{")) | (bs == ord("}")) |
                                     (bs == ord("[")) | (bs == ord("]")) |
                                     (bs == ord(":")) | (bs == ord(","))),
            })

        carry = {"run": 0, "qpar": 0, "depth": 0, "neg": False}

        def scan(item):
            i, c, nc, m = item
            if c == 0:       # new instance: reset the cross-chunk carries
                carry.update(run=0, qpar=0, depth=0, neg=False)
            quote = m["quote"]
            backslash = m["backslash"]
            opens = m["opens"]
            closes = m["closes"]
            schars = m["structural_chars"]
            n = len(quote)
            structural = np.zeros(n, bool)
            depth = np.empty(n, np.int32)
            run, qpar = carry["run"], carry["qpar"]
            d, neg = carry["depth"], carry["neg"]
            for j in range(n):
                esc = (run % 2) == 1           # odd backslash run before j
                run = run + 1 if backslash[j] else 0
                rq = quote[j] and not esc      # real (unescaped) quote
                in_str = qpar == 1             # parity of real quotes < j
                if rq:
                    qpar ^= 1
                structural[j] = (schars[j] and not in_str) or rq
                if opens[j] and not in_str:
                    d += 1
                elif closes[j] and not in_str:
                    d -= 1
                    if d < 0:
                        neg = True
                depth[j] = d
            carry.update(run=run, qpar=qpar, depth=d, neg=neg)
            ok = None
            if c == nc - 1:                    # document verdict on the tail
                ok = (d == 0) and (not neg) and (qpar == 0)
            return (i, c, structural, depth, ok)

        return items, [classify, scan]

    def _stream_collect(self, outputs):
        nc = len(outputs) // self.n_instances
        results = []
        for i in range(self.n_instances):
            recs = outputs[i * nc:(i + 1) * nc]
            assert all(r[0] == i for r in recs), "chunk stream misordered"
            structural = np.concatenate([r[2] for r in recs])
            depth = np.concatenate([r[3] for r in recs])
            results.append((structural, depth, np.bool_(recs[-1][4])))
        return results

    def check_one(self, result: Any) -> None:
        structural, depth, ok = result
        expected = jsonparse.oracle_counts(self.doc)
        if not bool(ok):
            raise WorkloadOracleError("json: kernel flagged a valid document")
        got_structural = int(np.asarray(structural).sum())
        if got_structural != expected["structural"]:
            raise WorkloadOracleError(
                f"json: {got_structural} structural chars, oracle says "
                f"{expected['structural']}")
        depth_np = np.asarray(depth)
        if int(depth_np.max()) != expected["max_depth"]:
            raise WorkloadOracleError(
                f"json: max depth {int(depth_np.max())}, oracle says "
                f"{expected['max_depth']}")
        if int(depth_np[-1]) != 0:
            raise WorkloadOracleError("json: document does not close at depth 0")
