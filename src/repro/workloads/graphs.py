"""The paper's six GAP graph kernels as registered workloads (§IV-A).

Every workload runs the JAX kernels from :mod:`repro.tasks.graph` on the
paper's input (the 32-node Kronecker graph), one private copy per instance
— the paper generates two identical graphs so the paired tasks never share
buffers. Oracles are independent pure-NumPy/Python reimplementations
(BFS frontier walk, DFS components, Brandes, Bellman-Ford, power
iteration), never the kernel under test. All six inherit the skewed
power-law cost dimension (``skew=``/``skew_seed=``) from
:class:`repro.workloads.base.Workload` — the irregular-cost profile the
RelicPool rebalancing benchmark (``--only skew``) measures against.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.tasks import graph
from repro.workloads.base import Workload, register_workload

SOURCE = 0  # the paper's single-source kernels all start at node 0


@functools.lru_cache(maxsize=1)
def _base_graph():
    """The shared Kronecker input, built once per process: numpy copies for
    the oracles, the jnp originals templated per instance by the workloads."""
    adj, w = graph.kronecker_graph()
    return np.asarray(adj), np.asarray(w)


# ------------------------------------------------------- NumPy/Python oracles

def _np_bfs(adj: np.ndarray, source: int) -> np.ndarray:
    n = adj.shape[0]
    dist = np.full(n, -1, np.int32)
    dist[source] = 0
    frontier = [source]
    level = 0
    while frontier:
        nxt = []
        for u in frontier:
            for v in np.nonzero(adj[u] > 0)[0]:
                if dist[v] < 0:
                    dist[v] = level + 1
                    nxt.append(int(v))
        frontier = nxt
        level += 1
    return dist


def _np_components(adj: np.ndarray) -> np.ndarray:
    """Min-index label per connected component (what min-label propagation
    converges to)."""
    n = adj.shape[0]
    labels = np.full(n, -1, np.int64)
    for s in range(n):
        if labels[s] >= 0:
            continue
        labels[s] = s
        stack = [s]
        while stack:
            u = stack.pop()
            for v in np.nonzero(adj[u] > 0)[0]:
                if labels[v] < 0:
                    labels[v] = s
                    stack.append(int(v))
    return labels.astype(np.int32)


def _np_pagerank(adj: np.ndarray, iters: int = 20, d: float = 0.85) -> np.ndarray:
    a = adj.astype(np.float32)
    n = a.shape[0]
    deg = np.maximum(a.sum(axis=1), 1.0).astype(np.float32)
    p = np.full(n, 1.0 / n, np.float32)
    for _ in range(iters):
        p = ((1 - d) / n + d * (a.T @ (p / deg))).astype(np.float32)
    return p


def _np_sssp(w: np.ndarray, source: int) -> np.ndarray:
    wf = w.astype(np.float32)
    n = wf.shape[0]
    dist = np.full(n, np.float32(1e9), np.float32)
    dist[source] = 0.0
    for _ in range(n):
        cand = (dist[:, None] + wf).min(axis=0).astype(np.float32)
        new = np.minimum(dist, cand)
        if np.array_equal(new, dist):
            break
        dist = new
    return dist


def _np_triangles(adj: np.ndarray) -> float:
    a = adj.astype(np.float32)
    return float((a * (a @ a)).sum() / 6.0)


def _np_betweenness(adj: np.ndarray, source: int) -> np.ndarray:
    """Classic level-synchronous single-source Brandes."""
    n = adj.shape[0]
    dist = _np_bfs(adj, source)
    sigma = np.zeros(n, np.float64)
    sigma[source] = 1.0
    max_level = int(dist.max())
    for lev in range(1, max_level + 1):
        for v in np.nonzero(dist == lev)[0]:
            preds = np.nonzero((adj[v] > 0) & (dist == lev - 1))[0]
            sigma[v] = sigma[preds].sum()
    delta = np.zeros(n, np.float64)
    for lev in range(max_level, 0, -1):
        for v in np.nonzero(dist == lev - 1)[0]:
            for s in np.nonzero((adj[v] > 0) & (dist == lev))[0]:
                delta[v] += sigma[v] / sigma[s] * (1.0 + delta[s])
    delta[source] = 0.0
    return delta.astype(np.float32)


# ----------------------------------------------------------------- workloads

class _GraphWorkload(Workload):
    """Common shape: the base class builds per-instance private copies of
    the (dense) input matrix and the vmap-over-stack fused variant; each
    kernel class only picks its matrix and its kernel call."""

    weighted = False  # instance input: weight matrix instead of adjacency

    def _input(self) -> jax.Array:
        adj, w = _base_graph()
        return jnp.asarray(w if self.weighted else adj)


@register_workload
class BfsWorkload(_GraphWorkload):
    name = "bfs"

    def _kernel(self, adj):
        return graph.bfs(adj, SOURCE)

    def check_one(self, result):
        adj, _ = _base_graph()
        np.testing.assert_array_equal(np.asarray(result), _np_bfs(adj, SOURCE))


@register_workload
class ConnectedComponentsWorkload(_GraphWorkload):
    name = "cc"

    def _kernel(self, adj):
        return graph.connected_components(adj)

    def check_one(self, result):
        adj, _ = _base_graph()
        np.testing.assert_array_equal(np.asarray(result), _np_components(adj))


@register_workload
class PagerankWorkload(_GraphWorkload):
    name = "pr"

    def _kernel(self, adj):
        return graph.pagerank(adj)

    def check_one(self, result):
        adj, _ = _base_graph()
        out = np.asarray(result)
        np.testing.assert_allclose(out, _np_pagerank(adj), rtol=1e-4, atol=1e-6)
        assert abs(float(out.sum()) - 1.0) < 1e-3, "pagerank mass must be ~1"


@register_workload
class SsspWorkload(_GraphWorkload):
    name = "sssp"
    weighted = True

    def _kernel(self, w):
        return graph.sssp(w, SOURCE)

    def check_one(self, result):
        _, w = _base_graph()
        np.testing.assert_allclose(np.asarray(result), _np_sssp(w, SOURCE),
                                   rtol=1e-5)


@register_workload
class TriangleCountWorkload(_GraphWorkload):
    name = "tc"

    def _kernel(self, adj):
        return graph.triangle_count(adj)

    def check_one(self, result):
        adj, _ = _base_graph()
        np.testing.assert_allclose(float(result), _np_triangles(adj), rtol=1e-5)


@register_workload
class BetweennessWorkload(_GraphWorkload):
    name = "bc"

    def _kernel(self, adj):
        return graph.betweenness_centrality(adj, SOURCE)

    def check_one(self, result):
        adj, _ = _base_graph()
        np.testing.assert_allclose(np.asarray(result),
                                   _np_betweenness(adj, SOURCE),
                                   rtol=1e-3, atol=1e-3)
