"""The ``Workload`` protocol: the paper's kernels as first-class workloads.

A workload is *n* identical instances of one fine-grained kernel (the paper
generates two identical graphs / two buffer copies, §IV) plus an oracle.
Every workload exposes the same three execution variants, all driven
through the :mod:`repro.tasks.api` façade:

  * ``serial()`` — every instance inline on the calling thread (the
    paper's baseline; also what warms the jit caches).
  * ``paired(scope)`` — the paper's two-instance offload (§V/§VII): the
    back half of the instances is submitted to the scope's substrate, the
    producer runs the front half itself, then joins the handles.
  * ``chunked(scope, grain)`` — worksharing loop execution (Maroñas et
    al., 2020): one ``parallel_for`` over the instances, chunked by
    ``grain`` instances per task.

Since PR 9 there is additionally ``streamed(substrate)`` — pipelined
execution over :mod:`repro.stream`, where the workload's stream items
flow through its ``_stream_stages()`` decomposition (instance tasks by
default; stencil time-steps / jsondoc byte chunks for the workloads that
override it). It is deliberately *not* part of ``VARIANTS``: the three
variants share one task list, while ``streamed`` reshapes the work, so
benchmarks compare it explicitly rather than implicitly.

Instance task closures **block until the result is ready** (each thunk
ends in ``jax.block_until_ready``), so every variant times compute, not
async dispatch — the fix for the PR 1–3 ``benchmarks/paper_kernels._pair``
bug, inherited by construction here. The raw non-blocking dispatch
closures remain available as ``dispatches`` for the device-side analogue
strategies (``jax_async_stream``), where overlap inside the XLA stream is
the point.

Results are checked two ways by :meth:`Workload.check`: all instances
must agree with instance 0 (they run identical inputs), and instance 0
must pass the subclass's independent oracle (``check_one``, NumPy/stdlib
reference implementations — never the JAX kernel under test).

Every workload also carries a *skewed cost* dimension (``skew=alpha``,
``skew_seed``): per-instance power-law repeat counts that model the
irregular task costs where static lane striping loses to dynamic load
balancing (the ``skew`` benchmark section / RelicPool rebalancing). A
skewed run returns the same results and passes the same oracle — only
the cost profile changes.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.tasks.api import TaskScope, parallel_for

__all__ = [
    "Workload",
    "WorkloadOracleError",
    "VARIANTS",
    "results_agree",
    "register_workload",
    "available_workloads",
    "make_workload",
]

# The uniform execution shapes every workload exposes (benchmarks and the
# conformance tests iterate this, not hand-rolled lists).
VARIANTS = ("serial", "paired", "chunked")


class WorkloadOracleError(AssertionError):
    """A workload result failed its oracle (or instances disagreed)."""


# --------------------------------------------------------------------- registry

_REGISTRY = {}


def register_workload(cls):
    """Class decorator registering a workload under ``cls.name`` (the same
    flat name -> factory shape as ``repro.core.schedulers``)."""
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a non-empty name")
    _REGISTRY[cls.name] = cls
    return cls


def available_workloads() -> List[str]:
    """Registered workload names, stable (sorted) order."""
    return sorted(_REGISTRY)


def make_workload(name: str, **kwargs: Any) -> "Workload":
    """Instantiate a workload by name (inputs built lazily)."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown workload {name!r}; available: {available_workloads()}"
        ) from None
    return factory(**kwargs)


def _leaves(tree: Any) -> List[np.ndarray]:
    return [np.asarray(leaf) for leaf in jax.tree_util.tree_leaves(tree)]


def results_agree(a: Any, b: Any, *, rtol: float = 0.0, atol: float = 0.0) -> bool:
    """True when two instance results (arbitrary pytrees of arrays) match."""
    la, lb = _leaves(a), _leaves(b)
    if len(la) != len(lb):
        return False
    return all(x.shape == y.shape and np.allclose(x, y, rtol=rtol, atol=atol)
               for x, y in zip(la, lb))


class Workload:
    """Base class: subclasses set ``name``, implement ``_input()`` (the
    shared base input array) + ``_kernel(x)`` (the single-instance kernel
    call), and ``check_one(result)`` (the oracle for one instance's
    result). The base class derives everything else: ``_build()`` gives
    each instance its **own copy** of the input (the paper's two identical
    graphs / buffer copies — paired tasks never alias device memory) and
    ``_build_fused()`` stacks the copies under one ``jit(vmap(kernel))``
    call. Workloads whose instances are not copy-of-one-input can override
    ``_build()``/``_build_fused()`` directly.

    ``_build()`` returns ``n_instances`` zero-argument callables, each
    dispatching the kernel on that instance's own input copy and returning
    the (possibly still in-flight) result; the base class derives the
    blocking ``tasks`` from them. Building is lazy and cached — the first
    access compiles and warms every instance.
    """

    name: str = ""
    default_instances: int = 2

    def __init__(self, n_instances: Optional[int] = None, *,
                 skew: Optional[float] = None, skew_seed: int = 0):
        n = self.default_instances if n_instances is None else n_instances
        if n < 2:
            raise ValueError(
                f"workload {self.name!r} needs >= 2 instances for the "
                f"paired variant, got {n}")
        self.n_instances = n
        # Skewed task-cost dimension (PR 6): with ``skew=alpha`` each
        # instance's blocking task repeats its kernel ``repeats[i]`` times,
        # where the repeat counts follow a Zipf-by-rank power law — the
        # rank-r instance costs ~ r**-alpha of the heaviest, scaled so the
        # heaviest repeats ``n`` times and every instance repeats at least
        # once. Which *position* gets which rank is a seeded shuffle
        # (``skew_seed``), so the cost profile is deterministic per seed
        # but not correlated with submission order. Results are unchanged
        # (the kernel is idempotent on its own input copy), so the oracle
        # and cross-instance agreement checks apply as-is — a skewed run
        # is still fully checked. Subclasses never override __init__, so
        # every registered workload gains the dimension uniformly.
        self.skew = skew
        self.skew_seed = skew_seed
        if skew is None:
            self.repeats: List[int] = [1] * n
        else:
            if not (skew > 0):
                raise ValueError(f"skew must be a positive exponent, got {skew}")
            ranks = np.arange(1, n + 1, dtype=np.float64)
            reps = np.maximum(1, np.rint(n / ranks ** skew)).astype(np.int64)
            np.random.default_rng(skew_seed).shuffle(reps)
            self.repeats = [int(r) for r in reps]
        self._dispatches: Optional[List[Callable[[], Any]]] = None
        self._tasks: Optional[List[Callable[[], Any]]] = None
        self._fused: Optional[Callable[[], Any]] = None

    # -- subclass surface --------------------------------------------------
    def _input(self) -> Any:
        """The shared base input array each instance gets a copy of."""
        raise NotImplementedError

    def _kernel(self, x: Any) -> Any:
        """Dispatch the kernel on one instance's input; may return an
        in-flight result (the base class blocks in ``tasks``)."""
        raise NotImplementedError

    def _build(self) -> Sequence[Callable[[], Any]]:
        copies = [jnp.array(self._input()) for _ in range(self.n_instances)]
        return [functools.partial(self._kernel, x) for x in copies]

    def _build_fused(self) -> Optional[Callable[[], Any]]:
        """One compiled call covering every instance (the ``fused_vmap``
        benchmark strategy — where a TPU-native port of the paper's two
        SMT lanes ultimately lands). Return None when unsupported."""
        stacked = jnp.stack([jnp.asarray(self._input())] * self.n_instances)
        vf = jax.jit(lambda xs: jax.vmap(self._kernel)(xs))
        return functools.partial(vf, stacked)

    def check_one(self, result: Any) -> None:
        raise NotImplementedError

    # -- lazy build --------------------------------------------------------
    @property
    def dispatches(self) -> List[Callable[[], Any]]:
        """Raw non-blocking dispatch thunks, one per instance."""
        if self._dispatches is None:
            built = list(self._build())
            if len(built) != self.n_instances:
                raise RuntimeError(
                    f"{type(self).__name__}._build() returned {len(built)} "
                    f"thunks for {self.n_instances} instances")
            self._dispatches = built
            for d in built:                  # compile + warm every instance
                jax.block_until_ready(d())
        return self._dispatches

    @property
    def tasks(self) -> List[Callable[[], Any]]:
        """Blocking task closures: ``dispatch`` + ``block_until_ready``,
        repeated ``repeats[i]`` times under a skewed cost profile (the
        result is the last repeat's — identical to the first, since each
        dispatch reruns the same kernel on the instance's own input)."""
        if self._tasks is None:
            def blocking(dispatch, reps):
                if reps == 1:
                    def task():
                        return jax.block_until_ready(dispatch())
                else:
                    def task():
                        for _ in range(reps - 1):
                            jax.block_until_ready(dispatch())
                        return jax.block_until_ready(dispatch())
                task.__name__ = f"{self.name}-instance-x{reps}"
                return task

            self._tasks = [blocking(d, r)
                           for d, r in zip(self.dispatches, self.repeats)]
        return self._tasks

    def fused_task(self) -> Callable[[], Any]:
        """Blocking thunk for the fused all-instances compiled call.
        Note: the fused variant ignores ``skew`` — one vmapped call has no
        per-instance cost knob; it exists to benchmark the uniform case."""
        if self._fused is None:
            fused = self._build_fused()
            if fused is None:
                raise NotImplementedError(
                    f"workload {self.name!r} has no fused variant")

            def task():
                return jax.block_until_ready(fused())
            task.__name__ = f"{self.name}-fused"
            self._fused = task
        return self._fused

    # -- streaming surface (PR 9) ------------------------------------------
    def _stream_stages(self, stages: Optional[int] = None):
        """``(items, stage_fns)`` for :meth:`streamed`. Base default: the
        instance indices flow through one stage running the instance's
        blocking task (so ``skew`` repeats are honored). Subclasses with a
        natural pipeline decomposition (stencil time-steps, jsondoc byte
        chunks) override this — those decompositions replace the per-task
        skew knob with real per-stage structure, so they ignore ``skew``
        like the fused variant does."""
        if stages not in (None, 1):
            raise ValueError(
                f"workload {self.name!r} has a single-stage stream; "
                f"got stages={stages}")
        tasks = self.tasks

        def run_instance(i: int) -> Any:
            return tasks[i]()

        return list(range(self.n_instances)), [run_instance]

    def _stream_collect(self, outputs: List[Any]) -> List[Any]:
        """Fold the pipeline's output items into the per-instance result
        list :meth:`check` expects (identity by default)."""
        return outputs

    def streamed(self, substrate: Any = "relic", *,
                 stages: Optional[int] = None,
                 capacity: Optional[int] = None) -> List[Any]:
        """Pipelined execution over the streaming executor: the workload's
        stream items flow through its stage functions composed as a
        :class:`repro.stream.Pipeline` (each stage its own assistant for a
        registry-name ``substrate``; fused onto a single ``Scheduler``
        instance; fully inline under ``"serial"``). Returns the same
        per-instance result list as every other variant — oracle-checked
        with :meth:`check` like the rest."""
        from repro.stream import Pipeline
        items, fns = self._stream_stages(stages)
        cap = capacity if capacity is not None else max(4, min(32, len(items)))
        with Pipeline(list(fns), substrate=substrate, capacity=cap) as pipe:
            outputs = pipe.run(items)
        return self._stream_collect(outputs)

    # -- the three execution variants --------------------------------------
    def serial(self) -> List[Any]:
        """Every instance inline, in order (the paper's serial baseline)."""
        return [t() for t in self.tasks]

    def paired(self, scope: TaskScope) -> List[Any]:
        """The paper's paired offload: submit the back half of the
        instances to the scope's substrate, run the front half on the
        calling thread (producer-participates, §VI), join the handles.
        Results come back in instance order."""
        tasks = self.tasks
        half = (len(tasks) + 1) // 2          # producer's share, never empty
        handles = [scope.submit(t) for t in tasks[half:]]
        mine = [t() for t in tasks[:half]]
        if not all(h.done() for h in handles):
            # Advisory hints must never deadlock a join (the SPI rule):
            # un-park a sleeping worker before blocking on the handles.
            scope.wake_up_hint()
        return mine + [h.result() for h in handles]

    def chunked(self, scope: TaskScope, grain: int = 1) -> List[Any]:
        """Worksharing over the instances: one ``parallel_for``, ``grain``
        instances per task (the calling thread runs the final chunk)."""
        tasks = self.tasks
        out: List[Any] = [None] * len(tasks)

        def body(i: int) -> None:
            out[i] = tasks[i]()

        parallel_for(scope, len(tasks), body, grain=grain)
        return out

    # -- oracle ------------------------------------------------------------
    # Float tolerance for cross-instance agreement: instances run identical
    # inputs through the same compiled kernel, so exact equality is the
    # default; subclasses with nondeterministic reductions may relax it.
    agree_rtol: float = 0.0
    agree_atol: float = 0.0

    def check(self, results: Sequence[Any]) -> None:
        """Validate one variant's result list: instance count, cross-instance
        agreement, then the subclass oracle on instance 0. Raises
        :class:`WorkloadOracleError` (an ``AssertionError``) on mismatch."""
        if len(results) != self.n_instances:
            raise WorkloadOracleError(
                f"{self.name}: expected {self.n_instances} instance results, "
                f"got {len(results)}")
        for i, r in enumerate(results[1:], start=1):
            if not results_agree(results[0], r, rtol=self.agree_rtol,
                                 atol=self.agree_atol):
                raise WorkloadOracleError(
                    f"{self.name}: instance {i} result disagrees with "
                    "instance 0 (identical inputs must give identical "
                    "results)")
        try:
            self.check_one(results[0])
        except WorkloadOracleError:
            raise
        except AssertionError as e:
            raise WorkloadOracleError(f"{self.name}: oracle failed: {e}") from e

    def __repr__(self) -> str:
        skew = "" if self.skew is None else f", skew={self.skew}"
        return (f"{type(self).__name__}(name={self.name!r}, "
                f"n={self.n_instances}{skew})")
