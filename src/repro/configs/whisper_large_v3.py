"""whisper-large-v3 [audio]: enc-dec, conv frontend stubbed to precomputed
frame embeddings. [arXiv:2212.04356; unverified]"""

from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,            # decoder layers
    enc_layers=32,          # encoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    use_rope=False,         # sinusoidal (enc) + learned (dec) absolute positions
    tie_embeddings=True,
    frontend=FrontendConfig(kind="audio_frames", n_tokens=1500, embed_dim=1280),
    max_seq=32768,
    source="arXiv:2212.04356; unverified",
)

SMOKE = CONFIG.replace(
    name="whisper-smoke",
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, max_seq=128,
    frontend=FrontendConfig(kind="audio_frames", n_tokens=24, embed_dim=64),
)
