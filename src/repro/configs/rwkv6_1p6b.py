"""rwkv6-1.6b [ssm] "Finch": attention-free, data-dependent decay.
O(1)-state decode => runs the long_500k cell. [arXiv:2404.05892; unverified]"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,             # wkv heads = d_model / head_dim
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    use_rope=False,
    ssm=SSMConfig(kind="rwkv6", head_dim=64, chunk=64),
    source="arXiv:2404.05892; unverified",
)

SMOKE = CONFIG.replace(
    name="rwkv6-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=512,
    ssm=SSMConfig(kind="rwkv6", head_dim=16, chunk=8),
)
