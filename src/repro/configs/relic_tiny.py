"""Paper-scale tiny config (~100M) for the runnable end-to-end examples:
train a few hundred steps on CPU / 1 chip."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="relic-tiny-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32000,
    remat="none",
    source="this repo",
)

SMOKE = CONFIG.replace(
    name="relic-tiny-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=512,
)
