"""arctic-480b [moe]: 128 experts top-2 with a parallel dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff=4864, dense_residual=True),
    source="hf:Snowflake/snowflake-arctic-base; hf",
)

SMOKE = CONFIG.replace(
    name="arctic-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=96,
    vocab_size=512,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=96, dense_residual=True),
)
