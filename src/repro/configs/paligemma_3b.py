"""paligemma-3b [vlm]: SigLIP patch embeddings (stubbed) + gemma backbone,
prefix-LM attention, MQA kv=1. [arXiv:2407.07726; hf]"""

from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    act="gelu",             # GeGLU
    rope_theta=10_000.0,
    tie_embeddings=True,
    frontend=FrontendConfig(kind="image_patches", n_tokens=256, embed_dim=1152),
    source="arXiv:2407.07726; hf",
)

SMOKE = CONFIG.replace(
    name="paligemma-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128,
    vocab_size=512,
    frontend=FrontendConfig(kind="image_patches", n_tokens=8, embed_dim=32),
)
