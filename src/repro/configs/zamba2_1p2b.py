"""zamba2-1.2b [hybrid]: Mamba-2 backbone + shared attention block applied
every 6 SSM layers (one shared param set). [arXiv:2411.15242; hf]"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,            # mamba2 layers
    d_model=2048,
    n_heads=32,             # shared attention block heads
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    attn_every=6,
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, conv_kernel=4,
                  expand=2, chunk=128),
    source="arXiv:2411.15242; hf",
)

SMOKE = CONFIG.replace(
    name="zamba2-smoke",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=512, attn_every=2,
    ssm=SSMConfig(kind="mamba2", state_dim=16, head_dim=16, conv_kernel=4,
                  expand=2, chunk=8),
)
