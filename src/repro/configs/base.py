"""Config dataclasses shared by every architecture and the launch tooling."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size
    capacity_factor: float = 1.25
    shared_expert: bool = False    # llama4-style always-on shared expert
    dense_residual: bool = False   # arctic-style parallel dense MLP path
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"           # "mamba2" | "rwkv6"
    state_dim: int = 64            # N (mamba2) / head_dim (rwkv6 per-head state)
    head_dim: int = 64             # P: channels per SSM head
    conv_kernel: int = 4           # depthwise conv width (mamba2)
    expand: int = 2                # d_inner = expand * d_model
    chunk: int = 128               # chunked-scan block length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: input_specs() hands the backbone precomputed
    frame/patch embeddings, per the assignment."""

    kind: str                      # "audio_frames" | "image_patches"
    n_tokens: int                  # encoder frames / image patches
    embed_dim: int                 # embedding dim delivered by the stub


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | encdec | ssm | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- attention details -------------------------------------------------
    qk_norm: bool = False          # qwen3
    rope_theta: float = 10_000.0
    use_rope: bool = True
    attn_logit_softcap: float = 0.0
    # --- block details -----------------------------------------------------
    act: str = "silu"              # gated (swiglu) unless gated=False
    gated_mlp: bool = True
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0            # hybrid: shared attn block after every k SSM layers
    # --- encoder (enc-dec and vlm prefixes) --------------------------------
    enc_layers: int = 0
    frontend: Optional[FrontendConfig] = None
    # --- numerics / execution ----------------------------------------------
    param_dtype: str = "float32"   # training master layout (serve: bfloat16)
    compute_dtype: str = "bfloat16"
    remat: str = "full"            # full | dots | none
    scan_layers: bool = True
    attn_chunk: int = 1024         # KV-block size for chunked (flash-style) attention
    attn_chunk_q: int = 512        # Q-block size for chunked attention
    causal_skip: bool = False      # skip fully-masked KV blocks (causal only)
    attn_chunk_threshold: int = 2048   # use chunked attention when S >= this
    use_kernels: bool = False      # Pallas fast path (TPU); False on CPU/dry-run
    mlp_tp_overlap: bool = False   # Relic-ring TP MLP (needs seq act layout)
    bf16_reduce: bool = False      # bf16 cross-shard partial-sum reductions
    max_seq: int = 8192
    # --- notes --------------------------------------------------------------
    source: str = ""               # provenance tag from the assignment table

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def subquadratic(self) -> bool:
        """True iff decode state is O(1) in context length (SSM/hybrid-SSM)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int


# The four assigned LM-family shape cells.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic (O(1)-state decode)
    archs; decode shapes skipped for encoder-only archs (none assigned)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention — skipped per assignment"
        )
    return True, ""
