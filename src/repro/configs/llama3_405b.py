"""llama3-405b [dense]: GQA kv=8, 128k vocab — the TP-heavy flagship.
[arXiv:2407.21783; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
    source="arXiv:2407.21783; unverified",
)

SMOKE = CONFIG.replace(
    name="llama3-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=192,
    vocab_size=512,
)
