"""phi3-mini-3.8b [dense]: RoPE SwiGLU, MHA-equivalent GQA (kv=32).
[arXiv:2404.14219; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10_000.0,
    source="arXiv:2404.14219; unverified",
)

SMOKE = CONFIG.replace(
    name="phi3-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=512,
)
