"""Architecture registry: the ten assigned configs + the paper-scale tiny LM.

Each module exports CONFIG (the exact assigned full config) and SMOKE (a
reduced same-family config for CPU smoke tests). Full configs are only ever
instantiated abstractly (dry-run via ShapeDtypeStruct); SMOKE configs run.
"""

from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    FrontendConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    shape_applicable,
)

ARCH_IDS = [
    "whisper_large_v3",
    "llama4_maverick_400b_a17b",
    "arctic_480b",
    "granite_8b",
    "phi3_mini_3p8b",
    "llama3_405b",
    "qwen3_14b",
    "rwkv6_1p6b",
    "zamba2_1p2b",
    "paligemma_3b",
    "relic_tiny",      # paper-scale end-to-end example config
]

_ALIASES = {
    "whisper-large-v3": "whisper_large_v3",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "arctic-480b": "arctic_480b",
    "granite-8b": "granite_8b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "llama3-405b": "llama3_405b",
    "qwen3-14b": "qwen3_14b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "zamba2-1.2b": "zamba2_1p2b",
    "paligemma-3b": "paligemma_3b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke) for a in ARCH_IDS if a != "relic_tiny"}
