"""granite-8b [dense]: llama-arch code model, GQA kv=8.
[arXiv:2405.04324; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    rope_theta=10_000_000.0,
    tie_embeddings=True,
    source="arXiv:2405.04324; hf",
)

SMOKE = CONFIG.replace(
    name="granite-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160,
    vocab_size=512,
)
