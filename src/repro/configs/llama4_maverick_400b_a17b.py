"""llama4-maverick-400b-a17b [moe]: 128 experts top-1 + shared expert
("early fusion" multimodality not in the LM-backbone scope).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=128, top_k=1, d_ff=8192, shared_expert=True),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)

SMOKE = CONFIG.replace(
    name="llama4-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=512,
    moe=MoEConfig(n_experts=8, top_k=1, d_ff=128, shared_expert=True),
)
