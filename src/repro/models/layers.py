"""Shared neural-net building blocks (pure functional, dict param trees).

Every module is an ``init_*(key, ...) -> params`` / ``*(params, x, ...) -> y``
pair. Params live in ``cfg.param_dtype``; compute casts to
``cfg.compute_dtype`` (bf16 by default) with f32 accumulation where it
matters (norms, softmax, losses).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import shard_act


def dt(name: str):
    return jnp.dtype(name)


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: int):
    p = {"scale": jnp.ones((dim,), dt(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), dt(cfg.param_dtype))
    return p


def norm(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    # Keep the f32 widening sharded like the residual stream: without this,
    # GSPMD hoists the next matmul's all-gather ABOVE the bf16 downcast and
    # moves f32 activation bytes over ICI (§Perf it5 — measured 2× wire).
    xf = shard_act(xf, "batch", None, "model", kind="resid")
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    y = y.astype(x.dtype)
    return shard_act(y, "batch", None, "model", kind="resid")


def rms_norm_headwise(x: jax.Array, scale: jax.Array) -> jax.Array:
    """qk-norm (qwen3): RMS-normalize the last (head) dim."""
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def init_embed(cfg: ModelConfig, key, vocab: int, dim: int):
    # 0.02 std keeps tied-unembed logits sane at init (GPT/whisper convention)
    return {"table": _normal(key, (vocab, dim), 0.02, dt(cfg.param_dtype))}


def embed(cfg: ModelConfig, p, tokens: jax.Array) -> jax.Array:
    y = jnp.take(p["table"].astype(dt(cfg.compute_dtype)), tokens, axis=0)
    return shard_act(y, "batch", None, "model", kind="resid")


def unembed(cfg: ModelConfig, p, x: jax.Array, *, tied_table=None) -> jax.Array:
    """Project to vocab logits (f32)."""
    if tied_table is not None:
        w = tied_table.astype(dt(cfg.compute_dtype)).T  # [D, V]
    else:
        w = p["kernel"].astype(dt(cfg.compute_dtype))
    logits = jnp.einsum("...d,dv->...v", x, w).astype(jnp.float32)
    return shard_act(logits, "batch", None, "model")


def init_unembed(cfg: ModelConfig, key, dim: int, vocab: int):
    return {"kernel": _normal(key, (dim, vocab), dim ** -0.5, dt(cfg.param_dtype))}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # [Dh/2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: [..., S] (broadcastable)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [Dh/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, dim: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10_000.0 ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # [n, dim]


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU / GeGLU, or plain 2-layer)
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, dim: int, hidden: int):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": _normal(k1, (dim, hidden), dim ** -0.5, dt(cfg.param_dtype)),
        "w_down": _normal(k2, (hidden, dim), hidden ** -0.5, dt(cfg.param_dtype)),
    }
    if cfg.gated_mlp:
        p["w_gate"] = _normal(k3, (dim, hidden), dim ** -0.5, dt(cfg.param_dtype))
    return p


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {name}")


def mlp(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    cd = dt(cfg.compute_dtype)
    x = x.astype(cd)
    if cfg.mlp_tp_overlap and cfg.gated_mlp:
        from repro import sharding as shd

        mesh = shd.current_mesh()
        if (mesh is not None and "model" in mesh.axis_names
                and x.shape[1] % mesh.shape["model"] == 0):
            from repro.core.collective_matmul import mlp_ring

            # Relic two-lane ring: fused AG(gate+up) + RS(down), seq-sharded
            # residual stream; every ppermute overlaps the previous chunk's
            # matmul (docs/schedulers.md).
            return mlp_ring(cfg.act, x, p["w_gate"].astype(cd),
                            p["w_up"].astype(cd), p["w_down"].astype(cd), mesh,
                            full_unroll=not cfg.scan_layers)
    x = shard_act(x, "batch", None, None, kind="blockin")
    up = x @ p["w_up"].astype(cd)
    if cfg.gated_mlp:
        gate = _act(cfg.act, x @ p["w_gate"].astype(cd))
        h = gate * up
    else:
        h = _act(cfg.act, up)
    h = shard_act(h, "batch", None, "model")
    if cfg.bf16_reduce:
        y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(cd),
                       preferred_element_type=cd).astype(cd)
    else:
        y = h @ p["w_down"].astype(cd)
    return shard_act(y, "batch", None, "model", kind="resid")
