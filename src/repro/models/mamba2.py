"""Mamba-2 (SSD) block — chunked state-space recurrence with scalar-per-head
decay, used by the zamba2 hybrid.

The chunked algorithm is the SSD decomposition: intra-chunk terms are a
masked "attention-like" matmul against C·B^T with cumulative scalar decays;
inter-chunk state is carried by a `lax.scan` (the same SPSC chunk-state chain
as rwkv6 — see repro/kernels/ssd.py). Scalar decay keeps the log-space rescaling
numerically benign at chunk=128.

Decode carries (conv_state [B,conv_dim,k-1], ssm_state [B,H,P,N]) — O(1).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _normal, dt
from repro.sharding import shard_act


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.state_dim  # x, B, C share the conv
    return d_inner, n_heads, conv_dim


def init_mamba2(cfg: ModelConfig, key):
    pd = dt(cfg.param_dtype)
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, conv_dim = _dims(cfg)
    in_dim = 2 * d_inner + 2 * s.state_dim + n_heads  # z, x, B, C, dt
    ks = jax.random.split(key, 5)
    import numpy as np

    dt_init = jnp.asarray(
        np.exp(
            np.random.RandomState(0).uniform(
                np.log(s.dt_min), np.log(s.dt_max), size=(n_heads,)
            )
        ),
        jnp.float32,
    )
    return {
        "w_in": _normal(ks[0], (d, in_dim), d ** -0.5, pd),
        "w_out": _normal(ks[1], (d_inner, d), d_inner ** -0.5, pd),
        "conv": _normal(ks[2], (s.conv_kernel, conv_dim), 0.1, pd),
        "A_log": jnp.zeros((n_heads,), pd),          # A = -exp(A_log) in [-1, ..]
        "D": jnp.ones((n_heads,), pd),
        "dt_bias": (dt_init + jnp.log(-jnp.expm1(-dt_init))).astype(pd),
        "norm_scale": jnp.ones((d_inner,), pd),
    }


def _split_in(cfg: ModelConfig, h: jax.Array):
    s = cfg.ssm
    d_inner, n_heads, _ = _dims(cfg)
    z, x, b, c, dt_raw = jnp.split(
        h, [d_inner, 2 * d_inner, 2 * d_inner + s.state_dim,
            2 * d_inner + 2 * s.state_dim], axis=-1
    )
    return z, x, b, c, dt_raw


def _causal_conv(x: jax.Array, w: jax.Array, conv_state=None):
    """Depthwise causal conv. x: [B,S,C]; w: [K,C]."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+K-1, C]
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return jax.nn.silu(out), new_state


def ssd_chunked(
    x: jax.Array,      # [B,T,H,P]   (dt-scaled inputs)
    a: jax.Array,      # [B,T,H]     log decay (<= 0)
    b: jax.Array,      # [B,T,N]
    c: jax.Array,      # [B,T,N]
    state0: jax.Array, # [B,H,P,N]
    chunk: int,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked scalar-decay SSD. Returns (y [B,T,H,P], state [B,H,P,N])."""
    bb, t, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    xs = x.reshape(bb, nc, chunk, h, p).astype(jnp.float32)
    as_ = a.reshape(bb, nc, chunk, h).astype(jnp.float32)
    bs = b.reshape(bb, nc, chunk, n).astype(jnp.float32)
    cs = c.reshape(bb, nc, chunk, n).astype(jnp.float32)

    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))  # inclusive

    def chunk_step(state, inp):
        xc, ac, bc, cc = inp             # [B,C,H,P],[B,C,H],[B,C,N],[B,C,N]
        la = jnp.cumsum(ac, axis=1)      # [B,C,H] inclusive
        # intra-chunk: y_t = sum_{tau<=t} exp(la_t - la_tau) (c_t.b_tau) x_tau
        cb = jnp.einsum("btn,bsn->bts", cc, bc)          # [B,C,C]
        decay = jnp.exp(la[:, :, None, :] - la[:, None, :, :])  # [B,C,C,H]
        w = cb[..., None] * decay * mask[None, :, :, None]
        y = jnp.einsum("btsh,bshp->bthp", w, xc)
        # inter-chunk: y_t += c_t . (state * exp(la_t))
        y = y + jnp.einsum(
            "btn,bhpn,bth->bthp", cc, state, jnp.exp(la)
        )
        # state update: S' = exp(la_end) S + sum_tau exp(la_end - la_tau) x_tau b_tau^T
        la_end = la[:, -1]               # [B,H]
        dec_end = jnp.exp(la_end[:, None] - la)          # [B,C,H]
        state = state * jnp.exp(la_end)[..., None, None] + jnp.einsum(
            "bshp,bsn,bsh->bhpn", xc, bc, dec_end
        )
        return state, y

    state, ys = jax.lax.scan(
        chunk_step,
        state0.astype(jnp.float32),
        (xs.swapaxes(0, 1), as_.swapaxes(0, 1), bs.swapaxes(0, 1), cs.swapaxes(0, 1)),
    )
    y = ys.swapaxes(0, 1).reshape(bb, t, h, p)
    return y, state


def ssd_step(x, a, b, c, state):
    """Single-token SSD. x: [B,H,P]; a: [B,H]; b/c: [B,N]; state [B,H,P,N]."""
    xf, bf, cf = (t.astype(jnp.float32) for t in (x, b, c))
    state = state * jnp.exp(a.astype(jnp.float32))[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xf, bf
    )
    y = jnp.einsum("bhpn,bn->bhp", state, cf)
    return y.astype(x.dtype), state


def _rms(x: jax.Array, scale: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


def mamba2_block(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    """Train/prefill path. x: [B,S,D] -> [B,S,D]."""
    cd = dt(cfg.compute_dtype)
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    h = x.astype(cd) @ p["w_in"].astype(cd)
    h = shard_act(h, "batch", None, "model")
    z, xi, bi, ci, dt_raw = _split_in(cfg, h)
    conv_in = jnp.concatenate([xi, bi, ci], axis=-1)
    conv_out, _ = _causal_conv(conv_in, p["conv"].astype(cd))
    xi, bi, ci = jnp.split(conv_out, [d_inner, d_inner + s.state_dim], axis=-1)

    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt_v          # [B,S,H] log decay
    xh = xi.reshape(*xi.shape[:-1], n_heads, s.head_dim)
    x_dt = xh.astype(jnp.float32) * dt_v[..., None]

    if cfg.use_kernels:
        from repro.kernels import ops  # Pallas fast path (TPU)

        y = ops.ssd(x_dt, a, bi.astype(jnp.float32), ci.astype(jnp.float32),
                    chunk=s.chunk)
    else:
        state0 = jnp.zeros(
            (x.shape[0], n_heads, s.head_dim, s.state_dim), jnp.float32)
        y, _ = ssd_chunked(x_dt, a, bi, ci, state0, s.chunk)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:-1], d_inner).astype(cd)
    y = _rms(y * jax.nn.silu(z), p["norm_scale"])
    out = y.astype(cd) @ p["w_out"].astype(cd)
    return shard_act(out, "batch", None, "model", kind="resid")


def mamba2_block_decode(cfg: ModelConfig, p, x: jax.Array, cache: dict):
    """Decode path. x: [B,1,D]; cache: {conv_state [B,K-1,C], ssm_state [B,H,P,N]}."""
    cd = dt(cfg.compute_dtype)
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    h = x.astype(cd) @ p["w_in"].astype(cd)
    z, xi, bi, ci, dt_raw = _split_in(cfg, h)
    conv_in = jnp.concatenate([xi, bi, ci], axis=-1)   # [B,1,C]
    conv_out, new_conv = _causal_conv(
        conv_in, p["conv"].astype(cd), conv_state=cache["conv_state"]
    )
    xi, bi, ci = jnp.split(conv_out, [d_inner, d_inner + s.state_dim], axis=-1)

    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = (-jnp.exp(p["A_log"].astype(jnp.float32)) * dt_v)[:, 0]   # [B,H]
    xh = xi[:, 0].reshape(x.shape[0], n_heads, s.head_dim)
    x_dt = xh.astype(jnp.float32) * dt_v[:, 0, :, None]

    y, state = ssd_step(x_dt, a, bi[:, 0], ci[:, 0],
                        cache["ssm_state"].astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(x.shape[0], 1, d_inner).astype(cd)
    y = _rms(y * jax.nn.silu(z), p["norm_scale"])
    out = y.astype(cd) @ p["w_out"].astype(cd)
    return out, {"conv_state": new_conv, "ssm_state": state}
