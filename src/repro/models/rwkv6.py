"""RWKV-6 "Finch" time-mix and channel-mix blocks (data-dependent decay).

Training/prefill uses the **chunked-parallel form**: within a chunk the
recurrence is expanded into matmuls against cumulative-decay-rescaled r/k
(MXU-friendly), and chunk-to-chunk state is carried by a `lax.scan` — the
chunk state handoff is a literal SPSC chain (chunk t produces the state chunk
t+1 consumes), which is how the paper's pattern shows up in an attention-free
arch (see repro/kernels/wkv6.py).

Numerics: decays are computed in log space; chunk length (cfg.ssm.chunk,
default 64 for rwkv6) bounds `exp(-logA)` growth. The naive per-step scan in
``repro.kernels.ref`` is the test oracle.

Decode carries (shift_state [B,D], wkv_state [B,H,Dh,Dh]) — O(1) in context,
which is why rwkv6 runs the long_500k cell.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _normal, dt
from repro.sharding import shard_act

LORA_RANK = 64


def init_rwkv_time_mix(cfg: ModelConfig, key):
    pd = dt(cfg.param_dtype)
    d = cfg.d_model
    da = cfg.ssm.head_dim * (d // cfg.ssm.head_dim)  # attn dim == d_model here
    ks = jax.random.split(key, 10)
    p = {
        "w_r": _normal(ks[0], (d, da), d ** -0.5, pd),
        "w_k": _normal(ks[1], (d, da), d ** -0.5, pd),
        "w_v": _normal(ks[2], (d, da), d ** -0.5, pd),
        "w_g": _normal(ks[3], (d, da), d ** -0.5, pd),
        "w_o": _normal(ks[4], (da, d), da ** -0.5, pd),
        # data-dependent decay LoRA:  w_t = exp(-exp(w0 + tanh(x A) B))
        "decay_A": _normal(ks[5], (d, LORA_RANK), d ** -0.5, pd),
        "decay_B": _normal(ks[6], (LORA_RANK, da), LORA_RANK ** -0.5, pd),
        "w0": jnp.full((da,), -0.6, pd),   # init decay ~ exp(-exp(-0.6)) ≈ 0.58
        "u": _normal(ks[7], (da,), 0.3, pd),  # per-channel bonus
        # token-shift interpolation coefficients (one per stream)
        "mu": 0.5 * jnp.ones((5, d), pd),     # r,k,v,g,w
        "ln_scale": jnp.ones((da,), pd),      # per-head groupnorm scale
    }
    return p


def _token_shift(x: jax.Array, shift_state=None):
    """Previous-token stream: [B,S,D] -> [B,S,D] shifted by one."""
    if shift_state is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1]], axis=1)
    return prev


def _mix(x, prev, mu):
    return x + (prev - x) * mu


def wkv6_chunked(
    r: jax.Array,       # [B,T,H,K]
    k: jax.Array,       # [B,T,H,K]
    v: jax.Array,       # [B,T,H,K]
    logw: jax.Array,    # [B,T,H,K]  log decay, <= 0
    u: jax.Array,       # [H,K]
    state0: jax.Array,  # [B,H,K,K]
    chunk: int,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked WKV6. Returns (out [B,T,H,K], state [B,H,K,K])."""
    b, t, h, kk = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    n = t // chunk

    rs = r.reshape(b, n, chunk, h, kk).astype(jnp.float32)
    ks_ = k.reshape(b, n, chunk, h, kk).astype(jnp.float32)
    vs = v.reshape(b, n, chunk, h, kk).astype(jnp.float32)
    lw = logw.reshape(b, n, chunk, h, kk).astype(jnp.float32)

    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)  # strict
    eye = jnp.eye(chunk, dtype=jnp.float32)

    def chunk_step(state, inp):
        rc, kc, vc, lwc = inp  # [B,C,H,K]
        la = jnp.cumsum(lwc, axis=1)            # inclusive cumulative log decay
        la_prev = la - lwc                       # decay up to t-1
        r_dec = rc * jnp.exp(la_prev)            # rescaled receptance (<= |r|)
        # intra-chunk pairwise scores, numerically exact: for kept (strictly
        # causal) pairs the decay exponent la_prev_t - la_tau <= 0, so
        # clamping at 0 before exp changes nothing — it only de-NaNs the
        # masked upper triangle (which would otherwise overflow for strong
        # decays). [B,C,C,H,K] is bounded by the chunk size (<=64).
        diff = jnp.minimum(la_prev[:, :, None] - la[:, None, :], 0.0)
        scores = jnp.einsum("bthk,bshk,btshk->bhts", rc, kc, jnp.exp(diff))
        scores = scores * causal[None, None]
        diag = jnp.einsum("bthk,hk,bthk->bht", rc, u.astype(jnp.float32), kc)
        scores = scores + diag[..., None] * eye[None, None]
        out = jnp.einsum("bhts,bshk->bthk", scores, vc)
        # inter-chunk: contribution from the carried state
        out = out + jnp.einsum("bthk,bhkj->bthj", r_dec, state)
        # state update to the chunk end
        total = la[:, -1]                        # [B,H,K]
        k_fut = kc * jnp.exp(total[:, None] - la)  # decay from t to chunk end
        state = state * jnp.exp(total)[..., None] + jnp.einsum(
            "bthk,bthj->bhkj", k_fut, vc
        )
        return state, out

    state, outs = jax.lax.scan(
        chunk_step,
        state0.astype(jnp.float32),
        (rs.swapaxes(0, 1), ks_.swapaxes(0, 1), vs.swapaxes(0, 1), lw.swapaxes(0, 1)),
    )
    out = outs.swapaxes(0, 1).reshape(b, t, h, kk)
    return out.astype(r.dtype), state


def wkv6_step(r, k, v, logw, u, state):
    """Single-token recurrence (decode). r/k/v/logw: [B,H,K]; state [B,H,K,K]."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    kv = jnp.einsum("bhk,bhj->bhkj", kf, vf)
    out = jnp.einsum("bhk,bhkj->bhj", rf, state + u.astype(jnp.float32)[None, :, :, None] * kv)
    state = state * w[..., None] + kv
    return out.astype(r.dtype), state


def _project_streams(cfg: ModelConfig, p, x, prev):
    cd = dt(cfg.compute_dtype)
    h = cfg.d_model // cfg.ssm.head_dim
    k_dim = cfg.ssm.head_dim

    def heads(y):
        return y.reshape(*y.shape[:-1], h, k_dim)

    mu = p["mu"].astype(jnp.float32)
    xs = [_mix(x, prev, mu[i]).astype(cd) for i in range(5)]
    r = heads(xs[0] @ p["w_r"].astype(cd))
    k = heads(xs[1] @ p["w_k"].astype(cd))
    v = heads(xs[2] @ p["w_v"].astype(cd))
    g = jax.nn.silu(xs[3] @ p["w_g"].astype(cd))
    lora = jnp.tanh(xs[4].astype(jnp.float32) @ p["decay_A"].astype(jnp.float32))
    logw = -jnp.exp(
        p["w0"].astype(jnp.float32) + lora @ p["decay_B"].astype(jnp.float32)
    )
    logw = heads(logw)
    return r, k, v, g, logw


def _group_norm(o: jax.Array, scale: jax.Array) -> jax.Array:
    """Per-head RMS normalization of wkv output. o: [B,T,H,K]."""
    of = o.astype(jnp.float32)
    ms = (of * of).mean(-1, keepdims=True)
    of = of * jax.lax.rsqrt(ms + 1e-5)
    return of.reshape(*o.shape[:-2], -1) * scale.astype(jnp.float32)


def rwkv_time_mix(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    """Train/prefill path. x: [B,S,D]."""
    cd = dt(cfg.compute_dtype)
    h = cfg.d_model // cfg.ssm.head_dim
    prev = _token_shift(x)
    r, k, v, g, logw = _project_streams(cfg, p, x, prev)
    u = p["u"].astype(jnp.float32).reshape(h, cfg.ssm.head_dim)
    if cfg.use_kernels:
        from repro.kernels import ops  # Pallas fast path (TPU)

        out = ops.wkv6(r, k, v, logw, u, chunk=cfg.ssm.chunk)
    else:
        state0 = jnp.zeros(
            (x.shape[0], h, cfg.ssm.head_dim, cfg.ssm.head_dim), jnp.float32)
        out, _ = wkv6_chunked(r, k, v, logw, u, state0, cfg.ssm.chunk)
    out = _group_norm(out, p["ln_scale"]).astype(cd) * g
    y = out @ p["w_o"].astype(cd)
    return shard_act(y, "batch", None, "model", kind="resid")


def rwkv_time_mix_decode(cfg: ModelConfig, p, x: jax.Array, cache: dict):
    """Decode path. x: [B,1,D]; cache: {shift_state [B,D], wkv_state [B,H,K,K]}."""
    cd = dt(cfg.compute_dtype)
    h = cfg.d_model // cfg.ssm.head_dim
    prev = cache["shift_state"][:, None, :]
    r, k, v, g, logw = _project_streams(cfg, p, x, prev)
    u = p["u"].astype(jnp.float32).reshape(h, cfg.ssm.head_dim)
    out, state = wkv6_step(
        r[:, 0], k[:, 0], v[:, 0], logw[:, 0], u,
        cache["wkv_state"].astype(jnp.float32),
    )
    out = _group_norm(out[:, None], p["ln_scale"]).astype(cd) * g
    y = out @ p["w_o"].astype(cd)
    new_cache = {"shift_state": x[:, 0], "wkv_state": state}
    return y, new_cache


# ---------------------------------------------------------------------------
# Channel mix (RWKV FFN)
# ---------------------------------------------------------------------------

def init_rwkv_channel_mix(cfg: ModelConfig, key):
    pd = dt(cfg.param_dtype)
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_k": _normal(k1, (d, f), d ** -0.5, pd),
        "w_v": _normal(k2, (f, d), f ** -0.5, pd),
        "w_r": _normal(k3, (d, d), d ** -0.5, pd),
        "mu": 0.5 * jnp.ones((2, d), pd),  # k, r
    }


def rwkv_channel_mix(cfg: ModelConfig, p, x: jax.Array, shift_state=None):
    cd = dt(cfg.compute_dtype)
    prev = _token_shift(x, shift_state)
    mu = p["mu"].astype(jnp.float32)
    xk = _mix(x, prev, mu[0]).astype(cd)
    xr = _mix(x, prev, mu[1]).astype(cd)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(cd)))
    k = shard_act(k, "batch", None, "model")
    r = jax.nn.sigmoid(xr @ p["w_r"].astype(cd))
    y = r * (k @ p["w_v"].astype(cd))
    return shard_act(y, "batch", None, "model", kind="resid")
