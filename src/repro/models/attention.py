"""GQA attention: full, chunked (flash-style streaming softmax in XLA), and
cached decode paths, plus cross-attention for encoder-decoder models.

The chunked path is the *portable* flash attention: a `lax.scan` over KV
blocks carrying the running (max, denominator, accumulator) — bounded memory
in the HLO itself, so 32k-token prefill lowers without materializing S×S
scores. On TPU the Pallas kernel (`repro.kernels.flash_attention`) is the
fast path; `repro.kernels.ops` dispatches between them.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _normal, apply_rope, dt, rms_norm_headwise
from repro.sharding import shard_act

NEG_INF = -1e30


def init_attention(
    cfg: ModelConfig,
    key,
    dim: int,
    n_heads: int,
    n_kv: int,
    head_dim: int,
):
    kq, kk, kv, ko, _ = jax.random.split(key, 5)
    pd = dt(cfg.param_dtype)
    scale = dim ** -0.5
    p = {
        "wq": _normal(kq, (dim, n_heads, head_dim), scale, pd),
        "wk": _normal(kk, (dim, n_kv, head_dim), scale, pd),
        "wv": _normal(kv, (dim, n_kv, head_dim), scale, pd),
        "wo": _normal(ko, (n_heads, head_dim, dim), (n_heads * head_dim) ** -0.5, pd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), pd)
        p["k_norm"] = jnp.ones((head_dim,), pd)
    return p


# ---------------------------------------------------------------------------
# Cores (operate on projected q/k/v)
# ---------------------------------------------------------------------------

def _grouped(q: jax.Array, n_kv: int):
    """[B,S,H,Dh] -> [B,S,Kv,G,Dh]"""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def attention_full(
    q: jax.Array,          # [B,Sq,H,Dh]
    k: jax.Array,          # [B,Sk,Kv,Dh]
    v: jax.Array,          # [B,Sk,Kv,Dh]
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,
    kv_len: Optional[jax.Array] = None,
    prefix_len: Optional[int] = None,
) -> jax.Array:
    """Unchunked reference / decode path (scores materialized)."""
    n_kv = k.shape[2]
    qg = _grouped(q, n_kv)  # [B,Sq,Kv,G,Dh]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    sq, sk = q.shape[1], k.shape[1]
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = qpos[:, None] >= kpos[None, :]
        if prefix_len is not None:  # prefix-LM: bidirectional over the prefix
            mask = mask | (kpos[None, :] < prefix_len)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    if kv_len is not None:
        valid = jnp.arange(sk) < kv_len
        s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(q.shape).astype(q.dtype)


def attention_chunked(
    q: jax.Array,          # [B,Sq,H,Dh]
    k: jax.Array,          # [B,Sk,Kv,Dh]
    v: jax.Array,          # [B,Sk,Kv,Dh]
    *,
    causal: bool,
    chunk_q: int = 512,
    chunk_k: int = 1024,
    q_offset: int = 0,
    prefix_len: Optional[int] = None,
    causal_skip: bool = False,
    full_unroll: bool = False,
) -> jax.Array:
    """Flash-style two-level streaming attention in pure XLA.

    Outer scan over Q blocks; inner scan over KV blocks carrying the running
    (m, l, acc). The inner carry is the SPSC handoff of the paper's pattern:
    block t's statistics are produced for block t+1's consumption — a static
    two-lane chain with no dynamic scheduling.

    causal_skip: per-Q-block inner scans only visit KV blocks at or below the
    diagonal — removes the ~2× masked-block waste of causal attention (§Perf).
    full_unroll: statically expand both scans so HloCostAnalysis counts every
    block (dry-run cost lowerings; a rolled loop body is counted once).
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    n_kv = k.shape[2]
    g = h // n_kv
    chunk_q = min(chunk_q, sq)
    chunk_k = min(chunk_k, sk)
    nq, nk = sq // chunk_q, sk // chunk_k
    assert sq % chunk_q == 0 and sk % chunk_k == 0, (sq, chunk_q, sk, chunk_k)
    scale = dh ** -0.5

    qg = _grouped(q, n_kv).reshape(b, nq, chunk_q, n_kv, g, dh)
    kb = k.reshape(b, nk, chunk_k, n_kv, dh)
    vb = v.reshape(b, nk, chunk_k, n_kv, dh)

    def q_block(qi, q_blk, nk_used):
        # q_blk: [B,Cq,Kv,G,Dh]; inner scan over the first nk_used kv blocks
        qf = q_blk.astype(jnp.float32) * scale
        m0 = jnp.full((b, n_kv, g, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, chunk_q, n_kv, g, dh), jnp.float32)

        def kv_block(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            s = jnp.einsum("bqkgd,btkd->bkgqt", qf, k_blk.astype(jnp.float32))
            if causal:
                qpos = qi * chunk_q + jnp.arange(chunk_q) + q_offset
                kpos = ki * chunk_k + jnp.arange(chunk_k)
                mask = qpos[:, None] >= kpos[None, :]
                if prefix_len is not None:
                    mask = mask | (kpos[None, :] < prefix_len)
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
                "bkgqt,btkd->bqkgd", p, v_blk.astype(jnp.float32)
            )
            return (m_new, l, acc), None

        ks = jnp.arange(nk_used)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (ks, kb.swapaxes(0, 1)[:nk_used], vb.swapaxes(0, 1)[:nk_used]),
            unroll=nk_used if full_unroll else 1,
        )
        out = acc / l.transpose(0, 3, 1, 2)[..., None]
        return out.reshape(b, chunk_q, h, dh)

    skip = causal_skip and causal and prefix_len is None and q_offset == 0
    if skip:
        # Variable-length inner scans: q block qi only needs kv blocks
        # covering positions [0, (qi+1)*Cq) — exact causal FLOPs.
        outs = [
            q_block(qi, qg[:, qi], -(-((qi + 1) * chunk_q) // chunk_k))
            for qi in range(nq)
        ]
        out = jnp.concatenate(outs, axis=1)  # [B, Sq, H, Dh]
        return out.astype(q.dtype)

    def outer(_, args):
        qi, q_blk = args
        return None, q_block(qi, q_blk, nk)

    _, out = jax.lax.scan(
        outer, None, (jnp.arange(nq), qg.swapaxes(0, 1)),
        unroll=nq if full_unroll else 1,
    )
    # out: [nq, B, Cq, H, Dh] -> [B, Sq, H, Dh]
    out = out.swapaxes(0, 1).reshape(b, sq, h, dh)
    return out.astype(q.dtype)


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (chunked attention tiling)."""
    for c in range(min(target, n), 0, -1):
        if n % c == 0:
            return c
    return n


def attention_core(
    cfg: ModelConfig,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,
    kv_len: Optional[jax.Array] = None,
    prefix_len: Optional[int] = None,
) -> jax.Array:
    """Dispatch: kernels (TPU) > chunked (long S) > full."""
    sq, sk = q.shape[1], k.shape[1]
    if cfg.use_kernels and sq > 1 and prefix_len is None:
        from repro.kernels import ops  # deferred: kernels are optional

        return ops.flash_attention(q, k, v, causal=causal)
    if sq > 1 and max(sq, sk) >= cfg.attn_chunk_threshold and kv_len is None:
        return attention_chunked(
            q, k, v, causal=causal,
            chunk_q=_pick_chunk(sq, cfg.attn_chunk_q),
            chunk_k=_pick_chunk(sk, cfg.attn_chunk),
            q_offset=q_offset, prefix_len=prefix_len,
            causal_skip=cfg.causal_skip,
            full_unroll=not cfg.scan_layers,  # exact dry-run cost accounting
        )
    return attention_full(q, k, v, causal=causal, q_offset=q_offset,
                          kv_len=kv_len, prefix_len=prefix_len)


# ---------------------------------------------------------------------------
# Full layer-level wrappers (projections + rope + cache handling)
# ---------------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, p, x: jax.Array, x_kv: Optional[jax.Array] = None):
    cd = dt(cfg.compute_dtype)
    x = shard_act(x.astype(cd), "batch", None, None, kind="blockin")
    src = x if x_kv is None else x_kv.astype(cd)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(cd))
    if cfg.qk_norm:
        q = rms_norm_headwise(q, p["q_norm"])
        k = rms_norm_headwise(k, p["k_norm"])
    q = shard_act(q, "batch", None, "model", None)
    k = shard_act(k, "batch", None, None, None)
    v = shard_act(v, "batch", None, None, None)
    return q, k, v


def _output(cfg: ModelConfig, p, o: jax.Array) -> jax.Array:
    cd = dt(cfg.compute_dtype)
    pet = cd if cfg.bf16_reduce else None  # bf16 cross-shard partial sums
    y = jnp.einsum("bshk,hkd->bsd", o.astype(cd), p["wo"].astype(cd),
                   preferred_element_type=pet)
    return shard_act(y.astype(cd), "batch", None, "model", kind="resid")


def self_attention(
    cfg: ModelConfig,
    p,
    x: jax.Array,
    *,
    causal: bool = True,
    positions: Optional[jax.Array] = None,
    prefix_len: Optional[int] = None,
) -> jax.Array:
    """Training / prefill self-attention over [B,S,D]."""
    q, k, v = _project_qkv(cfg, p, x)
    if cfg.use_rope:
        if positions is None:
            positions = jnp.arange(x.shape[1])[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = attention_core(cfg, q, k, v, causal=causal, prefix_len=prefix_len)
    return _output(cfg, p, o)


def cross_attention(
    cfg: ModelConfig,
    p,
    x: jax.Array,
    enc: jax.Array,
) -> jax.Array:
    q, k, v = _project_qkv(cfg, p, x, x_kv=enc)
    o = attention_core(cfg, q, k, v, causal=False)
    return _output(cfg, p, o)


def decode_self_attention(
    cfg: ModelConfig,
    p,
    x: jax.Array,           # [B,1,D]
    cache: dict,            # {"k": [B,T,Kv,Dh], "v": [B,T,Kv,Dh]}
    pos: jax.Array,         # [] int32 current position
):
    """One-token decode against a fixed-length KV cache; returns (y, cache)."""
    q, k_new, v_new = _project_qkv(cfg, p, x)
    if cfg.use_rope:
        posb = jnp.broadcast_to(pos, (x.shape[0], 1))
        q = apply_rope(q, posb, cfg.rope_theta)
        k_new = apply_rope(k_new, posb, cfg.rope_theta)
    zero = jnp.int32(0)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (zero, pos.astype(jnp.int32), zero, zero))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (zero, pos.astype(jnp.int32), zero, zero))
    o = attention_core(cfg, q, k, v, causal=False, kv_len=pos + 1)
    y = _output(cfg, p, o)
    return y, {"k": k, "v": v}


def decode_cross_attention(
    cfg: ModelConfig,
    p,
    x: jax.Array,           # [B,1,D]
    cache: dict,            # {"xk": [B,T,Kv,Dh], "xv": ...} precomputed from encoder
):
    cd = dt(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
    if cfg.qk_norm:
        q = rms_norm_headwise(q, p["q_norm"])
    o = attention_core(cfg, q, cache["xk"].astype(cd), cache["xv"].astype(cd),
                       causal=False)
    return _output(cfg, p, o)


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int, n_kv: int,
                      head_dim: int, dtype=None):
    dtype = dtype or dt(cfg.compute_dtype)
    shape = (batch, max_len, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
