"""Uniform Model interface over every architecture family.

``build_model(cfg)`` returns a `Model` whose five callables are everything
the launcher, dry-run, tests, and benchmarks need:

  init(key) -> params
  loss(params, batch) -> (scalar, metrics)            # train step objective
  init_cache(batch, cache_len) -> cache               # decode state
  decode_step(params, cache, tokens, pos) -> (logits, cache)
  input_specs(shape) -> (batch_pytree of ShapeDtypeStruct, cache_len | None)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ed
from repro.models import lm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, dict], Tuple[jax.Array, dict]]
    init_cache: Callable[[int, int], Any]
    decode_step: Callable[[Any, Any, jax.Array, jax.Array], Tuple[jax.Array, Any]]
    input_specs: Callable[[ShapeConfig], Tuple[dict, Optional[int]]]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _lm_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        batch = {"tokens": _sds((b, 1), "int32")}
        return batch, s
    specs = {
        "tokens": _sds((b, s), "int32"),
        "labels": _sds((b, s), "int32"),
        "mask": _sds((b, s), "float32"),
    }
    if cfg.family == "vlm":
        n_img = cfg.frontend.n_tokens
        specs["tokens"] = _sds((b, s - n_img), "int32")
        specs["labels"] = _sds((b, s - n_img), "int32")
        specs["mask"] = _sds((b, s - n_img), "float32")
        specs["patches"] = _sds((b, n_img, cfg.frontend.embed_dim),
                                cfg.compute_dtype)
    return specs, None


def _encdec_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    t_enc = cfg.frontend.n_tokens
    if shape.kind == "decode":
        return {"tokens": _sds((b, 1), "int32")}, s
    return {
        "frames": _sds((b, t_enc, cfg.d_model), cfg.compute_dtype),
        "tokens": _sds((b, s), "int32"),
        "labels": _sds((b, s), "int32"),
        "mask": _sds((b, s), "float32"),
    }, None


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: ed.init_encdec(cfg, key),
            loss=lambda p, b: ed.encdec_loss(cfg, p, b),
            init_cache=lambda batch, cache_len: ed.init_encdec_cache(
                cfg, batch, cache_len),
            decode_step=lambda p, c, t, pos: ed.encdec_decode_step(
                cfg, p, c, t, pos),
            input_specs=lambda shape: _encdec_input_specs(cfg, shape),
        )
    return Model(
        cfg=cfg,
        init=lambda key: lm.init_lm(cfg, key),
        loss=lambda p, b: lm.lm_loss(cfg, p, b),
        init_cache=lambda batch, cache_len: lm.init_lm_cache(
            cfg, batch, cache_len),
        decode_step=lambda p, c, t, pos: lm.lm_decode_step(cfg, p, c, t, pos),
        input_specs=lambda shape: _lm_input_specs(cfg, shape),
    )
