"""Decoder-only LM assembly: dense / MoE / RWKV-6 / Zamba2-hybrid families.

Layers are **scanned** (`lax.scan` over stacked params) so that HLO size and
compile time are O(1) in depth — required for 126-layer dry-runs — with a
configurable remat policy. Decode threads per-layer caches through the same
scans.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rwkv6 as r6
from repro.sharding import shard_act


# ---------------------------------------------------------------------------
# Per-family blocks.  Every block is  (cfg, params, x, **kw) -> (x, aux)
# and has a decode twin  (cfg, params, x, cache, pos) -> (x, cache, aux).
# ---------------------------------------------------------------------------

def init_block(cfg: ModelConfig, key):
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if cfg.family in ("dense", "vlm"):
        return {
            "ln1": L.init_norm(cfg, cfg.d_model),
            "attn": attn.init_attention(cfg, k1, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, hd),
            "ln2": L.init_norm(cfg, cfg.d_model),
            "mlp": L.init_mlp(cfg, k2, cfg.d_model, cfg.d_ff),
        }
    if cfg.family == "moe":
        return {
            "ln1": L.init_norm(cfg, cfg.d_model),
            "attn": attn.init_attention(cfg, k1, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, hd),
            "ln2": L.init_norm(cfg, cfg.d_model),
            "moe": moe_mod.init_moe(cfg, k2),
        }
    if cfg.family == "ssm":  # rwkv6
        return {
            "ln1": L.init_norm(cfg, cfg.d_model),
            "rwkv": r6.init_rwkv_time_mix(cfg, k1),
            "ln2": L.init_norm(cfg, cfg.d_model),
            "cmix": r6.init_rwkv_channel_mix(cfg, k2),
        }
    if cfg.family == "hybrid":  # zamba2 mamba layer
        return {
            "ln": L.init_norm(cfg, cfg.d_model),
            "ssm": m2.init_mamba2(cfg, k1),
        }
    raise ValueError(cfg.family)


def init_shared_attn(cfg: ModelConfig, key):
    """Zamba2's shared transformer block (one param set, applied periodically)."""
    hd = cfg.resolved_head_dim
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": attn.init_attention(cfg, k1, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, hd),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "mlp": L.init_mlp(cfg, k2, cfg.d_model, cfg.d_ff),
    }


def block_fwd(cfg: ModelConfig, p, x, *, prefix_len=None):
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("dense", "vlm"):
        x = x + attn.self_attention(cfg, p["attn"], L.norm(cfg, p["ln1"], x),
                                    causal=True, prefix_len=prefix_len)
        x = x + L.mlp(cfg, p["mlp"], L.norm(cfg, p["ln2"], x))
    elif cfg.family == "moe":
        x = x + attn.self_attention(cfg, p["attn"], L.norm(cfg, p["ln1"], x),
                                    causal=True)
        y, aux = moe_mod.moe_ffn(cfg, p["moe"], L.norm(cfg, p["ln2"], x))
        x = x + y
    elif cfg.family == "ssm":
        x = x + r6.rwkv_time_mix(cfg, p["rwkv"], L.norm(cfg, p["ln1"], x))
        x = x + r6.rwkv_channel_mix(cfg, p["cmix"], L.norm(cfg, p["ln2"], x))
    elif cfg.family == "hybrid":
        x = x + m2.mamba2_block(cfg, p["ssm"], L.norm(cfg, p["ln"], x))
    else:
        raise ValueError(cfg.family)
    return x, aux


def shared_attn_fwd(cfg: ModelConfig, p, x):
    x = x + attn.self_attention(cfg, p["attn"], L.norm(cfg, p["ln1"], x),
                                causal=True)
    x = x + L.mlp(cfg, p["mlp"], L.norm(cfg, p["ln2"], x))
    return x


# --------------------------------------------------------------- decode twins

def init_block_cache(cfg: ModelConfig, batch: int, cache_len: int):
    hd = cfg.resolved_head_dim
    if cfg.family in ("dense", "vlm", "moe"):
        return {"cache": attn.init_decode_cache(cfg, batch, cache_len,
                                                cfg.n_kv_heads, hd)}
    if cfg.family == "ssm":
        h = cfg.d_model // cfg.ssm.head_dim
        k = cfg.ssm.head_dim
        return {"cache": {
            "shift_state": jnp.zeros((batch, cfg.d_model), L.dt(cfg.compute_dtype)),
            "cmix_shift_state": jnp.zeros((batch, cfg.d_model), L.dt(cfg.compute_dtype)),
            "wkv_state": jnp.zeros((batch, h, k, k), jnp.float32),
        }}
    if cfg.family == "hybrid":
        d_inner, n_heads, conv_dim = m2._dims(cfg)
        return {"cache": {
            "conv_state": jnp.zeros((batch, cfg.ssm.conv_kernel - 1, conv_dim),
                                    L.dt(cfg.compute_dtype)),
            "ssm_state": jnp.zeros((batch, n_heads, cfg.ssm.head_dim,
                                    cfg.ssm.state_dim), jnp.float32),
        }}
    raise ValueError(cfg.family)


def block_decode(cfg: ModelConfig, p, x, cache, pos):
    """Returns (x, cache)."""
    c = cache["cache"]
    if cfg.family in ("dense", "vlm", "moe"):
        y, c = attn.decode_self_attention(cfg, p["attn"],
                                          L.norm(cfg, p["ln1"], x), c, pos)
        x = x + y
        if cfg.family == "moe":
            y, _ = moe_mod.moe_ffn(cfg, p["moe"], L.norm(cfg, p["ln2"], x))
        else:
            y = L.mlp(cfg, p["mlp"], L.norm(cfg, p["ln2"], x))
        x = x + y
    elif cfg.family == "ssm":
        xn = L.norm(cfg, p["ln1"], x)
        y, tc = r6.rwkv_time_mix_decode(cfg, p["rwkv"], xn,
                                        {"shift_state": c["shift_state"],
                                         "wkv_state": c["wkv_state"]})
        x = x + y
        xn2 = L.norm(cfg, p["ln2"], x)
        y2 = r6.rwkv_channel_mix(cfg, p["cmix"], xn2,
                                 shift_state=c["cmix_shift_state"])
        x = x + y2
        c = {"shift_state": tc["shift_state"], "wkv_state": tc["wkv_state"],
             "cmix_shift_state": xn2[:, 0]}
    elif cfg.family == "hybrid":
        y, c = m2.mamba2_block_decode(cfg, p["ssm"], L.norm(cfg, p["ln"], x), c)
        x = x + y
    else:
        raise ValueError(cfg.family)
    return x, {"cache": c}


def shared_attn_decode(cfg: ModelConfig, p, x, kv_cache, pos):
    y, kv_cache = attn.decode_self_attention(cfg, p["attn"],
                                             L.norm(cfg, p["ln1"], x),
                                             kv_cache, pos)
    x = x + y
    x = x + L.mlp(cfg, p["mlp"], L.norm(cfg, p["ln2"], x))
    return x, kv_cache


# ---------------------------------------------------------------------------
# Whole-model init / forward / decode
# ---------------------------------------------------------------------------

def unrolled_scan(body, carry, xs):
    """Python-loop twin of lax.scan (scan_layers=False): exact HLO cost
    accounting for the dry-run's depth extrapolation."""
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def maybe_scan(cfg: ModelConfig, body, carry, xs):
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    return unrolled_scan(body, carry, xs)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)  # "full": save only block boundaries


def _stacked_init(cfg: ModelConfig, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_block(cfg, k))(keys)


def init_lm(cfg: ModelConfig, key):
    ke, kl, kh, ks = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": L.init_embed(cfg, ke, cfg.vocab_size, cfg.d_model),
        "layers": _stacked_init(cfg, kl, cfg.n_layers),
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_unembed(cfg, kh, cfg.d_model, cfg.vocab_size)
    if cfg.family == "hybrid" and cfg.attn_every:
        params["shared_attn"] = init_shared_attn(cfg, ks)
    if cfg.family == "vlm" and cfg.frontend is not None:
        params["img_proj"] = {
            "kernel": L._normal(ks, (cfg.frontend.embed_dim, cfg.d_model),
                                cfg.frontend.embed_dim ** -0.5,
                                L.dt(cfg.param_dtype))
        }
    return params


def _scan_blocks(cfg: ModelConfig, layers_p, x, *, prefix_len=None):
    """Scan the homogeneous block stack; returns (x, aux_sum)."""
    blk = _remat(cfg, functools.partial(block_fwd, cfg, prefix_len=prefix_len))

    if not cfg.scan_layers:
        aux = jnp.zeros((), jnp.float32)
        n = jax.tree.leaves(layers_p)[0].shape[0]
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], layers_p)
            x, a = blk(lp, x)
            aux = aux + a
        return x, aux

    def body(carry, lp):
        x, aux = carry
        x, a = blk(lp, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), layers_p)
    return x, aux


def _hybrid_groups(cfg: ModelConfig):
    k = cfg.attn_every
    full = cfg.n_layers // k if k else 0
    tail = cfg.n_layers - full * k if k else cfg.n_layers
    return full, tail


def _hybrid_fwd(cfg: ModelConfig, params, x):
    """Zamba2: groups of `attn_every` mamba layers + shared attention block."""
    full, tail = _hybrid_groups(cfg)
    k = cfg.attn_every
    layers_p = params["layers"]
    aux = jnp.zeros((), jnp.float32)
    blk = _remat(cfg, functools.partial(block_fwd, cfg))

    if full:
        shared = _remat(cfg, functools.partial(shared_attn_fwd, cfg,
                                               params["shared_attn"]))
        grouped = jax.tree.map(
            lambda a: a[: full * k].reshape(full, k, *a.shape[1:]), layers_p
        )

        def group_body(carry, gp):
            x, aux = carry

            def inner(c, lp):
                x_, a_ = c
                x_, aa = blk(lp, x_)
                return (x_, a_ + aa), None

            (x, aux), _ = maybe_scan(cfg, inner, (x, aux), gp)
            x = shared(x)
            return (x, aux), None

        (x, aux), _ = maybe_scan(cfg, group_body, (x, aux), grouped)
    if tail:
        tail_p = jax.tree.map(lambda a: a[full * k:], layers_p)
        x, a = _scan_blocks(cfg, tail_p, x)
        aux = aux + a
    return x, aux


def lm_forward(cfg: ModelConfig, params, tokens: jax.Array,
               *, extra_embed: Optional[jax.Array] = None,
               prefix_len: Optional[int] = None):
    """tokens: [B,S] -> (logits [B,S,V] f32, aux_loss)."""
    x = L.embed(cfg, params["embed"], tokens)
    if cfg.family == "vlm":
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # gemma convention
    if extra_embed is not None:
        proj = extra_embed.astype(x.dtype) @ params["img_proj"]["kernel"].astype(x.dtype)
        x = jnp.concatenate([proj, x], axis=1)
    x = shard_act(x, "batch", None, "model", kind="resid")

    if cfg.family == "hybrid":
        x, aux = _hybrid_fwd(cfg, params, x)
    else:
        x, aux = _scan_blocks(cfg, params["layers"], x, prefix_len=prefix_len)

    x = L.norm(cfg, params["final_norm"], x)
    tied = params["embed"]["table"] if cfg.tie_embeddings else None
    logits = L.unembed(cfg, params.get("lm_head"), x, tied_table=tied)
    return logits, aux


def lm_loss(cfg: ModelConfig, params, batch: dict):
    """batch: {tokens [B,S], labels [B,S], mask [B,S]} -> (loss, metrics)."""
    extra = batch.get("patches")
    logits, aux = lm_forward(
        cfg, params, batch["tokens"], extra_embed=extra,
        prefix_len=(extra.shape[1] if extra is not None else None),
    )
    if extra is not None:
        logits = logits[:, extra.shape[1]:]  # loss over text positions only
    labels = batch["labels"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = -(ll * mask).sum() / denom
    loss = ce + aux
    metrics = {"loss": loss, "ce": ce, "aux": aux,
               "tokens": mask.sum()}
    return loss, metrics


# --------------------------------------------------------------------- decode

def init_lm_cache(cfg: ModelConfig, batch: int, cache_len: int):
    one = lambda: init_block_cache(cfg, batch, cache_len)
    caches = jax.vmap(lambda _: one())(jnp.arange(cfg.n_layers))
    out = {"layers": caches}
    if cfg.family == "hybrid" and cfg.attn_every:
        full, _ = _hybrid_groups(cfg)
        hd = cfg.resolved_head_dim
        out["shared_attn"] = jax.vmap(
            lambda _: attn.init_decode_cache(cfg, batch, cache_len,
                                             cfg.n_kv_heads, hd)
        )(jnp.arange(full))
    return out


def lm_decode_step(cfg: ModelConfig, params, cache: dict, tokens: jax.Array,
                   pos: jax.Array):
    """One decode step. tokens: [B,1]; pos: [] -> (logits [B,1,V], cache)."""
    x = L.embed(cfg, params["embed"], tokens)
    if cfg.family == "vlm":
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    blk = functools.partial(block_decode, cfg)

    if cfg.family == "hybrid":
        full, tail = _hybrid_groups(cfg)
        k = cfg.attn_every
        layers_p, layer_c = params["layers"], cache["layers"]
        new_cache = {"layers": None, "shared_attn": None}
        if full:
            gp = jax.tree.map(lambda a: a[: full * k].reshape(full, k, *a.shape[1:]),
                              layers_p)
            gc = jax.tree.map(lambda a: a[: full * k].reshape(full, k, *a.shape[1:]),
                              layer_c)

            def group_body(x, inp):
                g_p, g_c, sa_c = inp

                def inner(x_, inp_):
                    lp, lc = inp_
                    x_, nc = blk(lp, x_, lc, pos)
                    return x_, nc

                x, g_c_new = maybe_scan(cfg, inner, x, (g_p, g_c))
                x, sa_c_new = shared_attn_decode(cfg, params["shared_attn"], x,
                                                 sa_c, pos)
                return x, (g_c_new, sa_c_new)

            x, (gc_new, sac_new) = maybe_scan(
                cfg, group_body, x, (gp, gc, cache["shared_attn"]))
            gc_new = jax.tree.map(
                lambda a: a.reshape(full * k, *a.shape[2:]), gc_new)
        else:
            gc_new, sac_new = None, cache.get("shared_attn")
        if tail:
            tp = jax.tree.map(lambda a: a[full * k:], layers_p)
            tc = jax.tree.map(lambda a: a[full * k:], layer_c)

            def inner(x_, inp_):
                lp, lc = inp_
                x_, nc = blk(lp, x_, lc, pos)
                return x_, nc

            x, tc_new = maybe_scan(cfg, inner, x, (tp, tc))
            lc_new = (jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                   gc_new, tc_new)
                      if gc_new is not None else tc_new)
        else:
            lc_new = gc_new
        new_cache = {"layers": lc_new}
        if sac_new is not None:
            new_cache["shared_attn"] = sac_new
    else:
        def body(x, inp):
            lp, lc = inp
            x, nc = blk(lp, x, lc, pos)
            return x, nc

        x, lc_new = maybe_scan(cfg, body, x,
                               (params["layers"], cache["layers"]))
        new_cache = {"layers": lc_new}

    x = L.norm(cfg, params["final_norm"], x)
    tied = params["embed"]["table"] if cfg.tie_embeddings else None
    logits = L.unembed(cfg, params.get("lm_head"), x, tied_table=tied)
    return logits, new_cache
