"""Top-k routed mixture-of-experts with capacity-based dispatch.

Implementation notes (these choices matter for the roofline):

  * **No dense GShard dispatch einsum.** The classic `[G,T,E,C]` one-hot
    einsum costs `T*E*C*D` MAC FLOPs — orders of magnitude more than the
    expert FFNs themselves at 128 experts. We instead build an `[B,E,C]`
    integer routing table (masked-cumsum positions, scatter once) and use
    *gathers* both to dispatch and to combine, so compiled FLOPs stay at the
    true `topk * cf * T * D * F` scale.
  * Routing is per-group where a group is one batch row (tokens stay on
    their data shard; only the `[B,E,C,D]` expert buffers reshard across the
    `model` axis, which is the all-to-all the paper-style two-lane schedule
    overlaps in §Perf).
  * Experts are stacked `[E, D, F]` and sharded E→model (8 experts/device at
    E=128, TP=16).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import _act, _normal, dt, init_mlp, mlp
from repro.sharding import shard_act


def init_moe(cfg: ModelConfig, key):
    mc = cfg.moe
    assert mc is not None
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    pd = dt(cfg.param_dtype)
    d, f, e = cfg.d_model, mc.d_ff, mc.n_experts
    p = {
        "router": _normal(kr, (d, e), d ** -0.5, pd),
        "w_gate": _normal(kg, (e, d, f), d ** -0.5, pd),
        "w_up": _normal(ku, (e, d, f), d ** -0.5, pd),
        "w_down": _normal(kd, (e, f, d), f ** -0.5, pd),
    }
    if mc.shared_expert or mc.dense_residual:
        p["shared"] = init_mlp(cfg, ks, d, f if mc.shared_expert else cfg.d_ff)
    return p


def _capacity(mc: MoEConfig, tokens_per_group: int) -> int:
    c = int(mc.top_k * tokens_per_group * mc.capacity_factor / mc.n_experts)
    return max(c, 4)


def route(mc: MoEConfig, logits: jax.Array, capacity: int):
    """logits: [B,S,E] -> routing tables.

    Returns (expert_idx [B,S,K], probs [B,S,K], slot [B,S,K], keep [B,S,K],
    aux_loss scalar).
    """
    b, s, e = logits.shape
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    probs, expert_idx = jax.lax.top_k(gates, mc.top_k)          # [B,S,K]

    # Position of each (token, choice) inside its expert's buffer: masked
    # cumulative count over the sequence, counting earlier top-k slots first.
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)      # [B,S,K,E]
    # counts of the same expert in earlier slots of the same token
    prior_slots = jnp.cumsum(onehot, axis=2) - onehot            # [B,S,K,E]
    # counts from earlier tokens (all slots)
    prior_tokens = jnp.cumsum(onehot.sum(2), axis=1) - onehot.sum(2)  # [B,S,E]
    pos = prior_tokens[:, :, None, :] + prior_slots              # [B,S,K,E]
    slot = (pos * onehot).sum(-1)                                # [B,S,K]
    keep = slot < capacity

    # Load-balance aux loss (Switch-style).
    me = gates.mean(axis=(0, 1))                                 # [E]
    ce = onehot.sum(2).astype(jnp.float32).mean(axis=(0, 1)) / mc.top_k
    aux = e * jnp.sum(me * ce)

    return expert_idx, probs, slot, keep, aux


def moe_ffn(cfg: ModelConfig, p, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,D] -> (y [B,S,D], aux_loss)."""
    mc = cfg.moe
    cd = dt(cfg.compute_dtype)
    b, s, d = x.shape
    e = mc.n_experts
    cap = _capacity(mc, s)

    logits = jnp.einsum("bsd,de->bse", x.astype(cd), p["router"].astype(cd))
    expert_idx, probs, slot, keep, aux = route(mc, logits, cap)

    # ----- dispatch: build [B,E,C] token-index table, then gather ----------
    # flatten the K choices; dropped (overflow) entries scatter out of range.
    flat_e = expert_idx.reshape(b, s * mc.top_k)
    flat_slot = jnp.where(keep, slot, cap).reshape(b, s * mc.top_k)
    token_of_choice = jnp.broadcast_to(
        jnp.arange(s)[:, None], (s, mc.top_k)
    ).reshape(s * mc.top_k)

    def build_table(e_row, slot_row):
        tbl = jnp.zeros((e, cap + 1), jnp.int32)
        tbl = tbl.at[e_row, slot_row].set(token_of_choice, mode="drop")
        return tbl[:, :cap]

    idx_table = jax.vmap(build_table)(flat_e, flat_slot)         # [B,E,C]

    x_e = jnp.take_along_axis(
        x[:, :, None, :], idx_table.reshape(b, e * cap)[..., None, None], axis=1
    )
    x_e = x_e.reshape(b, e, cap, d)
    x_e = shard_act(x_e, "batch", "model", None, None)

    # ----- expert FFNs (batched over E) -------------------------------------
    xc = x_e.astype(cd)
    up = jnp.einsum("becd,edf->becf", xc, p["w_up"].astype(cd))
    gate = _act(cfg.act, jnp.einsum("becd,edf->becf", xc, p["w_gate"].astype(cd)))
    h = gate * up
    y_e = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(cd))
    y_e = shard_act(y_e, "batch", "model", None, None)

    # ----- combine: K gathers back to token order ---------------------------
    y = jnp.zeros((b, s, d), jnp.float32)
    flat_ec = (expert_idx * cap + jnp.minimum(slot, cap - 1))    # [B,S,K]
    y_flat = y_e.reshape(b, e * cap, d)
    for j in range(mc.top_k):
        gj = jnp.take_along_axis(y_flat, flat_ec[:, :, j][..., None], axis=1)
        wj = (probs[:, :, j] * keep[:, :, j]).astype(jnp.float32)
        y = y + wj[..., None] * gj.astype(jnp.float32)

    # normalize combined top-k weights (llama4/arctic convention)
    denom = (probs * keep).sum(-1, keepdims=True)
    y = y / jnp.maximum(denom, 1e-9)

    y = y.astype(x.dtype)
    if "shared" in p:
        y = y + mlp(cfg, p["shared"], x)
    y = shard_act(y, "batch", None, "model", kind="resid")
    return y, aux * mc.aux_loss_weight
