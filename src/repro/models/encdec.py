"""Whisper-style encoder–decoder backbone.

The audio frontend (two conv layers over log-mel) is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings
[B, T_enc, D], and the encoder consumes them directly (sinusoidal positions,
non-causal self-attention). The decoder is a standard causal stack with
cross-attention; embeddings are tied (whisper convention); layernorm + GELU,
no RoPE (learned decoder positions).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.lm import maybe_scan
from repro.sharding import shard_act


def _init_enc_block(cfg: ModelConfig, key):
    hd = cfg.resolved_head_dim
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": attn.init_attention(cfg, k1, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, hd),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "mlp": L.init_mlp(cfg, k2, cfg.d_model, cfg.d_ff),
    }


def _init_dec_block(cfg: ModelConfig, key):
    hd = cfg.resolved_head_dim
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "self_attn": attn.init_attention(cfg, k1, cfg.d_model, cfg.n_heads,
                                         cfg.n_kv_heads, hd),
        "lnx": L.init_norm(cfg, cfg.d_model),
        "cross_attn": attn.init_attention(cfg, k2, cfg.d_model, cfg.n_heads,
                                          cfg.n_kv_heads, hd),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "mlp": L.init_mlp(cfg, k3, cfg.d_model, cfg.d_ff),
    }


def init_encdec(cfg: ModelConfig, key):
    ke, k1, k2, kp = jax.random.split(key, 4)
    enc_keys = jax.random.split(k1, cfg.enc_layers)
    dec_keys = jax.random.split(k2, cfg.n_layers)
    return {
        "embed": L.init_embed(cfg, ke, cfg.vocab_size, cfg.d_model),
        "pos_embed": L._normal(kp, (cfg.max_seq, cfg.d_model), 0.01,
                               L.dt(cfg.param_dtype)),
        "enc_layers": jax.vmap(lambda k: _init_enc_block(cfg, k))(enc_keys),
        "enc_norm": L.init_norm(cfg, cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _init_dec_block(cfg, k))(dec_keys),
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }


def _enc_block(cfg, p, x):
    x = x + attn.self_attention(cfg, p["attn"], L.norm(cfg, p["ln1"], x),
                                causal=False)
    x = x + L.mlp(cfg, p["mlp"], L.norm(cfg, p["ln2"], x))
    return x


def _dec_block(cfg, p, x, enc_out):
    x = x + attn.self_attention(cfg, p["self_attn"], L.norm(cfg, p["ln1"], x),
                                causal=True)
    x = x + attn.cross_attention(cfg, p["cross_attn"], L.norm(cfg, p["lnx"], x),
                                 enc_out)
    x = x + L.mlp(cfg, p["mlp"], L.norm(cfg, p["ln2"], x))
    return x


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


def encode(cfg: ModelConfig, params, frames: jax.Array) -> jax.Array:
    """frames: [B,T_enc,D] (stubbed frontend output)."""
    cd = L.dt(cfg.compute_dtype)
    x = frames.astype(cd) + L.sinusoidal_positions(frames.shape[1],
                                                   cfg.d_model).astype(cd)
    x = shard_act(x, "batch", None, "model", kind="resid")
    blk = _remat(cfg, functools.partial(_enc_block, cfg))

    def body(x, lp):
        return blk(lp, x), None

    x, _ = maybe_scan(cfg, body, x, params["enc_layers"])
    return L.norm(cfg, params["enc_norm"], x)


def decode_train(cfg: ModelConfig, params, tokens: jax.Array,
                 enc_out: jax.Array) -> jax.Array:
    x = L.embed(cfg, params["embed"], tokens)
    s = tokens.shape[1]
    x = x + params["pos_embed"][:s].astype(x.dtype)[None]
    x = shard_act(x, "batch", None, "model", kind="resid")
    blk = _remat(cfg, functools.partial(_dec_block, cfg))

    def body(x, lp):
        return blk(lp, x, enc_out), None

    x, _ = maybe_scan(cfg, body, x, params["dec_layers"])
    x = L.norm(cfg, params["final_norm"], x)
    return L.unembed(cfg, None, x, tied_table=params["embed"]["table"])


def encdec_loss(cfg: ModelConfig, params, batch: dict):
    """batch: {frames [B,T,D], tokens [B,S], labels [B,S], mask?}."""
    enc_out = encode(cfg, params, batch["frames"])
    logits = decode_train(cfg, params, batch["tokens"], enc_out)
    labels = batch["labels"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    ce = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return ce, {"loss": ce, "ce": ce, "aux": jnp.zeros(()), "tokens": mask.sum()}


# --------------------------------------------------------------------- decode

def init_encdec_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Self-attn KV caches + precomputed cross-attn K/V (filled at prefill)."""
    hd = cfg.resolved_head_dim
    cd = L.dt(cfg.compute_dtype)
    enc_len = cfg.frontend.n_tokens if cfg.frontend else cfg.max_seq

    def one(_):
        return {
            "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), cd),
            "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), cd),
            "xk": jnp.zeros((batch, enc_len, cfg.n_kv_heads, hd), cd),
            "xv": jnp.zeros((batch, enc_len, cfg.n_kv_heads, hd), cd),
        }

    return {"layers": jax.vmap(one)(jnp.arange(cfg.n_layers))}


def prefill_cross_cache(cfg: ModelConfig, params, cache, enc_out: jax.Array):
    """Compute per-layer cross K/V from encoder output once."""
    cd = L.dt(cfg.compute_dtype)

    def per_layer(lp):
        k = jnp.einsum("btd,dhk->bthk", enc_out.astype(cd),
                       lp["cross_attn"]["wk"].astype(cd))
        v = jnp.einsum("btd,dhk->bthk", enc_out.astype(cd),
                       lp["cross_attn"]["wv"].astype(cd))
        return k, v

    xk, xv = jax.vmap(per_layer)(params["dec_layers"])
    new = dict(cache["layers"])
    new["xk"], new["xv"] = xk, xv
    return {"layers": new}


def encdec_decode_step(cfg: ModelConfig, params, cache, tokens: jax.Array,
                       pos: jax.Array):
    """One decoder token. tokens: [B,1] -> (logits, cache)."""
    x = L.embed(cfg, params["embed"], tokens)
    pe = jax.lax.dynamic_slice(params["pos_embed"], (pos, jnp.int32(0)),
                               (1, cfg.d_model))
    x = x + pe.astype(x.dtype)[None]

    def body(x, inp):
        lp, lc = inp
        y, kv = attn.decode_self_attention(
            cfg, lp["self_attn"], L.norm(cfg, lp["ln1"], x),
            {"k": lc["k"], "v": lc["v"]}, pos)
        x = x + y
        x = x + attn.decode_cross_attention(
            cfg, lp["cross_attn"], L.norm(cfg, lp["lnx"], x),
            {"xk": lc["xk"], "xv": lc["xv"]})
        x = x + L.mlp(cfg, lp["mlp"], L.norm(cfg, lp["ln2"], x))
        return x, {**kv, "xk": lc["xk"], "xv": lc["xv"]}

    x, new_layers = maybe_scan(cfg, body, x,
                               (params["dec_layers"], cache["layers"]))
    x = L.norm(cfg, params["final_norm"], x)
    logits = L.unembed(cfg, None, x, tied_table=params["embed"]["table"])
    return logits, {"layers": new_layers}
