"""RWKV-6 WKV chunked recurrence as a Pallas TPU kernel.

Layout: [B, H, T, K]. Grid: (batch, head, chunk) — the chunk axis is
sequential; the [K, K] state matrix lives in VMEM scratch and is handed from
chunk t to chunk t+1 (the SPSC chunk-state chain pattern, here with
zero HBM round-trips for the state). Within a chunk the recurrence is the
matmul-form expansion (cumulative log-decay rescaling), so the MXU does the
work while the next chunk's r/k/v/w blocks stream in.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(chunk, r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_ref):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, 0].astype(jnp.float32)      # [C, K]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)         # [K]

    la = jnp.cumsum(lw, axis=0)              # inclusive cumulative log decay
    la_prev = la - lw
    r_dec = r * jnp.exp(la_prev)

    c = r.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    strict = (row > col).astype(jnp.float32)
    # pairwise per-channel decays, clamped at 0 — exact for the kept strict-
    # causal pairs (their exponent is <= 0), NaN-proof for the masked ones.
    diff = jnp.minimum(la_prev[:, None, :] - la[None, :, :], 0.0)  # [C,C,K]
    scores = (r[:, None, :] * k[None, :, :] * jnp.exp(diff)).sum(-1) * strict
    diag = (r * u[None, :] * k).sum(-1)      # bonus term at tau == t
    scores = scores + jnp.where(row == col, diag[:, None], 0.0)

    out = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    out = out + jnp.dot(r_dec, state_ref[...], preferred_element_type=jnp.float32)
    o_ref[0, 0] = out.astype(o_ref.dtype)

    total = la[-1]                           # [K]
    k_fut = k * jnp.exp(total[None, :] - la)
    state_ref[...] = state_ref[...] * jnp.exp(total)[:, None] + jnp.dot(
        k_fut.T, v, preferred_element_type=jnp.float32)


def wkv6_bhtk(
    r: jax.Array,      # [B, H, T, K]
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,   # [B, H, T, K] log decay (<= 0)
    u: jax.Array,      # [H, K]
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    b, h, t, kk = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    kernel = functools.partial(_wkv_kernel, chunk)
    spec = pl.BlockSpec((1, 1, chunk, kk), lambda bi, hi, ci: (bi, hi, ci, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, h, t // chunk),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, kk), lambda bi, hi, ci: (hi, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, t, kk), r.dtype),
        scratch_shapes=[pltpu.VMEM((kk, kk), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
