"""relic_matmul — the paper's SPSC pipeline as a Pallas TPU matmul kernel.

The Relic mapping (docs/schedulers.md): the Pallas grid pipeline double-buffers
every BlockSpec operand — while the MXU (consumer lane) contracts block
(i, j, k), the DMA engines (producer lane) are already copying block
(i, j, k+1) HBM→VMEM. The in-flight VMEM block pair is a bounded SPSC queue
of depth 2 with DMA-completion semaphores as the lock-free synchronization;
roles are fixed, there is no dynamic scheduling — exactly the paper's design
point, realized by hardware lanes instead of SMT threads.

Tiling: (bm × bk) @ (bk × bn) accumulated in an f32 VMEM scratch tile.
MXU-aligned defaults (multiples of 128). A fused gated variant
(`relic_matmul_gated`) computes act(x@Wg) * (x@Wu) without materializing
either intermediate in HBM — the beyond-paper fusion used by §Perf.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, y_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def relic_matmul(
    x: jax.Array,               # [M, K]
    y: jax.Array,               # [K, N]
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"dims ({m},{n},{k}) must tile by ({bm},{bn},{bk})")
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        _mm_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)


def _gated_kernel(act_name, x_ref, wg_ref, wu_ref, o_ref, accg_ref, accu_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    accg_ref[...] += jnp.dot(x_ref[...], wg_ref[...],
                             preferred_element_type=jnp.float32)
    accu_ref[...] += jnp.dot(x_ref[...], wu_ref[...],
                             preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        g = accg_ref[...]
        if act_name == "silu":
            g = g * jax.nn.sigmoid(g)
        elif act_name == "gelu":
            g = jax.nn.gelu(g)
        o_ref[...] = (g * accu_ref[...]).astype(o_ref.dtype)


def relic_matmul_gated(
    x: jax.Array,               # [M, K]
    w_gate: jax.Array,          # [K, N]
    w_up: jax.Array,            # [K, N]
    *,
    act: str = "silu",
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
) -> jax.Array:
    """act(x @ w_gate) * (x @ w_up), fused — no HBM intermediates."""
    m, k = x.shape
    n = w_gate.shape[1]
    assert w_gate.shape == w_up.shape == (k, n)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        functools.partial(_gated_kernel, act),
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up)
