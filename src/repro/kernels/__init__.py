"""Pallas TPU kernels for the compute hot-spots, each with a jit wrapper in
``ops`` and an independent pure-jnp oracle in ``ref``:

  relic_matmul      — tiled matmul; the HBM→VMEM BlockSpec pipeline is the
                      paper's SPSC producer/consumer ring (docs/schedulers.md)
  relic_matmul_gated— fused act(x@Wg)*(x@Wu) (no HBM intermediates)
  flash_attention   — GQA causal/full streaming attention
  wkv6              — RWKV-6 chunked recurrence (VMEM-resident state chain)
  ssd               — Mamba-2 chunked recurrence

Validated on CPU with interpret=True; compiled natively on TPU backends.
"""

from repro.kernels import ops, ref  # noqa: F401
