"""Mamba-2 SSD chunked recurrence as a Pallas TPU kernel.

Layout: x [B, H, T, P], a (log decay) [B, H, T], b/c [B, T, N] (shared across
heads, n_groups=1). Grid: (batch, head, chunk), sequential chunk axis with
the [P, N] state in VMEM scratch — same SPSC chunk-state chain as wkv6 but
with scalar-per-step decay, so the intra-chunk term is a clean C×C matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(chunk, x_ref, a_ref, b_ref, c_ref, o_ref, state_ref):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)      # [C, P]
    a = a_ref[0, 0].astype(jnp.float32)      # [C]
    b = b_ref[0].astype(jnp.float32)         # [C, N]
    c = c_ref[0].astype(jnp.float32)         # [C, N]

    la = jnp.cumsum(a)                       # [C] inclusive
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)    # [C, C]
    n = x.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    decay = jnp.exp(la[:, None] - la[None, :])
    w = jnp.where(row >= col, cb * decay, 0.0)
    y = jnp.dot(w, x, preferred_element_type=jnp.float32)       # [C, P]

    # inter-chunk: y_t += exp(la_t) * c_t . state   (state: [P, N])
    y = y + jnp.exp(la)[:, None] * jnp.dot(
        c, state_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[0, 0] = y.astype(o_ref.dtype)

    la_end = la[-1]
    dec_end = jnp.exp(la_end - la)           # [C]
    state_ref[...] = state_ref[...] * jnp.exp(la_end) + jnp.dot(
        (x * dec_end[:, None]).T, b, preferred_element_type=jnp.float32)


def ssd_bhtp(
    x: jax.Array,      # [B, H, T, P]
    a: jax.Array,      # [B, H, T]
    b: jax.Array,      # [B, T, N]
    c: jax.Array,      # [B, T, N]
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bb, h, t, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    kernel = functools.partial(_ssd_kernel, chunk)
    return pl.pallas_call(
        kernel,
        grid=(bb, h, t // chunk),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bb, h, t, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c)
