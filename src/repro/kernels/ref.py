"""Pure-jnp oracles for every Pallas kernel (independent implementations —
no code shared with the kernels or the model fast paths)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array, out_dtype=None) -> jax.Array:
    out = jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))
    return out.astype(out_dtype or x.dtype)


def matmul_gated_ref(x, w_gate, w_up, act: str = "silu", out_dtype=None):
    xf = x.astype(jnp.float32)
    g = xf @ w_gate.astype(jnp.float32)
    if act == "silu":
        g = g * jax.nn.sigmoid(g)
    elif act == "gelu":
        g = jax.nn.gelu(g)
    out = g * (xf @ w_up.astype(jnp.float32))
    return out.astype(out_dtype or x.dtype)


def attention_ref(q, k, v, *, causal=True):
    """q: [B,H,Sq,D]; k/v: [B,Hkv,Sk,D] (GQA by head repeat)."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        sk = k.shape[2]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def wkv6_ref(r, k, v, logw, u):
    """Naive per-step recurrence. All inputs [B,H,T,K]; u [H,K]."""
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, logw))
    uf = u.astype(jnp.float32)
    b, h, t, kk = rf.shape
    state0 = jnp.zeros((b, h, kk, kk), jnp.float32)

    def step(state, inp):
        rt, kt, vt, lwt = inp   # [B,H,K]
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        out = jnp.einsum("bhi,bhij->bhj", rt,
                         state + uf[None, :, :, None] * kv)
        state = state * jnp.exp(lwt)[..., None] + kv
        return state, out

    xs = tuple(x.transpose(2, 0, 1, 3) for x in (rf, kf, vf, wf))
    _, outs = jax.lax.scan(step, state0, xs)
    return outs.transpose(1, 2, 0, 3).astype(r.dtype)


def ssd_ref(x, a, b, c):
    """Naive per-step SSD. x [B,H,T,P]; a [B,H,T]; b/c [B,T,N]."""
    xf, af, bf, cf = (t.astype(jnp.float32) for t in (x, a, b, c))
    bb, h, t, p = xf.shape
    n = bf.shape[-1]
    state0 = jnp.zeros((bb, h, p, n), jnp.float32)

    def step(state, inp):
        xt, at, bt, ct = inp     # [B,H,P], [B,H], [B,N], [B,N]
        state = state * jnp.exp(at)[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt, bt)
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    xs = (xf.transpose(2, 0, 1, 3), af.transpose(2, 0, 1),
          bf.transpose(1, 0, 2), cf.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 2, 0, 3).astype(x.dtype)
