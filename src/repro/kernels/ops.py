"""jit'd public wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the compiled kernels run natively; on any
other backend (this CPU container) `interpret=True` executes the kernel body
in Python for correctness validation. Shapes that don't satisfy a kernel's
tiling constraints fall back to the jnp reference (production systems need
the fallback anyway — e.g. whisper's 1500-frame encoder).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.relic_matmul import relic_matmul as _relic_matmul
from repro.kernels.relic_matmul import relic_matmul_gated as _relic_matmul_gated
from repro.kernels.ssd import ssd_bhtp
from repro.kernels.wkv6 import wkv6_bhtk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm=256, bn=256, bk=512):
    m, k = x.shape
    n = y.shape[1]
    if m % min(bm, m) or n % min(bn, n) or k % min(bk, k):
        return ref.matmul_ref(x, y)
    return _relic_matmul(x, y, bm=bm, bn=bn, bk=bk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn", "bk"))
def matmul_gated(x, w_gate, w_up, *, act="silu", bm=256, bn=256, bk=512):
    m, k = x.shape
    n = w_gate.shape[1]
    if m % min(bm, m) or n % min(bn, n) or k % min(bk, k):
        return ref.matmul_gated_ref(x, w_gate, w_up, act)
    return _relic_matmul_gated(x, w_gate, w_up, act=act, bm=bm, bn=bn, bk=bk,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, *, causal=True, bq=256, bk=256):
    """Model layout [B,S,H,D] in/out; GQA via kv-head grouping."""
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    sq, sk = qt.shape[2], kt.shape[2]
    h, hkv = qt.shape[1], kt.shape[1]
    if sq % min(bq, sq) or sk % min(bk, sk) or h % hkv:
        o = ref.attention_ref(qt, kt, vt, causal=causal)
    else:
        o = flash_attention_bhsd(qt, kt, vt, causal=causal, bq=bq, bk=bk,
                                 interpret=_interpret())
    return o.swapaxes(1, 2)


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, logw, u, *, chunk=64):
    """Model layout [B,T,H,K] in/out; u [H,K]."""
    rt, kt, vt, wt = (a.swapaxes(1, 2) for a in (r, k, v, logw))
    t = rt.shape[2]
    if t % min(chunk, t):
        o = ref.wkv6_ref(rt, kt, vt, wt, u)
    else:
        o = wkv6_bhtk(rt, kt, vt, wt, u, chunk=chunk, interpret=_interpret())
    return o.swapaxes(1, 2)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(x, a, b, c, *, chunk=128):
    """x [B,T,H,P]; a [B,T,H]; b/c [B,T,N] in model layout."""
    xt = x.swapaxes(1, 2)
    at = a.swapaxes(1, 2)
    t = xt.shape[2]
    if t % min(chunk, t):
        o = ref.ssd_ref(xt, at, b, c)
    else:
        o = ssd_bhtp(xt, at, b, c, chunk=chunk, interpret=_interpret())
    return o.swapaxes(1, 2)
