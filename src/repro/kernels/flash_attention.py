"""Flash attention (GQA, optional causal) as a Pallas TPU kernel.

Layout: [B, H, S, D] (ops.py transposes from the model's [B, S, H, D]).
Grid: (batch, q_head, q_block, kv_block) — the kv_block axis is the
sequential consumer loop; running (m, l, acc) live in VMEM scratch across the
kv sweep and the output block is flushed on the last kv step. The BlockSpec
pipeline prefetches K/V block t+1 while block t is being consumed — the same
Relic SPSC producer/consumer structure as relic_matmul.

Causal blocks that are fully masked are skipped with `pl.when` (no MXU work),
which is the kernel-level version of the §Perf causal-waste iteration.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(causal, scale, bq, bk, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip kv blocks strictly above the diagonal band
    run = True
    if causal:
        run = ki * bk <= qi * bq + (bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)               # [bk, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bk]
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[:, :1]                              # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)                             # [bq, bk]
        corr = jnp.exp(m_prev - m_new)                     # [bq, 1]
        l_ref[:, :1] = l_ref[:, :1] * corr + p.sum(-1, keepdims=True)
        m_ref[:, :1] = m_new
        v = v_ref[0, 0].astype(jnp.float32)                # [bk, D]
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(3) - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] / l_ref[:, :1]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,               # [B, H, Sq, D]
    k: jax.Array,               # [B, Hkv, Sk, D]
    v: jax.Array,               # [B, Hkv, Sk, D]
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = h // hkv
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    scale = d ** -0.5
    kernel = functools.partial(_fa_kernel, causal, scale, bq, bk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (lane-padded)
            pltpu.VMEM((bq, 128), jnp.float32),   # running denominator
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
