"""Data pipeline with a Relic-prefetched SPSC batch queue.

The host-side instance of the paper's pattern (DESIGN.md §2): the **assistant
thread produces** batches (synthetic generation / memmap reads / host->device
transfer release the GIL) while the **main thread consumes** them in the
train loop. `wake_up_hint()` is issued when the loop starts, `sleep_hint()`
between epochs/evals — the paper's explicit control points.

Determinism/restart: batch `i` is a pure function of (seed, i, shard), so
resuming from step `i` after a failure replays the exact stream; no iterator
state needs checkpointing beyond the step counter.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Iterator, Optional

import numpy as np

from repro.core.relic import Relic
from repro.core.spsc import SpscRing


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    shard: int = 0          # this host's index
    num_shards: int = 1
    prefetch: int = 8       # SPSC queue depth for prefetched batches


class SyntheticLM:
    """Seeded synthetic token stream (zipf-ish marginals so losses move)."""

    def __init__(self, dc: DataConfig):
        self.dc = dc
        probs = 1.0 / np.arange(1, dc.vocab_size + 1) ** 1.1
        self._probs = probs / probs.sum()

    def batch(self, index: int) -> dict:
        dc = self.dc
        rng = np.random.default_rng(
            np.random.SeedSequence([dc.seed, index, dc.shard]))
        b = dc.global_batch // dc.num_shards
        toks = rng.choice(dc.vocab_size, size=(b, dc.seq_len + 1),
                          p=self._probs).astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((b, dc.seq_len), np.float32),
        }


class MemmapLM:
    """Flat token file (np.memmap) chunked into fixed-length sequences."""

    def __init__(self, dc: DataConfig, path: str, dtype=np.int32):
        self.dc = dc
        self._data = np.memmap(path, dtype=dtype, mode="r")
        self._n_seqs = (len(self._data) - 1) // dc.seq_len

    def batch(self, index: int) -> dict:
        dc = self.dc
        rng = np.random.default_rng(
            np.random.SeedSequence([dc.seed, index, dc.shard]))
        b = dc.global_batch // dc.num_shards
        starts = rng.integers(0, self._n_seqs, size=b) * dc.seq_len
        toks = np.stack([np.asarray(self._data[s:s + dc.seq_len + 1])
                         for s in starts]).astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((b, dc.seq_len), np.float32),
        }


class PrefetchPipeline:
    """SPSC-prefetched batch stream driven by a Relic assistant."""

    def __init__(self, source, dc: DataConfig, start_index: int = 0,
                 transform: Optional[Callable[[dict], dict]] = None):
        self.source = source
        self.dc = dc
        self._next_submit = start_index
        self._transform = transform
        self._ring = SpscRing(dc.prefetch)
        self._relic = Relic(capacity=dc.prefetch, start_awake=False)
        self._started = False

    # -- assistant-side task ------------------------------------------------
    def _produce(self, index: int) -> None:
        batch = self.source.batch(index)
        if self._transform is not None:
            batch = self._transform(batch)
        while not self._ring.push((index, batch)):
            time.sleep(0)  # bounded queue backpressure

    # -- main-thread API ----------------------------------------------------
    def start(self) -> "PrefetchPipeline":
        if not self._started:
            self._relic.start()
            self._relic.wake_up_hint()
            for _ in range(self.dc.prefetch):
                self._relic.submit(self._produce, self._next_submit)
                self._next_submit += 1
            self._started = True
        return self

    def next_batch(self) -> dict:
        assert self._started, "call start() first"
        while True:
            item = self._ring.pop()
            if item is not None:
                break
            time.sleep(0)
        index, batch = item
        # keep the assistant one window ahead
        self._relic.submit(self._produce, self._next_submit)
        self._next_submit += 1
        return batch

    def pause(self) -> None:
        """Between parallelizable sections (paper's sleep_hint)."""
        self._relic.sleep_hint()

    def resume(self) -> None:
        self._relic.wake_up_hint()

    def stop(self) -> None:
        if self._started:
            self._relic.shutdown()
            self._started = False

    def __iter__(self) -> Iterator[dict]:
        self.start()
        while True:
            yield self.next_batch()
