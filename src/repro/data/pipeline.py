"""Data pipeline with a Relic-prefetched SPSC batch queue.

The host-side instance of the paper's pattern (docs/schedulers.md): the **assistant
thread produces** batches (synthetic generation / memmap reads / host->device
transfer release the GIL) while the **main thread consumes** them in the
train loop. `wake_up_hint()` is issued when the loop starts, `sleep_hint()`
between epochs/evals — the paper's explicit control points.

Determinism/restart: batch `i` is a pure function of (seed, i, shard), so
resuming from step `i` after a failure replays the exact stream; no iterator
state needs checkpointing beyond the step counter.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterator, Optional

import numpy as np

from repro.core.schedulers import Scheduler
from repro.stream import Pipeline, Stage, StreamFailure


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    shard: int = 0          # this host's index
    num_shards: int = 1
    prefetch: int = 8       # SPSC queue depth for prefetched batches


class SyntheticLM:
    """Seeded synthetic token stream (zipf-ish marginals so losses move)."""

    def __init__(self, dc: DataConfig):
        self.dc = dc
        probs = 1.0 / np.arange(1, dc.vocab_size + 1) ** 1.1
        self._probs = probs / probs.sum()

    def batch(self, index: int) -> dict:
        dc = self.dc
        rng = np.random.default_rng(
            np.random.SeedSequence([dc.seed, index, dc.shard]))
        b = dc.global_batch // dc.num_shards
        toks = rng.choice(dc.vocab_size, size=(b, dc.seq_len + 1),
                          p=self._probs).astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((b, dc.seq_len), np.float32),
        }


class MemmapLM:
    """Flat token file (np.memmap) chunked into fixed-length sequences."""

    def __init__(self, dc: DataConfig, path: str, dtype=np.int32):
        self.dc = dc
        self._data = np.memmap(path, dtype=dtype, mode="r")
        self._n_seqs = (len(self._data) - 1) // dc.seq_len

    def batch(self, index: int) -> dict:
        dc = self.dc
        rng = np.random.default_rng(
            np.random.SeedSequence([dc.seed, index, dc.shard]))
        b = dc.global_batch // dc.num_shards
        starts = rng.integers(0, self._n_seqs, size=b) * dc.seq_len
        toks = np.stack([np.asarray(self._data[s:s + dc.seq_len + 1])
                         for s in starts]).astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((b, dc.seq_len), np.float32),
        }


class PrefetchPipeline:
    """Prefetched batch stream, built as a 2-stage streaming pipeline.

    Since PR 9 this is a thin consumer of :class:`repro.stream.Pipeline`:
    batch *indices* flow in, batches flow out, through a ``produce`` stage
    (``source.batch(i)``) and — when a ``transform`` is given — a second
    ``transform`` stage whose work overlaps production of the next batch.
    Every ring in the network is strictly 1P1C by construction, which is
    why the old ``_push_lock`` no longer exists: that lock only served to
    serialize multi-worker pool substrates racing on one hand-rolled ring,
    a shape the per-stage 1P1C composition makes structurally impossible.

    Substrates: a registry name gives each stage its own assistant
    (``"serial"`` degrades to synchronous on-demand production, no worker
    thread); a ``Scheduler`` *instance* fuses produce+transform into one
    stage hosted on it. Batches are delivered strictly in index order on
    *every* substrate — the linear pipeline is FIFO end-to-end, so no
    index stash is needed either.

    Supervision (PR 8 discipline, closing the PR 8 gap in this file):
    every wait — consumer pops in ``next_batch()``, producer pushes on a
    full ring — is bounded, probing the neighbouring thread's liveness
    every ``_PROBE_EVERY_SPINS`` spins and raising
    :class:`repro.core.relic.RelicDeadError` with fed/drained diagnostics
    instead of spinning on a stream that can never advance
    (``RELIC_SUPERVISE=0`` opts out, same switch as the substrate).

    Failures stay in-stream: a batch whose production (or transform)
    raised arrives as a marker and ``next_batch()`` raises
    ``RuntimeError("batch {i} production failed")`` chaining the original
    error — the contract ``tests/test_schedulers_conformance.py`` pins.
    """

    def __init__(self, source, dc: DataConfig, start_index: int = 0,
                 transform: Optional[Callable[[dict], dict]] = None,
                 scheduler: "str | Scheduler" = "relic"):
        self.source = source
        self.dc = dc
        self._next_submit = start_index
        self._next_consume = start_index
        self._transform = transform
        self._scheduler_spec = scheduler
        self._pipe: Optional[Pipeline] = None
        self._started = False
        self._stopping = False

    def _produce(self, index: int) -> dict:
        return self.source.batch(index)

    # -- main-thread API ----------------------------------------------------
    def start(self) -> "PrefetchPipeline":
        if not self._started:
            if self._stopping:
                # Substrates are one-shot; determinism makes restart cheap
                # anyway (batch i is a pure function of (seed, i, shard)).
                raise RuntimeError(
                    "PrefetchPipeline cannot restart after stop(); build a "
                    "new pipeline with start_index at the resume point")
            spec = self._scheduler_spec
            cap = self.dc.prefetch
            if isinstance(spec, str) and self._transform is not None:
                # Two stages, two assistants: transform overlaps produce.
                nodes = [
                    Stage(self._produce, name="produce", capacity=cap,
                          substrate=spec),
                    Stage(self._transform, name="transform", capacity=cap,
                          substrate=spec),
                ]
            elif isinstance(spec, str):
                nodes = [Stage(self._produce, name="produce", capacity=cap,
                               substrate=spec)]
            else:
                # One Scheduler instance hosts one loop: fuse the stages.
                def produce_transform(index: int) -> dict:
                    batch = self.source.batch(index)
                    if self._transform is not None:
                        batch = self._transform(batch)
                    return batch
                nodes = [Stage(produce_transform, name="produce",
                               capacity=cap, substrate=spec)]
            self._pipe = Pipeline(nodes, capacity=cap).start()
            self._pipe.resume()
            # The consumer-facing batch ring (depth-pinned by tests): the
            # streaming network's sink. In inline (serial) mode outputs
            # buffer in a deque instead and the ring stays empty.
            self._ring = self._pipe.sink_ring
            # Prime the window: keep `prefetch` indices in flight.
            for _ in range(cap):
                self._pipe.put(self._next_submit)
                self._next_submit += 1
            self._started = True
        return self

    def next_batch(self) -> dict:
        assert self._started, "call start() first"
        # Bounded wait: get_raw probes the producing stage's liveness and
        # raises RelicDeadError if its assistant died mid-stream.
        batch = self._pipe.get_raw()
        index = self._next_consume
        self._next_consume += 1
        # keep the assistant one window ahead
        self._pipe.put(self._next_submit)
        self._next_submit += 1
        if type(batch) is StreamFailure:
            raise RuntimeError(
                f"batch {index} production failed") from batch.error
        return batch

    def pause(self) -> None:
        """Between parallelizable sections (paper's sleep_hint)."""
        if self._pipe is not None:
            self._pipe.pause()

    def resume(self) -> None:
        if self._pipe is not None:
            self._pipe.resume()

    def stop(self) -> None:
        if self._started:
            self._stopping = True
            self._pipe.close()   # flows STOP, drains leftovers, joins
            self._started = False

    def __iter__(self) -> Iterator[dict]:
        self.start()
        while True:
            yield self.next_batch()
