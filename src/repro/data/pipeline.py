"""Data pipeline with a Relic-prefetched SPSC batch queue.

The host-side instance of the paper's pattern (docs/schedulers.md): the **assistant
thread produces** batches (synthetic generation / memmap reads / host->device
transfer release the GIL) while the **main thread consumes** them in the
train loop. `wake_up_hint()` is issued when the loop starts, `sleep_hint()`
between epochs/evals — the paper's explicit control points.

Determinism/restart: batch `i` is a pure function of (seed, i, shard), so
resuming from step `i` after a failure replays the exact stream; no iterator
state needs checkpointing beyond the step counter.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Iterator, Optional

import numpy as np

from repro.core.schedulers import Scheduler
from repro.core.spsc import SpscRing
from repro.tasks.api import TaskScope


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    shard: int = 0          # this host's index
    num_shards: int = 1
    prefetch: int = 8       # SPSC queue depth for prefetched batches


class SyntheticLM:
    """Seeded synthetic token stream (zipf-ish marginals so losses move)."""

    def __init__(self, dc: DataConfig):
        self.dc = dc
        probs = 1.0 / np.arange(1, dc.vocab_size + 1) ** 1.1
        self._probs = probs / probs.sum()

    def batch(self, index: int) -> dict:
        dc = self.dc
        rng = np.random.default_rng(
            np.random.SeedSequence([dc.seed, index, dc.shard]))
        b = dc.global_batch // dc.num_shards
        toks = rng.choice(dc.vocab_size, size=(b, dc.seq_len + 1),
                          p=self._probs).astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((b, dc.seq_len), np.float32),
        }


class MemmapLM:
    """Flat token file (np.memmap) chunked into fixed-length sequences."""

    def __init__(self, dc: DataConfig, path: str, dtype=np.int32):
        self.dc = dc
        self._data = np.memmap(path, dtype=dtype, mode="r")
        self._n_seqs = (len(self._data) - 1) // dc.seq_len

    def batch(self, index: int) -> dict:
        dc = self.dc
        rng = np.random.default_rng(
            np.random.SeedSequence([dc.seed, index, dc.shard]))
        b = dc.global_batch // dc.num_shards
        starts = rng.integers(0, self._n_seqs, size=b) * dc.seq_len
        toks = np.stack([np.asarray(self._data[s:s + dc.seq_len + 1])
                         for s in starts]).astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((b, dc.seq_len), np.float32),
        }


class _ProduceFailure:
    """Marker pushed through the ring when batch production raised; the
    error surfaces at ``next_batch()`` for that index instead of hanging
    the consumer on a batch that will never arrive."""

    __slots__ = ("error",)

    def __init__(self, error: BaseException):
        self.error = error


class PrefetchPipeline:
    """SPSC-prefetched batch stream driven by a scheduling substrate.

    Host-side overlap defaults to the paper's Relic runtime but accepts any
    substrate from ``repro.core.schedulers`` — a registry name
    (``"relic"``, ``"spin"``, ``"condvar"``, ``"pool"``, ``"serial"``) or a
    not-yet-started ``Scheduler`` instance. ``"serial"`` degrades to
    synchronous on-demand batch production (no worker thread), which is the
    right fallback where spawning threads is undesirable.

    Batches are delivered strictly in index order on *every* substrate:
    arrivals are staged by index and released sequentially, so even the
    multi-worker ``"pool"`` substrate (which may finish production out of
    order) preserves the determinism/restart contract above.

    Production runs inside a long-lived :class:`repro.tasks.api.TaskScope`
    (the structured tasking façade) rather than on raw scheduler
    submit/wait; ``_produce`` handles its own failures in-stream (see
    ``_ProduceFailure``), so the scope's error aggregation stays empty.
    """

    def __init__(self, source, dc: DataConfig, start_index: int = 0,
                 transform: Optional[Callable[[dict], dict]] = None,
                 scheduler: "str | Scheduler" = "relic"):
        self.source = source
        self.dc = dc
        self._next_submit = start_index
        self._next_consume = start_index
        self._stash: dict = {}   # out-of-order arrivals, keyed by index
        self._transform = transform
        self._ring = SpscRing(dc.prefetch)
        self._scheduler_spec = scheduler
        self._scope: Optional[TaskScope] = None
        self._started = False
        self._stopping = False
        # The batch ring is SPSC by design; multi-worker substrates (pool)
        # would race on push, so producers serialize on this lock. For the
        # single-assistant substrates it is uncontended.
        self._push_lock = threading.Lock()

    # -- assistant-side task ------------------------------------------------
    def _produce(self, index: int) -> None:
        try:
            batch = self.source.batch(index)
            if self._transform is not None:
                batch = self._transform(batch)
        except BaseException as e:
            # Deliver the failure in-stream: the consumer would otherwise
            # spin forever on a batch that will never arrive.
            batch = _ProduceFailure(e)
        while True:
            with self._push_lock:
                pushed = self._ring.push((index, batch))
            if pushed:
                return
            if self._stopping:
                return  # consumer is gone; drop instead of spinning forever
            time.sleep(0)  # bounded queue backpressure

    # -- main-thread API ----------------------------------------------------
    def start(self) -> "PrefetchPipeline":
        if not self._started:
            if self._stopping:
                # Substrates are one-shot; determinism makes restart cheap
                # anyway (batch i is a pure function of (seed, i, shard)).
                raise RuntimeError(
                    "PrefetchPipeline cannot restart after stop(); build a "
                    "new pipeline with start_index at the resume point")
            spec = self._scheduler_spec
            if isinstance(spec, str):
                self._scope = TaskScope(spec, capacity=self.dc.prefetch)
            else:
                self._scope = TaskScope(spec)
            self._scope.wake_up_hint()
            for _ in range(self.dc.prefetch):
                self._scope.submit(self._produce, self._next_submit)
                self._next_submit += 1
            self._started = True
        return self

    def next_batch(self) -> dict:
        assert self._started, "call start() first"
        while self._next_consume not in self._stash:
            item = self._ring.pop()
            if item is None:
                time.sleep(0)
                continue
            self._stash[item[0]] = item[1]
        batch = self._stash.pop(self._next_consume)
        self._next_consume += 1
        # keep the assistant one window ahead
        self._scope.submit(self._produce, self._next_submit)
        self._next_submit += 1
        if isinstance(batch, _ProduceFailure):
            raise RuntimeError(
                f"batch {self._next_consume - 1} production failed"
            ) from batch.error
        return batch

    def pause(self) -> None:
        """Between parallelizable sections (paper's sleep_hint)."""
        if self._scope is not None:
            self._scope.sleep_hint()

    def resume(self) -> None:
        if self._scope is not None:
            self._scope.wake_up_hint()

    def stop(self) -> None:
        if self._started:
            self._stopping = True  # unblock producers stuck on a full ring
            self._scope.close()
            self._started = False

    def __iter__(self) -> Iterator[dict]:
        self.start()
        while True:
            yield self.next_batch()
