from repro.data.pipeline import (  # noqa: F401
    DataConfig,
    MemmapLM,
    PrefetchPipeline,
    SyntheticLM,
)
