"""Launch tooling: mesh builders, jit step builders, dry-run, train/serve
drivers. NOTE: `dryrun` must be imported/executed as a fresh process (it sets
XLA_FLAGS for 512 host devices before importing jax)."""

from repro.launch.mesh import make_host_mesh, make_mesh, make_production_mesh  # noqa: F401
from repro.launch.steps import (  # noqa: F401
    abstract_serve_state,
    abstract_train_state,
    make_serve_step,
    make_train_step,
    make_train_state,
)
