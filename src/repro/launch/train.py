"""Training driver: data pipeline (Relic-prefetched) -> jit train step ->
async checkpointing -> straggler monitoring. Runs a real loop on whatever
devices exist (CPU here; the same code path jit-compiles for a pod).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch relic_tiny --steps 200 \
      --batch 8 --seq 256 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, PrefetchPipeline, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_state, make_train_step
from repro.models import build_model
from repro.optim import OptConfig
from repro.runtime import StragglerMonitor


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="relic_tiny")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-checksum", default="on", choices=["on", "off"],
                    help="per-entry CRC32 in the checkpoint manifest "
                         "(verified on restore)")
    ap.add_argument("--ckpt-chaos", default="",
                    help="chaos: crash the Nth save at a named fs point, "
                         "as point[:at_save] (e.g. 'manifest:1'); points: "
                         "serialize-start, entry, manifest, pre-publish")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    mesh = make_host_mesh()
    oc = OptConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                   total_steps=args.steps)

    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    vocab_size=cfg.vocab_size)
    pipe = PrefetchPipeline(SyntheticLM(dc), dc).start()

    with shd.use_sharding_rules(mesh):
        state = make_train_state(model, jax.random.PRNGKey(0))
        state_sh = shd.named_shardings(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         state), mesh)
        state = jax.tree.map(jax.device_put, state, state_sh)
        step_fn = jax.jit(make_train_step(model, oc), donate_argnums=(0,))

        mgr = None
        if args.ckpt:
            mgr = CheckpointManager(
                args.ckpt,
                # Chaos runs save synchronously so the injected FsCrash
                # unwinds the driver at the exact write point — the
                # closest single-process stand-in for dying mid-save.
                async_=not args.ckpt_chaos,
                checksum=args.ckpt_checksum == "on")
            if args.ckpt_chaos:
                from repro.runtime.chaos import FsFaultInjector
                point, _, at_save = args.ckpt_chaos.partition(":")
                FsFaultInjector(crash_point=point,
                                at_save=int(at_save or 0)).arm(mgr)
        start = 0
        if mgr and args.resume and mgr.latest_step() is not None:
            state, start = mgr.restore(state, shardings=state_sh)
            print(f"resumed from step {start}")

        mon = StragglerMonitor(n_hosts=1)
        t_last = time.time()
        try:
            for i in range(start, args.steps):
                batch = {k: jnp.asarray(v)
                         for k, v in pipe.next_batch().items()}
                state, metrics = step_fn(state, batch)
                if (i + 1) % args.log_every == 0 or i == start:
                    loss = float(metrics["loss"])
                    dt_step = (time.time() - t_last) / args.log_every
                    mon.record(0, dt_step)
                    t_last = time.time()
                    print(f"step {i+1:5d}  loss {loss:.4f}  "
                          f"lr {float(metrics['lr']):.2e}  "
                          f"gnorm {float(metrics['grad_norm']):.3f}  "
                          f"{dt_step*1e3:.0f} ms/step", flush=True)
                if mgr and (i + 1) % args.ckpt_every == 0:
                    mgr.save(state, i + 1)  # async on the Relic assistant
            if mgr:
                mgr.save(state, args.steps, block=True)
                mgr.close()
        finally:
            # A chaos FsCrash (or any error) must not leak the prefetch
            # threads into the caller's process — the resume test runs
            # main() twice in-process.
            pipe.stop()
        return float(metrics["loss"])


if __name__ == "__main__":
    main()
