import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract the roofline terms from the compiled artifact.

Per cell this produces:
  * PROOF lowering — the full config (scanned layers), compiled on the target
    mesh. Success proves the sharding is coherent; `memory_analysis()` gives
    bytes/device.
  * COST lowerings — two small UNROLLED depth variants of the same family
    (XLA's HloCostAnalysis counts a `while` body once, so scanned-depth FLOPs
    must be recovered by exact linear extrapolation: every per-layer term is
    identical, so f(L) = f(L2) + (L-L2) * (f(L3)-f(L2))/(L3-L2); hybrids get
    a group+tail decomposition).
  * Collective byte parse of the partitioned HLO (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), converted to wire
    bytes with ring-algorithm factors and the op's replica group size.

Results are cached as JSON under benchmarks/artifacts/dryrun/.
"""

import argparse
import json
import math
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd
from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro import compat
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.launch.steps import (
    abstract_serve_state,
    abstract_train_state,
    make_serve_step,
    make_train_step,
)
from repro.models.registry import build_model
from repro.optim import OptConfig

ART_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

# v5e-flavoured hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link

_COLL_RE = re.compile(
    r"=\s+(?:\(?)([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.X)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}


def parse_collectives(hlo_text: str) -> dict:
    """Sum estimated wire bytes per collective kind from partitioned HLO."""
    out = {k: 0.0 for k in ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute")}
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        size = _DTYPE_BYTES[dtype]
        for d in dims.split(","):
            if d:
                size *= int(d)
        g = _GROUPS_RE.search(line)
        if g:
            group = int(g.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            group = len(gl.group(1).split(",")) if gl else 2
        # ring-algorithm wire bytes per device (result shape is per-device)
        if kind == "all-gather":
            wire = size * (group - 1) / group
        elif kind == "all-reduce":
            wire = 2 * size * (group - 1) / group
        elif kind == "reduce-scatter":
            wire = size * (group - 1)          # result is the scattered shard
        elif kind == "all-to-all":
            wire = size * (group - 1) / group
        else:  # collective-permute: point-to-point
            wire = size
        out[kind] += wire
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


# ---------------------------------------------------------------------------
# Model FLOPs (analytic 6·N·D for train, 2·N·D for a decode token)
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig, active_only: bool = False) -> float:
    m = build_model(cfg)
    sds = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
    total = sum(np.prod(x.shape) for x in jax.tree.leaves(sds))
    if active_only and cfg.moe is not None:
        mc = cfg.moe
        per_expert = 3 * cfg.d_model * mc.d_ff
        inactive = cfg.n_layers * per_expert * (mc.n_experts - mc.top_k)
        total -= inactive
    return float(total)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = count_params(cfg, active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------

def _batch_axes(mesh, b: int):
    axes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    prod = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and b % prod == 0:
        return axes
    if "data" in mesh.axis_names and b % mesh.shape["data"] == 0:
        return ("data",)
    return None


def batch_shardings(mesh, batch_sds):
    def one(sds):
        ba = _batch_axes(mesh, sds.shape[0]) if sds.ndim else None
        spec = [None] * sds.ndim
        if sds.ndim and ba:
            spec[0] = ba
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_sds)


def replicated(mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Lower + compile one configuration
# ---------------------------------------------------------------------------

def _prep_cfg(cfg: ModelConfig, shape: ShapeConfig, *, scan: bool,
              overrides: dict | None = None) -> ModelConfig:
    kw = {"scan_layers": scan}
    if shape.kind == "decode":
        kw["param_dtype"] = "bfloat16"
        kw["remat"] = "none"
    if not scan:
        # COST lowerings statically unroll the chunked-attention scans so
        # HloCostAnalysis counts every block (FLOPs are tiling-invariant);
        # coarser tiles keep the unrolled HLO tractable. Non-default tile
        # settings (hillclimb variants) are preserved.
        if cfg.attn_chunk_q == 512:
            kw.setdefault("attn_chunk_q", 4096)
        if cfg.attn_chunk == 1024:
            kw.setdefault("attn_chunk", 8192)
    if overrides:
        kw.update(overrides)
    return cfg.replace(**kw)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *, compile_: bool = True):
    """Returns (lowered, compiled|None, meta)."""
    model = build_model(cfg)
    batch_sds, cache_len = model.input_specs(shape)
    t0 = time.time()
    with shd.use_sharding_rules(mesh):
        if shape.kind == "decode":
            params_sds, cache_sds = abstract_serve_state(model, shape)
            in_sh = (
                shd.named_shardings(params_sds, mesh),
                shd.named_shardings(cache_sds, mesh),
                batch_shardings(mesh, batch_sds["tokens"]),
                replicated(mesh),
            )
            logits_sds = jax.ShapeDtypeStruct(
                (shape.global_batch, 1, cfg.vocab_size), jnp.float32)
            logits_spec = shd.fit_spec(
                mesh, [_batch_axes(mesh, shape.global_batch), None, "model"],
                logits_sds.shape)
            out_sh = (
                batch_shardings(mesh, batch_sds["tokens"]),
                NamedSharding(mesh, logits_spec),
                shd.named_shardings(cache_sds, mesh),
            )
            fn = jax.jit(make_serve_step(model), in_shardings=in_sh,
                         out_shardings=out_sh, donate_argnums=(1,))
            lowered = fn.lower(
                params_sds, cache_sds, batch_sds["tokens"],
                jax.ShapeDtypeStruct((), jnp.int32),
            )
        else:
            state_sds = abstract_train_state(model)
            state_sh = shd.named_shardings(state_sds, mesh)
            in_sh = (state_sh, batch_shardings(mesh, batch_sds))
            metrics_sh = {k: replicated(mesh) for k in
                          ("loss", "ce", "aux", "tokens", "grad_norm", "lr")}
            fn = jax.jit(make_train_step(model, OptConfig()),
                         in_shardings=in_sh,
                         out_shardings=(state_sh, metrics_sh),
                         donate_argnums=(0,))
            lowered = fn.lower(state_sds, batch_sds)
    lower_s = time.time() - t0
    compiled = None
    compile_s = None
    if compile_:
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
    return lowered, compiled, {"lower_s": lower_s, "compile_s": compile_s}


def _cost_points(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Unrolled small-depth lowerings for exact linear-in-depth costs."""
    fam = cfg.family

    def costs(c):
        _, comp, _ = lower_cell(c, shape, mesh)
        ca = compat.cost_analysis(comp)
        coll = parse_collectives(comp.as_text())
        return {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll["total"],
            "coll_by_kind": {k: coll[k] for k in
                             ("all-reduce", "all-gather", "reduce-scatter",
                              "all-to-all", "collective-permute")},
        }

    def lin(f2, f3, l2, l3, target):
        per = {k: (f3[k] - f2[k]) / (l3 - l2) for k in ("flops", "bytes", "coll")}
        out = {k: f2[k] + per[k] * (target - l2) for k in per}
        out["coll_by_kind"] = {
            k: f2["coll_by_kind"][k]
            + (f3["coll_by_kind"][k] - f2["coll_by_kind"][k]) / (l3 - l2)
            * (target - l2)
            for k in f2["coll_by_kind"]
        }
        return out

    if fam == "hybrid" and cfg.attn_every:
        ae = cfg.attn_every
        f_g1 = costs(_prep_cfg(cfg, shape, scan=False,
                               overrides={"n_layers": ae}))
        f_g2 = costs(_prep_cfg(cfg, shape, scan=False,
                               overrides={"n_layers": 2 * ae}))
        f_m2 = costs(_prep_cfg(cfg, shape, scan=False,
                               overrides={"n_layers": 2, "attn_every": 0}))
        f_m4 = costs(_prep_cfg(cfg, shape, scan=False,
                               overrides={"n_layers": 4, "attn_every": 0}))
        full, tail = cfg.n_layers // ae, cfg.n_layers % ae
        out = {}
        for k in ("flops", "bytes", "coll"):
            g = f_g2[k] - f_g1[k]                  # one (ae mamba + attn) group
            m = (f_m4[k] - f_m2[k]) / 2.0          # one mamba layer
            out[k] = f_g1[k] + (full - 1) * g + tail * m
        out["coll_by_kind"] = {
            k: f_g1["coll_by_kind"][k]
            + (full - 1) * (f_g2["coll_by_kind"][k] - f_g1["coll_by_kind"][k])
            + tail * (f_m4["coll_by_kind"][k] - f_m2["coll_by_kind"][k]) / 2.0
            for k in f_g1["coll_by_kind"]
        }
        return out

    if fam == "encdec":
        f2 = costs(_prep_cfg(cfg, shape, scan=False,
                             overrides={"n_layers": 2, "enc_layers": 2}))
        f3 = costs(_prep_cfg(cfg, shape, scan=False,
                             overrides={"n_layers": 3, "enc_layers": 3}))
        return lin(f2, f3, 2, 3, cfg.n_layers)

    f2 = costs(_prep_cfg(cfg, shape, scan=False, overrides={"n_layers": 2}))
    f3 = costs(_prep_cfg(cfg, shape, scan=False, overrides={"n_layers": 3}))
    return lin(f2, f3, 2, 3, cfg.n_layers)


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             force: bool = False) -> dict:
    ART_DIR.mkdir(parents=True, exist_ok=True)
    out_path = ART_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skipped": why}
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    n_chips = int(np.prod(list(mesh.shape.values())))

    # PROOF: full depth, scanned, compiled.
    proof_cfg = _prep_cfg(cfg, shape, scan=True)
    _, compiled, meta = lower_cell(proof_cfg, shape, mesh)
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes_est": int(ma.argument_size_in_bytes + ma.temp_size_in_bytes
                              + ma.output_size_in_bytes
                              - ma.alias_size_in_bytes),
    }

    # COST: extrapolated exact depth costs (per-device).
    cost = _cost_points(cfg, shape, mesh)

    mf = model_flops(cfg, shape)
    flops_dev = cost["flops"]
    bytes_dev = cost["bytes"]
    coll_dev = cost["coll"]
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_chips": n_chips,
        "lower_s": round(meta["lower_s"], 2),
        "compile_s": round(meta["compile_s"], 2),
        "memory": mem,
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_wire_bytes": coll_dev,
            "collective_by_kind": cost["coll_by_kind"],
        },
        "model_flops_global": mf,
        "useful_flops_ratio": mf / (flops_dev * n_chips) if flops_dev else None,
        "roofline_terms_s": terms,
        "dominant": dominant,
    }
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [a for a in ARCH_IDS if a != "relic_tiny"] \
        if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                tag = f"{arch} × {shape} × {mesh_name}"
                try:
                    t0 = time.time()
                    rec = run_cell(arch, shape, mesh_name, force=args.force)
                    if "skipped" in rec:
                        print(f"[skip] {tag}: {rec['skipped']}", flush=True)
                    else:
                        t = rec["roofline_terms_s"]
                        print(
                            f"[ok]   {tag}: dom={rec['dominant']} "
                            f"comp={t['compute_s']:.4f}s mem={t['memory_s']:.4f}s "
                            f"coll={t['collective_s']:.4f}s "
                            f"({time.time()-t0:.0f}s wall)", flush=True,
                        )
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} failures:")
        for tag, err in failures:
            print(" ", tag, err[:200])
        raise SystemExit(1)
    print("\nAll requested dry-run cells passed.")


if __name__ == "__main__":
    main()
