"""train_step / serve_step builders — the jit roots the launcher, dry-run,
benchmarks, and examples all share."""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.registry import Model
from repro.optim import OptConfig, adamw_update, clip_by_global_norm, init_opt_state


def make_train_state(model: Model, key, oc: Optional[OptConfig] = None) -> dict:
    params = model.init(key)
    state = {"params": params, "opt": init_opt_state(params),
             "step": jnp.zeros((), jnp.int32)}
    if oc is not None and oc.compress_grads:
        from repro.optim.compression import init_residual

        state["opt"]["residual"] = init_residual(params)
    return state


def abstract_train_state(model: Model) -> Any:
    """ShapeDtypeStruct pytree of the train state (no allocation)."""
    return jax.eval_shape(lambda: make_train_state(model, jax.random.PRNGKey(0)))


def make_train_step(model: Model, oc: OptConfig):
    def train_step(state: dict, batch: dict) -> Tuple[dict, dict]:
        def loss_fn(params, mb):
            return model.loss(params, mb)

        if oc.grad_accum > 1:
            # Microbatched gradient accumulation: scan over grad_accum slices
            # of the leading batch dim (activation memory / oc.grad_accum).
            def split(x):
                b = x.shape[0]
                assert b % oc.grad_accum == 0, (b, oc.grad_accum)
                return x.reshape(oc.grad_accum, b // oc.grad_accum,
                                 *x.shape[1:])

            mbs = jax.tree.map(split, batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])

            def acc_body(carry, mb):
                g_acc, _ = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state["params"], mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32) / oc.grad_accum,
                    g_acc, g)
                return (g_acc, metrics), None

            zero_m = {"loss": jnp.zeros(()), "ce": jnp.zeros(()),
                      "aux": jnp.zeros(()), "tokens": jnp.zeros(())}
            (grads, metrics), _ = jax.lax.scan(
                acc_body, (zero_g, zero_m), mbs)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"], batch)
        opt_state = dict(state["opt"])
        if oc.compress_grads:
            # int8 + error feedback: the quantized view is what a bandwidth-
            # starved pod axis would all-reduce; the residual carries the
            # quantization error to the next step (unbiased long-run).
            from repro.optim.compression import compress_with_feedback

            grads, residual = compress_with_feedback(
                grads, opt_state.pop("residual"))
        grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
        new_params, new_opt, lr = adamw_update(
            oc, grads, opt_state, state["params"], state["step"]
        )
        if oc.compress_grads:
            new_opt["residual"] = residual
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return new_state, metrics

    return train_step


def make_serve_step(model: Model):
    """One greedy decode step: (params, cache, tokens[B,1], pos) ->
    (next_tokens [B,1], logits [B,1,V], cache)."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return serve_step


def make_prefill_step(model: Model):
    """Teacher-forced prefill in ONE dispatch: ``lax.scan`` of
    ``decode_step`` over the prompt positions, carrying the cache.

    Cache-position contract: every model family's ``decode_step`` is
    strictly single-token — ``tokens`` is ``[B, 1]`` and ``pos`` is the
    absolute position of that token, which must advance by exactly 1 per
    call (attention reads ``kv_len = pos + 1``; SSM/hybrid states shift
    once per call). Prefill therefore cannot feed a multi-token chunk
    through ``decode_step``; what it *can* do is move the per-position
    loop from Python (O(prompt_len) jit dispatches) into a ``lax.scan``
    (one dispatch, identical per-position math). Pinned equivalent to the
    one-at-a-time loop by ``tests/test_serve.py``.

    Returns ``prefill(params, cache, prompts[B, P]) -> (next_tokens[B, 1],
    cache)`` where ``next_tokens`` is the greedy prediction after the full
    prompt — exactly what the first decode step consumes.
    """

    def prefill(params, cache, prompts):
        toks = jnp.swapaxes(prompts, 0, 1)[:, :, None]        # [P, B, 1]
        positions = jnp.arange(prompts.shape[1], dtype=jnp.int32)

        def body(cache, xs):
            tok, pos = xs
            logits, cache = model.decode_step(params, cache, tok, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return cache, nxt

        cache, nxts = jax.lax.scan(body, cache, (toks, positions))
        return nxts[-1], cache

    return prefill


def abstract_serve_state(model: Model, shape: ShapeConfig):
    """(params_sds, cache_sds) for a decode shape (no allocation)."""
    cfg = model.cfg
    _, cache_len = model.input_specs(shape)
    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, cache_len)
    )
    return params_sds, cache_sds
