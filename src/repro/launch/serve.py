"""Serving driver: batched prefill + greedy decode with a fixed-length KV
cache. Demonstrates the serve_step path the decode dry-run cells lower.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch relic_tiny --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models import build_model


def prefill_via_decode(model, params, cache, prompts, serve_step):
    """Feed prompt tokens one-by-one (teacher forcing) to fill the cache."""
    b, plen = prompts.shape
    tok = None
    for t in range(plen):
        tok, _, cache = serve_step(params, cache,
                                   prompts[:, t:t + 1], jnp.int32(t))
    return tok, cache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="relic_tiny")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    cfg = cfg.replace(param_dtype="bfloat16")  # serving layout
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, cache_len)
    serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    if cfg.family == "encdec":
        from repro.models.encdec import encode, prefill_cross_cache
        frames = jnp.asarray(
            rng.normal(size=(args.batch, cfg.frontend.n_tokens, cfg.d_model)),
            jnp.bfloat16)
        cache = prefill_cross_cache(cfg, params, cache,
                                    encode(cfg, params, frames))

    tok, cache = prefill_via_decode(model, params, cache, prompts, serve_step)

    out = [tok]
    t0 = time.time()
    for t in range(args.prompt_len, args.prompt_len + args.gen - 1):
        tok, _, cache = serve_step(params, cache, tok, jnp.int32(t))
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    tps = args.batch * (args.gen - 1) / dt
    print(f"generated {gen.shape} tokens; {tps:.1f} tok/s "
          f"({dt/(args.gen-1)*1e3:.1f} ms/step)")
    print("sample row:", np.asarray(gen[0][:16]))
    return gen


if __name__ == "__main__":
    main()
