"""Serving driver: batched scan-prefill + greedy decode, served as a
streaming request through ``repro.serve.ServeScheduler``.

The demo form of the serving stack (docs/serving.md): the decode loop is a
*generator* work function — each generated token is one yielded item, so
the response's ``first_result_t`` is the time-to-first-token and the
subsystem's latency accounting applies unchanged to token serving.

Prefill is ``make_prefill_step`` — one jitted ``lax.scan`` dispatch over
the prompt positions instead of O(prompt_len) ``serve_step`` dispatches
(same teacher-forced single-token math; see the cache-position contract in
``repro.launch.steps``).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch relic_tiny --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import build_model
from repro.serve import ServeScheduler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="relic_tiny")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--lanes", type=int, default=1,
                    help="RelicPool lanes backing the request server")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    cfg = cfg.replace(param_dtype="bfloat16")  # serving layout
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, cache_len)
    serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))
    prefill = jax.jit(make_prefill_step(model), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    if cfg.family == "encdec":
        from repro.models.encdec import encode, prefill_cross_cache
        frames = jnp.asarray(
            rng.normal(size=(args.batch, cfg.frontend.n_tokens, cfg.d_model)),
            jnp.bfloat16)
        cache = prefill_cross_cache(cfg, params, cache,
                                    encode(cfg, params, frames))

    # Warm the decode jit off the served path (its own throwaway cache —
    # serve_step donates its cache argument), so the served request
    # measures steady-state steps, not compilation.
    warm_cache = model.init_cache(args.batch, cache_len)
    warm_tok = jnp.zeros((args.batch, 1), jnp.int32)
    jax.block_until_ready(
        serve_step(params, warm_cache, warm_tok, jnp.int32(0))[0])

    tok, cache = prefill(params, cache, prompts)
    jax.block_until_ready(tok)

    steps_timed = [0]  # decode-loop accounting, asserted against gen below

    def decode_stream(first_tok, dcache):
        def gen():
            t_tok = first_tok
            t_cache = dcache
            yield first_tok  # the prefill prediction is token 0
            for t in range(args.prompt_len, args.prompt_len + args.gen - 1):
                t_tok, _, t_cache = serve_step(
                    params, t_cache, t_tok, jnp.int32(t))
                steps_timed[0] += 1
                yield t_tok
            jax.block_until_ready(t_tok)
        return gen()

    with ServeScheduler(lanes=args.lanes) as server:
        client = server.open_client("decode")
        resp = client.submit(decode_stream, tok, cache)
        out = resp.result()

    # Token accounting must match the timed step count: one prefill
    # prediction + one token per timed decode step.
    assert steps_timed[0] == args.gen - 1, (steps_timed[0], args.gen)
    assert len(out) == 1 + steps_timed[0], (len(out), steps_timed[0])

    gen_toks = jnp.concatenate(out, axis=1)
    assert resp.first_result_t is not None and resp.complete_t is not None
    ttft = resp.first_result_t - resp.request.arrival_t
    dt = max(resp.complete_t - resp.first_result_t, 1e-9)
    tps = args.batch * (args.gen - 1) / dt
    print(f"generated {gen_toks.shape} tokens; {tps:.1f} tok/s "
          f"({dt / max(args.gen - 1, 1) * 1e3:.1f} ms/step, "
          f"ttft {ttft * 1e3:.1f} ms, lanes {args.lanes})")
    print("sample row:", np.asarray(gen_toks[0][:16]))
    return gen_toks


if __name__ == "__main__":
    main()
