"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state; `jax.make_mesh` is only called when a launcher actually asks for a
mesh (the dry-run sets XLA_FLAGS for 512 host devices *before* any import).
"""

from __future__ import annotations

import jax

from repro import compat


def _make(shape, axes):
    return compat.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips with a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, examples, elastic re-mesh)."""
    return _make(tuple(shape), tuple(axes))


def make_host_mesh():
    """Whatever devices exist locally, as a 1D 'data' mesh (examples/CI)."""
    n = len(jax.devices())
    return _make((n,), ("data",))
