"""End-to-end training driver example.

Default runs a fast CPU-sized config; pass --full to train the ~100M
`relic_tiny` config for a few hundred steps (the deliverable-scale run —
give it real hardware or patience on CPU).

The loop underneath (repro.launch.train) includes:
  * Relic-prefetched data pipeline (SPSC assistant thread)
  * async checkpointing every --ckpt-every steps on the Relic assistant
  * resume with --resume (deterministic: same stream, same loss curve)
  * straggler monitor hooks

Run:  PYTHONPATH=src python examples/train_lm.py [--full] [--steps 300]
"""

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, a few hundred steps")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/relic_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.full:
        argv = ["--arch", "relic_tiny", "--steps", str(args.steps or 300),
                "--batch", "8", "--seq", "512", "--ckpt", args.ckpt,
                "--ckpt-every", "50"]
    else:
        argv = ["--arch", "relic_tiny", "--smoke", "--steps",
                str(args.steps or 120), "--batch", "8", "--seq", "128",
                "--ckpt", args.ckpt, "--ckpt-every", "40"]
    if args.resume:
        argv.append("--resume")
    final_loss = train_main(argv)
    print(f"final loss: {final_loss:.4f}")


if __name__ == "__main__":
    main()
