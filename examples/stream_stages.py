"""Streaming dataflow demo: compose SPSC lanes into a pipeline and a farm.

Three networks over the same toy work (docs/streaming.md):

1. A 3-stage ``Pipeline`` (parse -> square -> tag), one Relic assistant
   per stage, bounded 1P1C rings between them.
2. The same pipeline on the ``serial`` substrate — degrades to inline
   execution on this thread, same results, zero threads (the A/B).
3. A ``Farm`` inside a pipeline: pre -> Farm(work, workers=3) -> post,
   with in-order release despite skewed per-item cost.

Run:  PYTHONPATH=src python examples/stream_stages.py [--items 64]
"""

import argparse
import time

from repro.stream import Farm, Pipeline


def parse(s):
    return int(s)


def square(x):
    return x * x


def tag(x):
    return {"value": x}


def skewed_work(x):
    # Item cost varies 5x: in-order release must reorder at the collector.
    time.sleep((x % 5) * 20e-6)
    return x * x


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--items", type=int, default=64)
    args = ap.parse_args()
    items = [str(i) for i in range(args.items)]
    expect = [{"value": i * i} for i in range(args.items)]

    # 1. Threaded pipeline: one assistant per stage.
    with Pipeline([parse, square, tag], substrate="relic") as pipe:
        t0 = time.perf_counter()
        outs = pipe.run(items)
        dt = time.perf_counter() - t0
    assert outs == expect
    print(f"pipeline/relic    {len(outs)} items in {dt * 1e3:7.2f} ms "
          f"(stages={len(pipe.nodes)})")

    # 2. Same network, workers=0 substrate: inline on this thread.
    with Pipeline([parse, square, tag], substrate="serial") as pipe:
        t0 = time.perf_counter()
        outs = pipe.run(items)
        dt = time.perf_counter() - t0
    assert outs == expect
    print(f"pipeline/inline   {len(outs)} items in {dt * 1e3:7.2f} ms "
          f"(inline={pipe.inline})")

    # 3. Farm in a pipeline: round-robin deal, in-order release.
    with Pipeline([parse, Farm(skewed_work, workers=3, ordered=True),
                   tag]) as pipe:
        t0 = time.perf_counter()
        outs = pipe.run(items)
        dt = time.perf_counter() - t0
    assert outs == expect
    print(f"farm/workers3     {len(outs)} items in {dt * 1e3:7.2f} ms "
          f"(ordered release)")


if __name__ == "__main__":
    main()
