"""Quickstart: the three layers of the framework in ~60 lines.

  1. Structured tasking façade — TaskScope/parallel_for over the paper's
     Relic runtime (scope exit is the barrier; raw submit/wait is the SPI).
  2. A model from the zoo — one train step + one decode step.
  3. The two-lane device schedule — overlapped collective matmul (shown on
     whatever devices exist; run under XLA_FLAGS=...device_count=8 to see it
     shard).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_serve_step, make_train_state, make_train_step
from repro.models import build_model
from repro.optim import OptConfig
from repro.tasks import TaskScope, parallel_for

# ------------------------------------------------- 1. the tasking façade
squares = [0] * 8
with TaskScope("relic") as scope:     # Relic assistant spun up for the scope
    scope.wake_up_hint()              # a parallelizable section is coming
    # worksharing loop: chunks of 2 indices; the main thread runs the
    # final chunk itself (the paper's producer-participates pattern)
    parallel_for(scope, 8, lambda i: squares.__setitem__(i, i * i), grain=2)
    total = scope.submit(sum, squares)   # futures, too: a TaskHandle
    # scope exit = barrier; task errors (none here) would raise together
print("parallel_for squares:", squares, "| sum future:", total.result())

# ------------------------------------------------------- 2. model + training
cfg = get_config("relic_tiny", smoke=True)
model = build_model(cfg)
state = make_train_state(model, jax.random.PRNGKey(0))
n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
print(f"model: {cfg.name} ({n_params/1e6:.2f}M params)")

rng = np.random.default_rng(0)
batch = {
    "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)), jnp.int32),
    "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)), jnp.int32),
    "mask": jnp.ones((4, 64), jnp.float32),
}
train_step = jax.jit(make_train_step(model, OptConfig(total_steps=100)))
state, metrics = train_step(state, batch)
print(f"train step: loss={float(metrics['loss']):.4f} "
      f"gnorm={float(metrics['grad_norm']):.3f}")

# ------------------------------------------------------------- 3. decoding
serve_step = jax.jit(make_serve_step(model))
cache = model.init_cache(batch_size := 4, cache_len := 16)
tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch_size, 1)), jnp.int32)
for t in range(8):
    tok, _, cache = serve_step(state["params"], cache, tok, jnp.int32(t))
print("decoded tokens:", np.asarray(tok[:, 0]))

# ------------------------------------------- 4. the device-scale Relic ring
from repro.core.collective_matmul import allgather_matmul_gated  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402

x = jnp.asarray(rng.normal(size=(128, 256)), jnp.float32)
w = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
out = ops.matmul(x, w, bm=128, bn=128, bk=128)   # Pallas relic_matmul
err = float(jnp.abs(out - ref.matmul_ref(x, w)).max())
print(f"relic_matmul (Pallas, interpret on CPU): max err vs oracle = {err:.2e}")
print("quickstart OK")
