"""The paper's experiment, live: two instances of each fine-grained kernel
(six GAP graph kernels + JSON structural parse, paper §IV) scheduled by each
strategy; µs/iteration and speedup-over-serial per kernel.

This is the interactive version of `benchmarks/run.py --only fig1`.

Run:  PYTHONPATH=src python examples/relic_tasks.py [--iters 300]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.schedulers import bench_strategies  # noqa: E402
from repro.workloads import PAPER_WORKLOADS, make_workload  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=200)
    args = ap.parse_args()

    print(f"{'kernel':<8}" + "".join(f"{s:>22}" for s in
          ("serial", "relic_spsc", "jax_async_stream", "fused_vmap")))
    for name in PAPER_WORKLOADS:
        w = make_workload(name)
        ta, tb = w.tasks
        da, db = w.dispatches
        res = bench_strategies(ta, tb, w.fused_task(),
                               dispatch_a=da, dispatch_b=db, iters=args.iters)
        base = res["serial"]
        row = f"{name:<8}"
        for s in ("serial", "relic_spsc", "jax_async_stream", "fused_vmap"):
            row += f"{res[s]:>12.1f}us x{base/res[s]:>5.2f}  "
        print(row)
    print("\n(1-CPU container: thread-based overlap is GIL-bound — see "
          "docs/EXPERIMENTS.md §Paper repro for the full 8-strategy figure "
          "recipe and the SMT-assumption discussion.)")


if __name__ == "__main__":
    main()
