"""Batched serving example: prefill a batch of prompts, then greedy-decode
with a fixed-length KV cache — the code path the decode_32k dry-run cells
lower at pod scale.

Run:  PYTHONPATH=src python examples/serve_batch.py [--arch qwen3_14b]
(any arch id works; smoke-sized weights are used so every family runs on CPU)
"""

import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--smoke", "--batch", str(args.batch),
                "--prompt-len", "12", "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
