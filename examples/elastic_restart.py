"""Fault-tolerance walkthrough: train on a healthy mesh, checkpoint
asynchronously, "lose" half the data-parallel capacity, and resume on the
shrunken mesh from the same checkpoint — the elastic-restart path a 1000-node
deployment takes after a pod failure.

Spawns itself under XLA_FLAGS=--xla_force_host_platform_device_count=8 so the
mesh shrink (4x2 -> 2x2) is real.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import subprocess
import sys
from pathlib import Path

BODY = r"""
import os, tempfile
import jax, jax.numpy as jnp
import numpy as np
from repro import sharding as shd
from repro.checkpoint import CheckpointManager, elastic_restore
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_train_state, make_train_step
from repro.models import build_model
from repro.optim import OptConfig
from repro.runtime import HeartbeatTracker, plan_elastic_remesh

cfg = get_config("relic_tiny", smoke=True)
model = build_model(cfg)
oc = OptConfig(warmup_steps=2, total_steps=40)
dc = DataConfig(seq_len=64, global_batch=8, vocab_size=cfg.vocab_size)
src = SyntheticLM(dc)
step_fn = jax.jit(make_train_step(model, oc))

mesh_a = make_mesh((4, 2), ("data", "model"))
print(f"[healthy] mesh {dict(mesh_a.shape)}")
with shd.use_sharding_rules(mesh_a):
    state = make_train_state(model, jax.random.PRNGKey(0))
    shs = shd.named_shardings(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state), mesh_a)
    state = jax.tree.map(jax.device_put, state, shs)
    for i in range(6):
        batch = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
        state, m = step_fn(state, batch)
    print(f"[healthy] step 6 loss {float(m['loss']):.4f}")
    ckpt = tempfile.mkdtemp()
    mgr = CheckpointManager(ckpt, async_=True)
    mgr.save(state, 6)          # async on the Relic assistant
    mgr.wait()

# --- failure: two hosts (half the data axis) stop heartbeating -------------
t = {"now": 0.0}
hb = HeartbeatTracker(n_hosts=4, timeout_s=30, clock=lambda: t["now"])
t["now"] = 60.0
for h in (0, 1):
    hb.beat(h)
dead = hb.dead()
print(f"[failure] dead hosts: {dead}")
plan = plan_elastic_remesh((4, 2), ("data", "model"), dead, chips_per_host=1,
                           restore_step=6)
print(f"[plan] {plan.old_shape} -> {plan.new_shape}, resume @ {plan.restore_step}")

# --- elastic restart on the surviving mesh ---------------------------------
mesh_b = make_mesh(plan.new_shape, plan.axes)
with shd.use_sharding_rules(mesh_b):
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, at_step = elastic_restore(mgr, state, mesh_b,
                                        step=plan.restore_step)
    print(f"[restart] restored step {at_step} onto {dict(mesh_b.shape)}")
    for i in range(6, 10):
        batch = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
        restored, m = step_fn(restored, batch)
    print(f"[restart] step 10 loss {float(m['loss']):.4f} — training continued")
mgr.close()
print("elastic restart OK")
"""


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    r = subprocess.run([sys.executable, "-c", BODY], env=env)
    raise SystemExit(r.returncode)


if __name__ == "__main__":
    main()
