"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — unit/smoke tests see
the real single CPU device; multi-device SPMD tests spawn subprocesses with
--xla_force_host_platform_device_count set (see test_distributed.py)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
