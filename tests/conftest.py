"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — unit/smoke tests see
the real single CPU device; multi-device SPMD tests spawn subprocesses with
--xla_force_host_platform_device_count set (see test_distributed.py).

Hypothesis guard: property tests use ``hypothesis`` when available, but the
suite must *collect* (and every example-based test must run) without it.
When the package is absent we install a minimal stand-in module whose
``@given`` replaces the test with a skip, so hypothesis-marked tests report
as skipped instead of exploding module import for their whole file.
"""

import sys

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without the dep
    import types

    class _OpaqueStrategy:
        """Accepts any strategy-combinator usage (st.lists(st.integers()),
        st.composite, ...) and returns itself."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    def _given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def _settings(*_args, **_kwargs):
        if _args and callable(_args[0]):  # bare @settings
            return _args[0]
        return lambda fn: fn

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _OpaqueStrategy()
    _hyp.HealthCheck = _OpaqueStrategy()
    _hyp.assume = lambda *a, **k: True
    _hyp.note = lambda *a, **k: None
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: getattr(_hyp.strategies, name)
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def rng():
    return np.random.default_rng(0)
