"""SPMD tests on 8 fake host devices.

jax pins the device count at first init, so each test execs a fresh python
with XLA_FLAGS=--xla_force_host_platform_device_count=8 and asserts inside
the subprocess (non-zero exit = failure)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_spmd(body: str):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_collective_matmul_ring_matches_ref():
    run_spmd("""
        from repro.core.collective_matmul import (
            tp_allgather_matmul, tp_matmul_reducescatter)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("model",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
        w1 = jnp.asarray(rng.normal(size=(32, 48)), jnp.float32)
        w2 = jnp.asarray(rng.normal(size=(48, 32)), jnp.float32)
        y = tp_allgather_matmul(x, w1, mesh)
        assert float(jnp.abs(y - x @ w1).max()) < 1e-4
        z = tp_matmul_reducescatter(y, w2, mesh)
        assert float(jnp.abs(z - (x @ w1) @ w2).max()) < 1e-3
        # unoverlapped references agree too
        y2 = tp_allgather_matmul(x, w1, mesh, overlapped=False)
        z2 = tp_matmul_reducescatter(y, w2, mesh, overlapped=False)
        assert float(jnp.abs(y2 - y).max()) < 1e-4
        assert float(jnp.abs(z2 - z).max()) < 1e-3
    """)


def test_train_step_sharded_2d_matches_single_device():
    run_spmd("""
        from repro import sharding as shd
        from repro.configs import get_config
        from repro.models import build_model
        from repro.launch.steps import make_train_state, make_train_step
        from repro.launch.mesh import make_mesh
        from repro.optim import OptConfig

        cfg = get_config("granite_8b", smoke=True)
        model = build_model(cfg)
        oc = OptConfig(warmup_steps=1, total_steps=10)
        rng = np.random.default_rng(0)
        b, s = 4, 32
        batch = {
          "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
          "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
          "mask": jnp.ones((b, s), jnp.float32),
        }
        # single device
        state = make_train_state(model, jax.random.PRNGKey(0))
        _, m1 = jax.jit(make_train_step(model, oc))(state, batch)

        # 2D sharded
        mesh = make_mesh((4, 2), ("data", "model"))
        with shd.use_sharding_rules(mesh):
            state2 = make_train_state(model, jax.random.PRNGKey(0))
            shs = shd.named_shardings(jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state2), mesh)
            state2 = jax.tree.map(jax.device_put, state2, shs)
            step = jax.jit(make_train_step(model, oc))
            _, m2 = step(state2, batch)
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert abs(l1 - l2) / abs(l1) < 5e-2, (l1, l2)
    """)


def test_elastic_reshard_roundtrip():
    run_spmd("""
        import tempfile
        from repro import sharding as shd
        from repro.checkpoint import CheckpointManager, elastic_restore
        from repro.configs import get_config
        from repro.models import build_model
        from repro.launch.steps import make_train_state
        from repro.launch.mesh import make_mesh

        cfg = get_config("granite_8b", smoke=True)
        model = build_model(cfg)
        mesh_a = make_mesh((4, 2), ("data", "model"))   # healthy fleet
        mesh_b = make_mesh((2, 2), ("data", "model"))   # after losing hosts

        with shd.use_sharding_rules(mesh_a):
            state = make_train_state(model, jax.random.PRNGKey(0))
            shs = shd.named_shardings(jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state), mesh_a)
            state = jax.tree.map(jax.device_put, state, shs)

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_=False)
            mgr.save(state, 7)
            restored, step = elastic_restore(mgr, state, mesh_b)
            assert step == 7
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            # restored arrays really live on mesh_b
            leaf = jax.tree.leaves(restored)[0]
            assert leaf.sharding.mesh.shape == mesh_b.shape
    """)


def test_compressed_psum_close_to_exact():
    run_spmd("""
        from repro.optim.compression import compressed_psum
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("pod",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 1024)), jnp.float32)

        def f(xs):
            return compressed_psum(xs, "pod")

        from repro.compat import shard_map
        out = shard_map(f, mesh=mesh, in_specs=P("pod", None),
                        out_specs=P("pod", None))(x)
        want = jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)
        err = float(jnp.abs(out - want).max())
        scale = float(jnp.abs(x).max()) / 127
        assert err <= 8 * scale + 1e-6, (err, scale)
    """)


def test_pipeline_parallel_matches_sequential():
    """GPipe over the pod axis: forward exact, gradients correct."""
    run_spmd("""
        from repro.core.pipeline import pipeline_apply, split_stages
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("pod", "model"))
        rng = np.random.default_rng(0)
        L, D = 8, 16
        ws = jnp.asarray(rng.normal(size=(L, D, D)) * 0.3, jnp.float32)
        M, mb, S = 6, 2, 4
        x = jnp.asarray(rng.normal(size=(M, mb, S, D)), jnp.float32)

        def layer(w, h):
            return jnp.tanh(h @ w)

        def stage_fn(stage_ws, h):
            h, _ = jax.lax.scan(lambda h, w: (layer(w, h), None), h, stage_ws)
            return h

        def seq_apply(ws_, xm):
            out, _ = jax.lax.scan(lambda h, w: (layer(w, h), None), xm, ws_)
            return out

        ref = jax.vmap(lambda xm: seq_apply(ws, xm))(x)
        stages = split_stages(ws, 4)
        out = pipeline_apply(stage_fn, stages, x, mesh)
        assert float(jnp.abs(out - ref).max()) < 1e-6

        g_pipe = jax.grad(lambda w_, x_: jnp.sum(
            pipeline_apply(stage_fn, w_, x_, mesh) ** 2))(stages, x)
        g_seq = jax.grad(lambda w_, x_: jnp.sum(
            jax.vmap(lambda xm: seq_apply(w_, xm))(x_) ** 2))(ws, x)
        err = float(jnp.abs(g_pipe.reshape(L, D, D) - g_seq).max())
        assert err < 1e-4, err
    """)


def test_dryrun_single_cell_on_8_devices():
    """End-to-end dry-run machinery on a small mesh (fast sanity — the full
    512-device run is exercised by repro.launch.dryrun itself)."""
    run_spmd("""
        from repro import sharding as shd
        from repro.configs import get_config, SHAPES
        from repro.launch.mesh import make_mesh
        from repro.launch import dryrun as dr

        cfg = get_config("granite_8b", smoke=True).replace(scan_layers=True)
        mesh = make_mesh((4, 2), ("data", "model"))
        shape = SHAPES["train_4k"]
        import dataclasses
        shape = dataclasses.replace(shape, seq_len=128, global_batch=8)
        lowered, compiled, meta = dr.lower_cell(cfg, shape, mesh)
        ma = compiled.memory_analysis()
        assert ma.argument_size_in_bytes > 0
        from repro.compat import cost_analysis
        ca = cost_analysis(compiled)
        assert ca.get("flops", 0) > 0
        colls = dr.parse_collectives(compiled.as_text())
        assert colls["total"] > 0
    """)
