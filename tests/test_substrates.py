"""Checkpointing, data pipeline, compression, and fault-tolerance units."""

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, PrefetchPipeline, SyntheticLM
from repro.optim.compression import (
    compress_with_feedback, dequantize, init_residual, quantize)
from repro.runtime import (
    HeartbeatTracker, StragglerMonitor, plan_elastic_remesh)


# ------------------------------------------------------------- checkpointing

def _state(rng):
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(8,)), jnp.bfloat16)},
        "opt": {"mu": {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,))}},
        "step": jnp.int32(3),
    }


def test_checkpoint_roundtrip(tmp_path, rng):
    state = _state(rng)
    mgr = CheckpointManager(tmp_path, async_=False)
    mgr.save(state, 10)
    restored, step = mgr.restore(state)
    assert step == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_async_and_retention(tmp_path, rng):
    state = _state(rng)
    mgr = CheckpointManager(tmp_path, keep=2, async_=True)
    for s in (1, 2, 3, 4):
        mgr.save(state, s)
    mgr.wait()
    steps = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert steps == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4
    mgr.close()


def test_checkpoint_partial_write_is_not_restorable(tmp_path, rng):
    state = _state(rng)
    mgr = CheckpointManager(tmp_path, async_=False)
    mgr.save(state, 1)
    # simulate a crash mid-write of step 2: tmp dir without manifest rename
    broken = Path(tmp_path) / "step_00000002.tmp"
    broken.mkdir()
    (broken / "garbage.npy").write_bytes(b"xx")
    assert mgr.latest_step() == 1
    restored, step = mgr.restore(state)
    assert step == 1


def test_checkpoint_resume_determinism(tmp_path, rng):
    """Training N steps straight == training k, restoring, training N-k."""
    from repro.configs import get_config
    from repro.launch.steps import make_train_state, make_train_step
    from repro.models import build_model
    from repro.optim import OptConfig

    cfg = get_config("relic_tiny", smoke=True)
    model = build_model(cfg)
    oc = OptConfig(warmup_steps=2, total_steps=20)
    step_fn = jax.jit(make_train_step(model, oc))
    dc = DataConfig(seq_len=32, global_batch=4, vocab_size=cfg.vocab_size)
    src = SyntheticLM(dc)

    def run(state, lo, hi):
        for i in range(lo, hi):
            batch = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
            state, m = step_fn(state, batch)
        return state, m

    s0 = make_train_state(model, jax.random.PRNGKey(0))
    straight, m_straight = run(s0, 0, 6)

    s1 = make_train_state(model, jax.random.PRNGKey(0))
    s1, _ = run(s1, 0, 3)
    mgr = CheckpointManager(tmp_path, async_=False)
    mgr.save(s1, 3)
    s2, _ = mgr.restore(s1)
    resumed, m_resumed = run(s2, 3, 6)
    np.testing.assert_allclose(float(m_straight["loss"]),
                               float(m_resumed["loss"]), rtol=1e-5)


# ------------------------------------------------------------- data pipeline

def test_pipeline_deterministic_restart():
    dc = DataConfig(seq_len=16, global_batch=4, vocab_size=100, prefetch=4)
    src = SyntheticLM(dc)
    p1 = PrefetchPipeline(src, dc).start()
    first = [p1.next_batch()["tokens"] for _ in range(5)]
    p1.stop()
    # restart at index 3 must replay batches 3, 4, ...
    p2 = PrefetchPipeline(src, dc, start_index=3).start()
    replay = [p2.next_batch()["tokens"] for _ in range(2)]
    p2.stop()
    np.testing.assert_array_equal(first[3], replay[0])
    np.testing.assert_array_equal(first[4], replay[1])


def test_pipeline_shards_disjoint_batches():
    dc0 = DataConfig(seq_len=16, global_batch=8, vocab_size=1000,
                     shard=0, num_shards=2)
    dc1 = DataConfig(seq_len=16, global_batch=8, vocab_size=1000,
                     shard=1, num_shards=2)
    b0 = SyntheticLM(dc0).batch(0)
    b1 = SyntheticLM(dc1).batch(0)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_pipeline_keeps_prefetch_depth():
    dc = DataConfig(seq_len=8, global_batch=2, vocab_size=50, prefetch=4)
    p = PrefetchPipeline(SyntheticLM(dc), dc).start()
    time.sleep(0.2)
    # assistant should have filled the ring
    assert len(p._ring) >= 1
    for _ in range(10):
        p.next_batch()
    p.stop()


# -------------------------------------------------------------- compression

@given(st.integers(0, 2**32 - 1), st.integers(1, 4096))
@settings(deadline=None, max_examples=30)
def test_quantize_error_bound(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * rng.uniform(0.1, 10), jnp.float32)
    q, s, size = quantize(x)
    back = dequantize(q, s, size, x.shape)
    # per-block error bounded by half a quantization step
    blocks = np.asarray(jnp.pad(x, (0, (-n) % 256)).reshape(-1, 256))
    step = np.abs(blocks).max(1) / 127.0
    err = np.abs(np.asarray(back) - np.asarray(x))
    err_blocks = np.pad(err, (0, (-n) % 256)).reshape(-1, 256)
    assert (err_blocks.max(1) <= step / 2 + 1e-7).all()


def test_error_feedback_preserves_signal():
    """Sum of EF-compressed grads converges to the sum of raw grads."""
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(128,)), jnp.float32)}
    res = init_residual(grads)
    total_c = jnp.zeros((128,))
    steps = 50
    for _ in range(steps):
        c, res = compress_with_feedback(grads, res)
        total_c = total_c + c["w"]
    total_raw = grads["w"] * steps
    # residual carry-over keeps the long-run average unbiased
    err = float(jnp.abs(total_c + res["w"] - total_raw).max())
    assert err < 1e-3, err


# ---------------------------------------------------------------- adafactor

def test_adafactor_trains_and_saves_memory():
    from repro.configs import get_config
    from repro.models import build_model
    from repro.optim import (AdafactorConfig, OptConfig, adafactor_update,
                             clip_by_global_norm, init_adafactor_state,
                             schedule, state_bytes)

    cfg = get_config("relic_tiny", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                              jnp.int32),
        "mask": jnp.ones((4, 32), jnp.float32),
    }
    ac = AdafactorConfig()
    oc = OptConfig(peak_lr=1e-2, warmup_steps=2, total_steps=40)
    opt = init_adafactor_state(params)

    @jax.jit
    def step(params, opt, i):
        (loss, _), g = jax.value_and_grad(model.loss, has_aux=True)(params,
                                                                    batch)
        g, _ = clip_by_global_norm(g, 1.0)
        params, opt = adafactor_update(ac, g, opt, params, i, schedule(oc, i))
        return params, opt, loss

    l0 = None
    for i in range(20):
        params, opt, loss = step(params, opt, jnp.int32(i))
        l0 = l0 if l0 is not None else float(loss)
    assert float(loss) < l0 - 0.5, (l0, float(loss))

    # factored state is far smaller than Adam's
    adam_b = state_bytes(params, adam=True)
    af_b = state_bytes(params, adam=False)
    assert af_b < adam_b / 20, (adam_b, af_b)


# ------------------------------------------------------------------- faults

def test_straggler_monitor_flags_persistent_slow_host():
    mon = StragglerMonitor(n_hosts=8, window=16, z=4.0, patience=3)
    rng = np.random.default_rng(0)
    flagged = []
    for step in range(40):
        d = 0.1 + rng.normal(0, 0.002, size=8)
        if step >= 10:
            d[3] = 0.25  # host 3 goes slow
        mon.record_step(d.tolist())
        flagged = mon.stragglers()
    assert flagged == [3]
    st_ = mon.stats()
    assert st_.worst_host == 3 and st_.worst_ratio > 2


def test_straggler_monitor_ignores_transients():
    mon = StragglerMonitor(n_hosts=4, window=16, patience=3)
    rng = np.random.default_rng(1)
    for step in range(30):
        d = (0.1 + rng.normal(0, 0.002, size=4))
        if step == 12:
            d[2] = 1.0  # one-off GC pause
        mon.record_step(d.tolist())
    assert mon.stragglers() == []


def test_heartbeat_dead_detection():
    t = {"now": 1000.0}
    hb = HeartbeatTracker(n_hosts=4, timeout_s=30, clock=lambda: t["now"])
    t["now"] = 1020.0
    for h in (0, 1, 3):
        hb.beat(h)
    t["now"] = 1045.0
    assert hb.dead() == [2]


def test_elastic_plan_shrinks_data_axis():
    plan = plan_elastic_remesh((16, 16), ("data", "model"), dead_hosts=[5],
                               chips_per_host=4, restore_step=1200)
    assert plan.new_shape == (12, 16)
    assert plan.dropped_hosts == (5,)
    with pytest.raises(RuntimeError):
        plan_elastic_remesh((4, 16), ("data", "model"), dead_hosts=[0],
                            chips_per_host=4, restore_step=None)
