"""Shared metrics primitives (repro.runtime.metrics) and the serve-layer
re-export contract.

The nearest-rank percentile helpers and ``LatencySeries`` moved from
``repro.serve.metrics`` into the runtime layer so the streaming executor
and benchmarks can use them without importing the serving stack. The
serve module re-exports them; these identity pins turn an accidental
re-implementation (two diverging copies) into a test failure.
"""

import numpy as np
import pytest

import repro.runtime.metrics as runtime_metrics
import repro.serve.metrics as serve_metrics
from repro.runtime.metrics import Gauge, LatencySeries, nearest_rank, percentiles


# ------------------------------------------------------------- identity pins

def test_serve_reexports_are_the_same_objects():
    assert serve_metrics.nearest_rank is runtime_metrics.nearest_rank
    assert serve_metrics.percentiles is runtime_metrics.percentiles
    assert serve_metrics.LatencySeries is runtime_metrics.LatencySeries
    assert serve_metrics.Gauge is runtime_metrics.Gauge


# ------------------------------------------------------------- nearest rank

def test_nearest_rank_matches_definition():
    vals = sorted([5.0, 1.0, 3.0, 2.0, 4.0])
    # rank = ceil(q/100 * n), 1-indexed, clamped to [1, n]
    assert nearest_rank(vals, 50) == 3.0
    assert nearest_rank(vals, 95) == 5.0
    assert nearest_rank(vals, 100) == 5.0
    assert nearest_rank(vals, 1) == 1.0
    assert nearest_rank([7.0], 99) == 7.0


def test_percentiles_dict():
    vals = [float(i) for i in range(1, 101)]
    p = percentiles(vals)
    assert p == {50: 50.0, 95: 95.0, 99: 99.0}
    with pytest.raises(ValueError, match="empty sample"):
        percentiles([])


def test_nearest_rank_agrees_with_numpy_on_large_samples():
    rng = np.random.default_rng(0)
    vals = sorted(rng.exponential(10.0, size=5000).tolist())
    for q in (50, 90, 99):
        ours = nearest_rank(vals, q)
        ref = float(np.percentile(vals, q, method="inverted_cdf"))
        assert abs(ours - ref) <= 1e-9


# ------------------------------------------------------------ LatencySeries

def test_latency_series_snapshot_and_percentiles():
    s = LatencySeries()
    for v in (3.0, 1.0, 2.0):
        s.add(v)
    assert len(s) == 3
    assert s.snapshot() == [3.0, 1.0, 2.0]   # insertion order preserved
    assert s.percentiles()[50] == 2.0


def test_latency_series_empty():
    s = LatencySeries()
    assert len(s) == 0
    assert s.snapshot() == []


# -------------------------------------------------------------------- Gauge

def test_gauge_observe_and_mean():
    g = Gauge()
    for v in (2.0, 4.0, 6.0):
        g.observe(v)
    assert g.samples == 3
    assert g.last == 6.0
    assert g.min == 2.0 and g.max == 6.0
    assert g.mean == 4.0
    d = g.asdict()
    assert d == {"last": 6.0, "min": 2.0, "max": 6.0, "mean": 4.0}
    assert Gauge().asdict() == {"last": 0.0, "min": 0.0, "max": 0.0,
                                "mean": 0.0}
