"""RelicPool semantics: lane striping, broadcast hints, cross-lane errors.

The pool-specific half of the PR 5 coverage (the generic Scheduler contract
for ``relic-pool``/``relic2``/``relic4`` lives in the conformance suite,
which parametrizes over every registered substrate automatically).
"""

import threading
import time

import pytest

from repro.core.relic import (Relic, RelicUsageError,
                              resolve_spin_pause_every)
from repro.core.relic_pool import RelicPool
from repro.core.schedulers import make_scheduler
from repro.core.spsc import SpscRing

LANE_COUNTS = [1, 2, 4]


# ------------------------------------------------------------ lane striping

@pytest.mark.parametrize("lanes", LANE_COUNTS)
def test_submit_stripes_round_robin_over_every_lane(lanes):
    """Single submissions land on all lanes, evenly (pure round-robin when
    no ring ever fills)."""
    done = []
    with RelicPool(lanes=lanes, start_awake=True) as pool:
        for i in range(8 * lanes):
            pool.submit(done.append, i)
        pool.wait()
    assert sorted(done) == list(range(8 * lanes))
    assert [s.submitted for s in pool.stats.lanes] == [8] * lanes


@pytest.mark.parametrize("lanes", LANE_COUNTS)
def test_submit_batch_shards_across_every_lane_in_one_pass(lanes):
    """A burst is dealt out as contiguous near-equal shards."""
    done = []
    with RelicPool(lanes=lanes, start_awake=True) as pool:
        pool.submit_batch([(done.append, (i,), {}) for i in range(8 * lanes)])
        pool.wait()
    assert sorted(done) == list(range(8 * lanes))
    assert [s.submitted for s in pool.stats.lanes] == [8] * lanes


def test_small_burst_rotates_lanes_across_bursts():
    """A burst smaller than the lane count advances the round-robin cursor
    by its remainder, so successive small bursts cover all lanes."""
    with RelicPool(lanes=4, start_awake=True) as pool:
        for _ in range(4):
            pool.submit_batch([(lambda: None, (), {})] * 3)
        pool.wait()
    assert [s.submitted for s in pool.stats.lanes] == [3, 3, 3, 3]


def test_each_lane_preserves_fifo_locally():
    """The SPSC invariant survives pooling: per-lane completion order is
    per-lane submission order (global order is explicitly NOT promised)."""
    lanes = 3
    per_lane = [[] for _ in range(lanes)]
    with RelicPool(lanes=lanes, start_awake=True) as pool:
        for i in range(60):
            # round-robin: submission i goes to lane i % lanes
            per = per_lane[i % lanes]
            pool.submit(per.append, i)
        pool.wait()
    for lane_idx, got in enumerate(per_lane):
        assert got == sorted(got), f"lane {lane_idx} reordered"
        assert [g % lanes for g in got] == [lane_idx] * len(got)


def test_single_lane_pool_is_globally_fifo():
    out = []
    with RelicPool(lanes=1, start_awake=True) as pool:
        for i in range(200):
            pool.submit(out.append, i)
        pool.wait()
    assert out == list(range(200))


def test_full_lane_falls_back_to_least_loaded():
    """When the round-robin target's ring is full, submit() places the task
    on another (least-loaded) lane instead of spinning on the full one —
    even while the full lane's assistant is wedged behind a long task."""
    gate = threading.Event()
    with RelicPool(lanes=2, capacity=2, start_awake=True) as pool:
        pool.submit(gate.wait)          # lane 0's assistant blocks here
        # Deterministic: wait until lane 0's assistant has actually popped
        # the blocker (ring drained) before filling the ring — a fixed
        # sleep makes the submitted-count assertions flaky on a loaded
        # runner.
        deadline = time.time() + 5
        while len(pool._lanes[0]._ring) and time.time() < deadline:
            time.sleep(0.001)
        assert not len(pool._lanes[0]._ring), "assistant never popped"
        # Fill lane 0's ring while it is blocked. Round-robin alternates,
        # so submit 2*capacity+1 tasks: lane 0 receives capacity and is
        # full, after which its round-robin turns must overflow to lane 1.
        for i in range(8):
            pool.submit(lambda: None)
        lane0, lane1 = pool.stats.lanes
        assert lane0.submitted == 3     # the blocker + its full ring (cap 2)
        assert lane1.submitted == 6     # its own turns + every fallback
        gate.set()
        pool.wait()
        assert pool.stats.completed == 9


# ----------------------------------------------------------- hint broadcast

def test_hints_broadcast_to_every_lane():
    lanes = 3
    pool = RelicPool(lanes=lanes).start()       # start_awake=False: parked
    try:
        time.sleep(0.05)
        assert sum(s.parks for s in pool.stats.lanes) == lanes
        pool.wake_up_hint()
        time.sleep(0.05)
        for lane in pool._lanes:
            assert lane._awake.is_set()
        pool.sleep_hint()
        for lane in pool._lanes:
            assert not lane._awake.is_set()
        # Advisory rule survives broadcast: a barrier over parked lanes
        # un-parks them rather than deadlocking.
        done = []
        for i in range(6):
            pool.submit(done.append, i)
        pool.wait()
        assert sorted(done) == list(range(6))
    finally:
        pool.shutdown()


# ----------------------------------------------- first-error-wins across lanes

def test_first_error_by_submission_order_wins_across_lanes():
    """Submission order, not lane order, decides which error wait()
    re-raises: a later-submitted failure on lane 0 must lose to an
    earlier-submitted failure on lane 1."""

    def boom(exc):
        raise exc

    with RelicPool(lanes=2, start_awake=True) as pool:
        pool.submit(lambda: None)               # seq 0 -> lane 0
        pool.submit(boom, IndexError("seq 1"))  # seq 1 -> lane 1 (earliest)
        pool.submit(boom, ValueError("seq 2"))  # seq 2 -> lane 0
        pool.submit(boom, KeyError("seq 3"))    # seq 3 -> lane 1
        with pytest.raises(IndexError, match="seq 1"):
            pool.wait()
        assert pool.stats.task_errors == 3
        # The channel is cleared: the next window's own first error wins.
        pool.submit(boom, ZeroDivisionError())  # lane 0
        with pytest.raises(ZeroDivisionError):
            pool.wait()
        assert pool.stats.task_errors == 4
        done = []
        pool.submit(done.append, "after")       # still usable
        pool.wait()
        assert done == ["after"]


def test_first_error_ordering_covers_submit_batch_shards():
    """Shard striping keeps the submission-order error rule: the earliest
    failing task of a burst wins even when a lower-numbered lane also
    fails (with a later task of the same burst)."""

    def boom(exc):
        raise exc

    tasks = [(lambda: None, (), {}) for _ in range(8)]
    # lanes=2, burst of 8 -> lane 0 gets seqs 0-3, lane 1 gets seqs 4-7.
    tasks[4] = (boom, (IndexError("seq 4"),), {})   # lane 1, earliest failure
    tasks[6] = (boom, (KeyError("seq 6"),), {})     # lane 1
    tasks[5] = (boom, (ValueError("seq 5"),), {})   # lane 1
    tasks[7] = (boom, (OSError("seq 7"),), {})      # lane 1
    with RelicPool(lanes=2, start_awake=True) as pool:
        pool.submit_batch(tasks)
        with pytest.raises(IndexError, match="seq 4"):
            pool.wait()
        assert pool.stats.task_errors == 4


def test_rotated_burst_error_ordering_beats_lane_order():
    """Discriminates seq-order from lane-order: after the cursor rotates,
    the HIGHER-numbered lane holds the earlier seqs of the next burst —
    its failure must win over a lower-numbered lane's later failure (an
    implementation ordering errors by lane index would raise the wrong
    one)."""

    def boom(exc):
        raise exc

    with RelicPool(lanes=2, start_awake=True) as pool:
        # burst of 3: rem=1 advances the cursor to lane 1 (seqs 0-2 ok)
        pool.submit_batch([(lambda: None, (), {})] * 3)
        # burst of 8 from cursor=1: lane 1 gets seqs 3-6, lane 0 seqs 7-10
        tasks = [(lambda: None, (), {}) for _ in range(8)]
        tasks[2] = (boom, (IndexError("early, lane 1"),), {})  # seq 5
        tasks[5] = (boom, (ValueError("late, lane 0"),), {})   # seq 8
        pool.submit_batch(tasks)
        lane0, lane1 = pool.stats.lanes
        assert lane0.submitted == 6 and lane1.submitted == 5  # rotation held
        with pytest.raises(IndexError, match="early, lane 1"):
            pool.wait()
        assert pool.stats.task_errors == 2


def test_burst_shards_flow_past_a_wedged_lane():
    """Two-phase burst delivery: a lane wedged behind a long task (its
    ring full) must not stop the other lanes' shards of the same burst
    from being delivered and run — including the cross-shard-dependency
    shape where the wedged task itself waits on later-shard work."""
    release = threading.Event()
    other_done = threading.Event()
    with RelicPool(lanes=2, capacity=2, start_awake=True) as pool:
        pool.submit(release.wait)       # wedge lane 0 (popped, blocking)
        deadline = time.time() + 5
        while len(pool._lanes[0]._ring) and time.time() < deadline:
            time.sleep(0.001)
        pool.submit(lambda: None)       # lane 1's rr turn
        pool.submit(lambda: None)       # lane 0 ring: 1
        pool.submit(lambda: None)       # lane 1
        pool.submit(lambda: None)       # lane 0 ring: 2 == capacity, full
        # Burst of 8 (cursor is at lane 1): lane 1's shard is tasks[0..3],
        # lane 0's is tasks[4..7] and cannot be handed off until the wedge
        # clears. Two-phase delivery means lane 1's shard runs WHILE the
        # producer is still blocked sweeping lane 0's remainder — the
        # releaser thread records whether that actually happened before it
        # clears the wedge (the cross-shard dependency the sweep exists
        # for). Head-of-line delivery would record False: nothing of lane
        # 1's shard would run until the 5 s timeout force-released it.
        done = []
        tasks = [(done.append, (i,), {}) for i in range(8)]
        tasks[1] = (other_done.set, (), {})     # lands in lane 1's shard

        ran_before_release = []

        def releaser():
            ran_before_release.append(other_done.wait(5))
            release.set()

        t = threading.Thread(target=releaser)
        t.start()
        pool.submit_batch(tasks)        # main thread: the only producer
        t.join(5)
        pool.wait()
        assert ran_before_release == [True], \
            "lane 1's shard never ran past the wedged lane 0"
    assert sorted(done) == [0, 2, 3, 4, 5, 6, 7]


def test_seq_log_stays_bounded_without_wait():
    """A fire-and-observe consumer that never calls wait() (pipeline-style
    use on a long-lived scope) must not grow the per-lane seq log one
    entry per task forever: completed tasks' entries are trimmed on the
    submit path, keeping the log O(capacity)."""
    with RelicPool(lanes=2, capacity=8, start_awake=True) as pool:
        for i in range(5_000):
            pool.submit(lambda: None)
        high_water = max(len(r) for r in pool._runs)
        # in-flight bound is 2*capacity; the log trims at 4*capacity, so
        # it must never get far past that (slack for the racy _completed)
        assert high_water <= 2 * pool._trim_at, high_water
        pool.wait()
        assert pool.stats.completed == 5_000
        assert all(len(r) == 0 for r in pool._runs)


def test_first_error_ordering_survives_seq_log_trimming():
    """Submission-order error ordering must hold even after the log has
    been trimmed many times: a pending error's entry is kept mappable."""

    def boom(exc):
        raise exc

    with RelicPool(lanes=2, capacity=4, start_awake=True) as pool:
        for i in range(200):          # many trims at capacity 4
            pool.submit(lambda: None)
        # earliest-submitted failure (whatever lane striping/fallback
        # placed it on) must win over the later one
        pool.submit(boom, IndexError("earlier"))
        for i in range(150):          # more trims after the pending error
            pool.submit(lambda: None)
        pool.submit(boom, ValueError("later"))
        with pytest.raises(IndexError, match="earlier"):
            pool.wait()
        assert pool.stats.task_errors == 2


# ------------------------------------------------------------------- misuse

def test_assistant_threads_cannot_submit():
    errs = []
    with RelicPool(lanes=2, start_awake=True) as pool:
        def recursive():
            try:
                pool.submit(lambda: None)
            except RelicUsageError as e:
                errs.append(e)

        for _ in range(2):
            pool.submit(recursive)
        pool.wait()
    assert len(errs) == 2


def test_submit_after_shutdown_raises_and_lanes_match():
    pool = RelicPool(lanes=2).start()
    pool.shutdown()
    with pytest.raises(RelicUsageError, match="shutdown"):
        pool.submit(lambda: None)
    with pytest.raises(RelicUsageError, match="shutdown"):
        pool.submit_batch([(lambda: None, (), {})])
    with pytest.raises(RelicUsageError, match="already started"):
        pool.start()


def test_pool_rejects_nonpositive_lanes():
    with pytest.raises(ValueError, match="lanes"):
        RelicPool(lanes=0)


def test_convenience_names_reject_conflicting_lane_counts():
    """relic2/relic4 ARE their lane counts: an explicit conflicting
    lanes= must raise, never silently mislabel a differently-sized pool
    (BENCH rows are keyed by name). The matching count and the generic
    name stay configurable."""
    with pytest.raises(ValueError, match="fixed at lanes=4"):
        make_scheduler("relic4", lanes=2)
    assert make_scheduler("relic4", lanes=4).workers == 4   # no-op explicit
    assert make_scheduler("relic-pool", lanes=3).workers == 3


# ----------------------------------------------------------- aggregate stats

def test_stats_aggregate_and_expose_lanes():
    with RelicPool(lanes=2, start_awake=True) as pool:
        for i in range(10):
            pool.submit(lambda: None)
        pool.wait()
        assert pool.stats.submitted == 10
        assert pool.stats.completed == 10
        assert pool.stats.task_errors == 0
        assert len(pool.stats.lanes) == 2
        assert sum(s.submitted for s in pool.stats.lanes) == 10
        assert "lanes=2" in repr(pool.stats)


def test_scheduler_adapter_close_keeps_error_observable():
    sched = make_scheduler("relic2").start()
    sched.submit(lambda: 1 / 0)
    sched.close()
    assert sched.stats.task_errors == 1
    assert isinstance(sched.stats.last_error, ZeroDivisionError)


# ---------------------------------------- satellite: SpscRing.__len__ clamp

def test_ring_len_clamps_negative_observer_estimate():
    """A third (observer) thread can see a fresh _head against a stale
    _tail, making tail-head negative; len() must clamp to 0 (the pool's
    least-loaded picker and stats readers never see -1). Simulated by
    writing the counters the way the stale read would present them."""
    ring = SpscRing(8)
    for i in range(4):
        ring.push(i)
    assert len(ring) == 4
    ring._head = 5                      # observer: fresh head, stale tail
    ring._tail = 3
    assert len(ring) == 0


def test_ring_push_many_stop_bounds_the_window():
    """push_many's stop parameter pushes exactly items[start:stop] — the
    shard hand-off RelicPool uses on one shared flattened burst."""
    ring = SpscRing(16)
    items = list(range(10))
    assert ring.push_many(items, 2, 7) == 5
    assert ring.pop_many() == [2, 3, 4, 5, 6]
    assert ring.push_many(items, 7, 7) == 0     # empty window: no-op
    assert ring.push_many(items, 8) == 2        # stop=None: to the end
    assert ring.pop_many() == [8, 9]


# ------------------------------- satellite: RELIC_SPIN_PAUSE_EVERY override

def test_spin_pause_every_env_override(monkeypatch):
    monkeypatch.setenv("RELIC_SPIN_PAUSE_EVERY", "7")
    assert resolve_spin_pause_every() == 7
    rt = Relic()
    assert rt._spin_pause_every == 7
    pool = RelicPool(lanes=2)
    assert all(lane._spin_pause_every == 7 for lane in pool._lanes)
    spin = make_scheduler("spin")
    assert spin._spin_pause_every == 7
    # Re-read per instance, not frozen at import: a later change to the
    # environment is visible to the next runtime.
    monkeypatch.setenv("RELIC_SPIN_PAUSE_EVERY", "3")
    assert Relic()._spin_pause_every == 3


def test_spin_pause_every_env_unset_uses_cpu_heuristic(monkeypatch):
    monkeypatch.delenv("RELIC_SPIN_PAUSE_EVERY", raising=False)
    import os

    expected = 1 if (os.cpu_count() or 1) < 3 else 64
    assert resolve_spin_pause_every() == expected
    monkeypatch.setenv("RELIC_SPIN_PAUSE_EVERY", "")
    assert resolve_spin_pause_every() == expected


@pytest.mark.parametrize("bad", ["0", "-3", "many", "1.5"])
def test_spin_pause_every_env_invalid_raises(monkeypatch, bad):
    monkeypatch.setenv("RELIC_SPIN_PAUSE_EVERY", bad)
    with pytest.raises(ValueError, match="RELIC_SPIN_PAUSE_EVERY"):
        resolve_spin_pause_every()


def test_spin_pause_override_still_completes_work(monkeypatch):
    """The cadence is a perf knob, never a correctness knob: an aggressive
    override must not change observable semantics."""
    monkeypatch.setenv("RELIC_SPIN_PAUSE_EVERY", "1")
    done = []
    with RelicPool(lanes=2, capacity=2, start_awake=True) as pool:
        pool.submit_batch([(done.append, (i,), {}) for i in range(50)])
        pool.wait()
    assert sorted(done) == list(range(50))
