"""RelicPool semantics: lane striping, broadcast hints, cross-lane errors.

The pool-specific half of the PR 5 coverage (the generic Scheduler contract
for ``relic-pool``/``relic2``/``relic4`` lives in the conformance suite,
which parametrizes over every registered substrate automatically).
"""

import threading
import time

import pytest

from repro.core.relic import (Relic, RelicUsageError,
                              resolve_spin_pause_every)
from repro.core.relic_pool import RelicPool
from repro.core.schedulers import make_scheduler
from repro.core.spsc import SpscRing

LANE_COUNTS = [1, 2, 4]


# ------------------------------------------------------------ lane striping

@pytest.mark.parametrize("lanes", LANE_COUNTS)
def test_submit_stripes_round_robin_over_every_lane(lanes):
    """Single submissions land on all lanes, evenly (pure round-robin when
    no ring ever fills)."""
    done = []
    with RelicPool(lanes=lanes, start_awake=True) as pool:
        for i in range(8 * lanes):
            pool.submit(done.append, i)
        pool.wait()
    assert sorted(done) == list(range(8 * lanes))
    assert [s.submitted for s in pool.stats.lanes] == [8] * lanes


@pytest.mark.parametrize("lanes", LANE_COUNTS)
def test_submit_batch_shards_across_every_lane_in_one_pass(lanes):
    """A burst is dealt out as contiguous near-equal shards."""
    done = []
    with RelicPool(lanes=lanes, start_awake=True) as pool:
        pool.submit_batch([(done.append, (i,), {}) for i in range(8 * lanes)])
        pool.wait()
    assert sorted(done) == list(range(8 * lanes))
    assert [s.submitted for s in pool.stats.lanes] == [8] * lanes


def test_small_burst_rotates_lanes_across_bursts():
    """A burst smaller than the lane count advances the round-robin cursor
    by its remainder, so successive small bursts cover all lanes."""
    with RelicPool(lanes=4, start_awake=True) as pool:
        for _ in range(4):
            pool.submit_batch([(lambda: None, (), {})] * 3)
        pool.wait()
    assert [s.submitted for s in pool.stats.lanes] == [3, 3, 3, 3]


def test_each_lane_preserves_fifo_locally():
    """The SPSC invariant survives pooling: per-lane completion order is
    per-lane submission order (global order is explicitly NOT promised)."""
    lanes = 3
    per_lane = [[] for _ in range(lanes)]
    with RelicPool(lanes=lanes, start_awake=True) as pool:
        for i in range(60):
            # round-robin: submission i goes to lane i % lanes
            per = per_lane[i % lanes]
            pool.submit(per.append, i)
        pool.wait()
    for lane_idx, got in enumerate(per_lane):
        assert got == sorted(got), f"lane {lane_idx} reordered"
        assert [g % lanes for g in got] == [lane_idx] * len(got)


def test_single_lane_pool_is_globally_fifo():
    out = []
    with RelicPool(lanes=1, start_awake=True) as pool:
        for i in range(200):
            pool.submit(out.append, i)
        pool.wait()
    assert out == list(range(200))


def test_full_lane_falls_back_to_least_loaded():
    """When the round-robin target's ring is full, submit() places the task
    on another (least-loaded) lane instead of spinning on the full one —
    even while the full lane's assistant is wedged behind a long task.
    Pinned with ``rebalance=False``: with rebalancing on, a momentarily
    busy helper lane diverts singles into a handoff ring instead (covered
    by the handoff tests below), which makes these exact per-primary
    counts timing-dependent."""
    gate = threading.Event()
    with RelicPool(lanes=2, capacity=2, rebalance=False,
                   start_awake=True) as pool:
        pool.submit(gate.wait)          # lane 0's assistant blocks here
        # Deterministic: wait until lane 0's assistant has actually popped
        # the blocker (ring drained) before filling the ring — a fixed
        # sleep makes the submitted-count assertions flaky on a loaded
        # runner.
        deadline = time.time() + 5
        while len(pool._lanes[0]._ring) and time.time() < deadline:
            time.sleep(0.001)
        assert not len(pool._lanes[0]._ring), "assistant never popped"
        # Fill lane 0's ring while it is blocked. Round-robin alternates,
        # so submit 2*capacity+1 tasks: lane 0 receives capacity and is
        # full, after which its round-robin turns must overflow to lane 1.
        for i in range(8):
            pool.submit(lambda: None)
        lane0, lane1 = pool.stats.lanes
        assert lane0.submitted == 3     # the blocker + its full ring (cap 2)
        assert lane1.submitted == 6     # its own turns + every fallback
        gate.set()
        pool.wait()
        assert pool.stats.completed == 9


# ----------------------------------------------------------- hint broadcast

def test_hints_broadcast_to_every_lane():
    lanes = 3
    pool = RelicPool(lanes=lanes).start()       # start_awake=False: parked
    try:
        time.sleep(0.05)
        assert sum(s.parks for s in pool.stats.lanes) == lanes
        pool.wake_up_hint()
        time.sleep(0.05)
        for lane in pool._lanes:
            assert lane._awake.is_set()
        pool.sleep_hint()
        for lane in pool._lanes:
            assert not lane._awake.is_set()
        # Advisory rule survives broadcast: a barrier over parked lanes
        # un-parks them rather than deadlocking.
        done = []
        for i in range(6):
            pool.submit(done.append, i)
        pool.wait()
        assert sorted(done) == list(range(6))
    finally:
        pool.shutdown()


# ----------------------------------------------- first-error-wins across lanes

def test_first_error_by_submission_order_wins_across_lanes():
    """Submission order, not lane order, decides which error wait()
    re-raises: a later-submitted failure on lane 0 must lose to an
    earlier-submitted failure on lane 1."""

    def boom(exc):
        raise exc

    with RelicPool(lanes=2, start_awake=True) as pool:
        pool.submit(lambda: None)               # seq 0 -> lane 0
        pool.submit(boom, IndexError("seq 1"))  # seq 1 -> lane 1 (earliest)
        pool.submit(boom, ValueError("seq 2"))  # seq 2 -> lane 0
        pool.submit(boom, KeyError("seq 3"))    # seq 3 -> lane 1
        with pytest.raises(IndexError, match="seq 1"):
            pool.wait()
        assert pool.stats.task_errors == 3
        # The channel is cleared: the next window's own first error wins.
        pool.submit(boom, ZeroDivisionError())  # lane 0
        with pytest.raises(ZeroDivisionError):
            pool.wait()
        assert pool.stats.task_errors == 4
        done = []
        pool.submit(done.append, "after")       # still usable
        pool.wait()
        assert done == ["after"]


def test_first_error_ordering_covers_submit_batch_shards():
    """Shard striping keeps the submission-order error rule: the earliest
    failing task of a burst wins even when a lower-numbered lane also
    fails (with a later task of the same burst)."""

    def boom(exc):
        raise exc

    tasks = [(lambda: None, (), {}) for _ in range(8)]
    # lanes=2, burst of 8 -> lane 0 gets seqs 0-3, lane 1 gets seqs 4-7.
    tasks[4] = (boom, (IndexError("seq 4"),), {})   # lane 1, earliest failure
    tasks[6] = (boom, (KeyError("seq 6"),), {})     # lane 1
    tasks[5] = (boom, (ValueError("seq 5"),), {})   # lane 1
    tasks[7] = (boom, (OSError("seq 7"),), {})      # lane 1
    with RelicPool(lanes=2, start_awake=True) as pool:
        pool.submit_batch(tasks)
        with pytest.raises(IndexError, match="seq 4"):
            pool.wait()
        assert pool.stats.task_errors == 4


def test_rotated_burst_error_ordering_beats_lane_order():
    """Discriminates seq-order from lane-order: after the cursor rotates,
    the HIGHER-numbered lane holds the earlier seqs of the next burst —
    its failure must win over a lower-numbered lane's later failure (an
    implementation ordering errors by lane index would raise the wrong
    one)."""

    def boom(exc):
        raise exc

    with RelicPool(lanes=2, start_awake=True) as pool:
        # burst of 3: rem=1 advances the cursor to lane 1 (seqs 0-2 ok)
        pool.submit_batch([(lambda: None, (), {})] * 3)
        # burst of 8 from cursor=1: lane 1 gets seqs 3-6, lane 0 seqs 7-10
        tasks = [(lambda: None, (), {}) for _ in range(8)]
        tasks[2] = (boom, (IndexError("early, lane 1"),), {})  # seq 5
        tasks[5] = (boom, (ValueError("late, lane 0"),), {})   # seq 8
        pool.submit_batch(tasks)
        lane0, lane1 = pool.stats.lanes
        assert lane0.submitted == 6 and lane1.submitted == 5  # rotation held
        with pytest.raises(IndexError, match="early, lane 1"):
            pool.wait()
        assert pool.stats.task_errors == 2


def test_burst_shards_flow_past_a_wedged_lane():
    """Two-phase burst delivery: a lane wedged behind a long task (its
    ring full) must not stop the other lanes' shards of the same burst
    from being delivered and run — including the cross-shard-dependency
    shape where the wedged task itself waits on later-shard work."""
    release = threading.Event()
    other_done = threading.Event()
    with RelicPool(lanes=2, capacity=2, start_awake=True) as pool:
        pool.submit(release.wait)       # wedge lane 0 (popped, blocking)
        deadline = time.time() + 5
        while len(pool._lanes[0]._ring) and time.time() < deadline:
            time.sleep(0.001)
        pool.submit(lambda: None)       # lane 1's rr turn
        pool.submit(lambda: None)       # lane 0 ring: 1
        pool.submit(lambda: None)       # lane 1
        pool.submit(lambda: None)       # lane 0 ring: 2 == capacity, full
        # Burst of 8 (cursor is at lane 1): lane 1's shard is tasks[0..3],
        # lane 0's is tasks[4..7] and cannot be handed off until the wedge
        # clears. Two-phase delivery means lane 1's shard runs WHILE the
        # producer is still blocked sweeping lane 0's remainder — the
        # releaser thread records whether that actually happened before it
        # clears the wedge (the cross-shard dependency the sweep exists
        # for). Head-of-line delivery would record False: nothing of lane
        # 1's shard would run until the 5 s timeout force-released it.
        done = []
        tasks = [(done.append, (i,), {}) for i in range(8)]
        tasks[1] = (other_done.set, (), {})     # lands in lane 1's shard

        ran_before_release = []

        def releaser():
            ran_before_release.append(other_done.wait(5))
            release.set()

        t = threading.Thread(target=releaser)
        t.start()
        pool.submit_batch(tasks)        # main thread: the only producer
        t.join(5)
        pool.wait()
        assert ran_before_release == [True], \
            "lane 1's shard never ran past the wedged lane 0"
    assert sorted(done) == [0, 2, 3, 4, 5, 6, 7]


def test_seq_log_stays_bounded_without_wait():
    """A fire-and-observe consumer that never calls wait() (pipeline-style
    use on a long-lived scope) must not grow the per-lane seq log one
    entry per task forever: completed tasks' entries are trimmed on the
    submit path, keeping the log O(capacity)."""
    with RelicPool(lanes=2, capacity=8, start_awake=True) as pool:
        for i in range(5_000):
            pool.submit(lambda: None)
        high_water = max(len(r) for r in pool._runs)
        # in-flight bound is 2*capacity; the log trims at 4*capacity, so
        # it must never get far past that (slack for the racy _completed)
        assert high_water <= 2 * pool._trim_at, high_water
        pool.wait()
        assert pool.stats.completed == 5_000
        assert all(len(r) == 0 for r in pool._runs)


def test_first_error_ordering_survives_seq_log_trimming():
    """Submission-order error ordering must hold even after the log has
    been trimmed many times: a pending error's entry is kept mappable."""

    def boom(exc):
        raise exc

    with RelicPool(lanes=2, capacity=4, start_awake=True) as pool:
        for i in range(200):          # many trims at capacity 4
            pool.submit(lambda: None)
        # earliest-submitted failure (whatever lane striping/fallback
        # placed it on) must win over the later one
        pool.submit(boom, IndexError("earlier"))
        for i in range(150):          # more trims after the pending error
            pool.submit(lambda: None)
        pool.submit(boom, ValueError("later"))
        with pytest.raises(IndexError, match="earlier"):
            pool.wait()
        assert pool.stats.task_errors == 2


# ------------------------------------------------------------------- misuse

def test_assistant_threads_cannot_submit():
    errs = []
    with RelicPool(lanes=2, start_awake=True) as pool:
        def recursive():
            try:
                pool.submit(lambda: None)
            except RelicUsageError as e:
                errs.append(e)

        for _ in range(2):
            pool.submit(recursive)
        pool.wait()
    assert len(errs) == 2


def test_submit_after_shutdown_raises_and_lanes_match():
    pool = RelicPool(lanes=2).start()
    pool.shutdown()
    with pytest.raises(RelicUsageError, match="shutdown"):
        pool.submit(lambda: None)
    with pytest.raises(RelicUsageError, match="shutdown"):
        pool.submit_batch([(lambda: None, (), {})])
    with pytest.raises(RelicUsageError, match="already started"):
        pool.start()


def test_pool_rejects_nonpositive_lanes():
    with pytest.raises(ValueError, match="lanes"):
        RelicPool(lanes=0)


def test_convenience_names_reject_conflicting_lane_counts():
    """relic2/relic4 ARE their lane counts: an explicit conflicting
    lanes= must raise, never silently mislabel a differently-sized pool
    (BENCH rows are keyed by name). The matching count and the generic
    name stay configurable."""
    with pytest.raises(ValueError, match="fixed at lanes=4"):
        make_scheduler("relic4", lanes=2)
    assert make_scheduler("relic4", lanes=4).workers == 4   # no-op explicit
    assert make_scheduler("relic-pool", lanes=3).workers == 3


# ----------------------------------------------------------- aggregate stats

def test_stats_aggregate_and_expose_lanes():
    with RelicPool(lanes=2, start_awake=True) as pool:
        for i in range(10):
            pool.submit(lambda: None)
        pool.wait()
        assert pool.stats.submitted == 10
        assert pool.stats.completed == 10
        assert pool.stats.task_errors == 0
        assert len(pool.stats.lanes) == 2
        assert sum(s.submitted for s in pool.stats.lanes) == 10
        assert "lanes=2" in repr(pool.stats)


def test_scheduler_adapter_close_keeps_error_observable():
    sched = make_scheduler("relic2").start()
    sched.submit(lambda: 1 / 0)
    sched.close()
    assert sched.stats.task_errors == 1
    assert isinstance(sched.stats.last_error, ZeroDivisionError)


# ---------------------------------------- satellite: SpscRing.__len__ clamp

def test_ring_len_clamps_negative_observer_estimate():
    """A third (observer) thread can see a fresh _head against a stale
    _tail, making tail-head negative; len() must clamp to 0 (the pool's
    least-loaded picker and stats readers never see -1). Simulated by
    writing the counters the way the stale read would present them."""
    ring = SpscRing(8)
    for i in range(4):
        ring.push(i)
    assert len(ring) == 4
    ring._head = 5                      # observer: fresh head, stale tail
    ring._tail = 3
    assert len(ring) == 0


def test_ring_push_many_stop_bounds_the_window():
    """push_many's stop parameter pushes exactly items[start:stop] — the
    shard hand-off RelicPool uses on one shared flattened burst."""
    ring = SpscRing(16)
    items = list(range(10))
    assert ring.push_many(items, 2, 7) == 5
    assert ring.pop_many() == [2, 3, 4, 5, 6]
    assert ring.push_many(items, 7, 7) == 0     # empty window: no-op
    assert ring.push_many(items, 8) == 2        # stop=None: to the end
    assert ring.pop_many() == [8, 9]


# ------------------------------- satellite: RELIC_SPIN_PAUSE_EVERY override

def test_spin_pause_every_env_override(monkeypatch):
    monkeypatch.setenv("RELIC_SPIN_PAUSE_EVERY", "7")
    assert resolve_spin_pause_every() == 7
    rt = Relic()
    assert rt._spin_pause_every == 7
    pool = RelicPool(lanes=2)
    assert all(lane._spin_pause_every == 7 for lane in pool._lanes)
    spin = make_scheduler("spin")
    assert spin._spin_pause_every == 7
    # Re-read per instance, not frozen at import: a later change to the
    # environment is visible to the next runtime.
    monkeypatch.setenv("RELIC_SPIN_PAUSE_EVERY", "3")
    assert Relic()._spin_pause_every == 3


def test_spin_pause_every_env_unset_uses_cpu_heuristic(monkeypatch):
    monkeypatch.delenv("RELIC_SPIN_PAUSE_EVERY", raising=False)
    import os

    expected = 1 if (os.cpu_count() or 1) < 2 else 64
    assert resolve_spin_pause_every() == expected
    monkeypatch.setenv("RELIC_SPIN_PAUSE_EVERY", "")
    assert resolve_spin_pause_every() == expected


@pytest.mark.parametrize("cpus,expected", [
    # Yield-every-iteration only when producer+assistant genuinely
    # outnumber the host's contexts (1 context). A 2-context host is the
    # paper's own §VI shape (one SMT core) and must spin mostly-hot — the
    # pre-PR 6 threshold (< 2 + 1) misclassified it as oversubscribed.
    (None, 1),
    (1, 1),
    (2, 64),
    (4, 64),
])
def test_spin_cadence_pinned_per_host_context_count(monkeypatch, cpus, expected):
    import os

    monkeypatch.delenv("RELIC_SPIN_PAUSE_EVERY", raising=False)
    monkeypatch.setattr(os, "cpu_count", lambda: cpus)
    assert resolve_spin_pause_every() == expected
    assert Relic()._spin_pause_every == expected


@pytest.mark.parametrize("bad", ["0", "-3", "many", "1.5"])
def test_spin_pause_every_env_invalid_raises(monkeypatch, bad):
    monkeypatch.setenv("RELIC_SPIN_PAUSE_EVERY", bad)
    with pytest.raises(ValueError, match="RELIC_SPIN_PAUSE_EVERY"):
        resolve_spin_pause_every()


def test_spin_pause_override_still_completes_work(monkeypatch):
    """The cadence is a perf knob, never a correctness knob: an aggressive
    override must not change observable semantics."""
    monkeypatch.setenv("RELIC_SPIN_PAUSE_EVERY", "1")
    done = []
    with RelicPool(lanes=2, capacity=2, start_awake=True) as pool:
        pool.submit_batch([(done.append, (i,), {}) for i in range(50)])
        pool.wait()
    assert sorted(done) == list(range(50))


# ------------------------------- tentpole: skew resistance (dynamic balancing)

def _wedge_lane(pool, lane_idx, gate):
    """Submit a blocking task destined for ``lane_idx`` (rr cursor must be
    there) and wait until that lane's assistant has actually popped it."""
    popped = threading.Event()

    def wedge():
        popped.set()
        gate.wait()

    pool.submit(wedge)
    assert popped.wait(5), "wedge task never ran"
    deadline = time.time() + 5
    while len(pool._lanes[lane_idx]._ring) and time.time() < deadline:
        time.sleep(0.001)
    assert not len(pool._lanes[lane_idx]._ring), "wedge never drained"


def test_restripe_redeals_stuck_remainder_past_a_wedged_lane():
    """The headline re-striping behavior: a burst whose shard is stuck
    behind a wedged lane is re-dealt to the lanes with room, so
    submit_batch RETURNS while the wedge still holds (with static
    striping the sweep would spin until the wedge cleared — which in
    this test is never, before the producer's own gate.set())."""
    gate = threading.Event()
    watchdog = threading.Timer(30, gate.set)   # a regression must fail on
    watchdog.start()                           # counts, not hang the suite
    try:
        with RelicPool(lanes=2, capacity=2, start_awake=True) as pool:
            _wedge_lane(pool, 0, gate)         # rr: first submit -> lane 0
            done = []
            pool.submit_batch([(done.append, (i,), {}) for i in range(20)])
            # Re-striping delivered the whole burst despite the wedge:
            # lane 0 holds only the wedge + its ring capacity; everything
            # else was re-dealt to lane 1 (primary and handoff ring).
            lane0, lane1 = pool.stats.lanes
            assert lane0.submitted == 3, lane0.submitted
            assert lane1.submitted == 18, lane1.submitted
            gate.set()
            pool.wait()
        assert sorted(done) == list(range(20))
    finally:
        watchdog.cancel()


def test_wedged_lane_keeps_its_own_fifo_under_restriping():
    """Re-striping moves only *not-yet-pushed* remainders: tasks already
    in the wedged lane's ring stay there and run in push order, and the
    helper lane's pre-burst tasks keep their relative order too."""
    gate = threading.Event()
    events = []

    def rec(label):
        events.append((threading.current_thread().name, label))

    with RelicPool(lanes=2, capacity=2, start_awake=True) as pool:
        _wedge_lane(pool, 0, gate)
        pool.submit(rec, "l1-a")       # lane 1 (rr)
        pool.submit(rec, "l0-a")       # lane 0 ring slot 1
        pool.submit(rec, "l1-b")       # lane 1
        pool.submit(rec, "l0-b")       # lane 0 ring slot 2 (now full)
        pool.submit_batch([(rec, (f"burst-{i}",), {}) for i in range(12)])
        gate.set()
        pool.wait()
    lane0 = [lab for name, lab in events if name == "relic-pool-lane0"]
    lane1 = [lab for name, lab in events if name == "relic-pool-lane1"]
    # The wedged lane ran exactly its ring content, in FIFO order; every
    # burst task was re-dealt to lane 1 (lane 0 had no room throughout).
    assert lane0 == ["l0-a", "l0-b"]
    assert lane1.index("l1-a") < lane1.index("l1-b")
    assert len(events) == 16


def test_handoff_ring_accepts_singles_when_every_primary_is_full():
    """Single-submit fallback, rebalancing edition: when every lane's
    primary ring is full (all assistants wedged), submit() hands the task
    to a handoff ring and returns instead of busy-waiting."""
    gate = threading.Event()
    ready = [threading.Event(), threading.Event()]

    def wedge(i):
        ready[i].set()
        gate.wait()

    done = []
    with RelicPool(lanes=2, capacity=1, start_awake=True) as pool:
        pool.submit(wedge, 0)          # lane 0 assistant blocks
        pool.submit(wedge, 1)          # lane 1 assistant blocks
        assert ready[0].wait(5) and ready[1].wait(5)
        deadline = time.time() + 5
        while (any(len(lane._ring) for lane in pool._lanes)
               and time.time() < deadline):
            time.sleep(0.001)
        pool.submit(lambda: None)      # fills lane 0's 1-task ring
        pool.submit(lambda: None)      # fills lane 1's 1-task ring
        pool.submit(done.append, 99)   # every primary full -> handoff ring
        assert sum(len(lane._oring) for lane in pool._lanes) == 2  # 1 task
        gate.set()
        pool.wait()
        assert pool.stats.completed == 5
    assert done == [99]


def test_error_in_handoff_task_wins_by_submission_order():
    """A failure that rode a handoff ring is ordered by its pool-global
    submission seq like any other: earlier-submitted handoff error beats
    a later-submitted primary-ring error."""

    def boom(exc):
        raise exc

    gate = threading.Event()
    ready = [threading.Event(), threading.Event()]

    def wedge(i):
        ready[i].set()
        gate.wait()

    with RelicPool(lanes=2, capacity=1, start_awake=True) as pool:
        pool.submit(wedge, 0)
        pool.submit(wedge, 1)
        assert ready[0].wait(5) and ready[1].wait(5)
        deadline = time.time() + 5
        while (any(len(lane._ring) for lane in pool._lanes)
               and time.time() < deadline):
            time.sleep(0.001)
        pool.submit(lambda: None)                    # seq 2: fills lane 0
        pool.submit(lambda: None)                    # seq 3: fills lane 1
        pool.submit(boom, IndexError("handoff, seq 4"))   # -> handoff ring
        assert sum(len(lane._oring) for lane in pool._lanes) == 2
        gate.set()
        # Drain everything, then fail later on a primary ring: the wait()
        # must re-raise the earlier (handoff) error.
        deadline = time.time() + 10
        while pool.stats.completed < 5 and time.time() < deadline:
            time.sleep(0.001)
        pool.submit(boom, ValueError("primary, seq 5"))
        with pytest.raises(IndexError, match="handoff, seq 4"):
            pool.wait()
        assert pool.stats.task_errors == 2
        # Consumed as one unit: no stale index on any lane (PR 6 bugfix).
        for s in pool.stats.lanes:
            assert s.last_error is None
            assert s.first_error_index is None
            assert s.first_error_handoff_index is None


def test_earlier_primary_error_beats_later_handoff_error():
    """The mirror direction: an earlier-submitted primary-ring failure
    wins over a later failure that rode a handoff ring."""

    def boom(exc):
        raise exc

    gate = threading.Event()
    ready = [threading.Event(), threading.Event()]

    def wedge(i):
        ready[i].set()
        gate.wait()

    with RelicPool(lanes=2, capacity=1, start_awake=True) as pool:
        pool.submit(wedge, 0)
        pool.submit(wedge, 1)
        assert ready[0].wait(5) and ready[1].wait(5)
        deadline = time.time() + 5
        while (any(len(lane._ring) for lane in pool._lanes)
               and time.time() < deadline):
            time.sleep(0.001)
        pool.submit(boom, IndexError("primary, seq 2"))  # fills lane 0
        pool.submit(lambda: None)                        # seq 3: fills lane 1
        pool.submit(boom, ValueError("handoff, seq 4"))  # -> handoff ring
        gate.set()
        with pytest.raises(IndexError, match="primary, seq 2"):
            pool.wait()
        assert pool.stats.task_errors == 2


def test_handoff_tasks_cannot_submit():
    """§VI-A survives rebalancing: a task delivered through a handoff
    ring still runs on an assistant thread, which cannot submit."""
    gate = threading.Event()
    ready = [threading.Event(), threading.Event()]

    def wedge(i):
        ready[i].set()
        gate.wait()

    errs = []
    with RelicPool(lanes=2, capacity=1, start_awake=True) as pool:
        def recursive():
            try:
                pool.submit(lambda: None)
            except RelicUsageError as e:
                errs.append(e)

        pool.submit(wedge, 0)
        pool.submit(wedge, 1)
        assert ready[0].wait(5) and ready[1].wait(5)
        deadline = time.time() + 5
        while (any(len(lane._ring) for lane in pool._lanes)
               and time.time() < deadline):
            time.sleep(0.001)
        pool.submit(lambda: None)
        pool.submit(lambda: None)
        pool.submit(recursive)         # every primary full -> handoff ring
        assert sum(len(lane._oring) for lane in pool._lanes) == 2
        gate.set()
        pool.wait()
    assert len(errs) == 1


def test_rebalance_off_and_single_lane_skip_handoff_machinery():
    """``rebalance=False`` reproduces the static PR 5 pool (no handoff
    rings anywhere); a single-lane pool has nowhere to re-deal to and
    never pays for rebalancing regardless of the flag."""
    static = RelicPool(lanes=2, rebalance=False)
    assert not static._rebalance
    assert all(lane._oring is None for lane in static._lanes)
    single = RelicPool(lanes=1, rebalance=True)
    assert not single._rebalance
    assert single._lanes[0]._oring is None
    done = []
    with RelicPool(lanes=2, capacity=2, rebalance=False,
                   start_awake=True) as pool:
        pool.submit_batch([(done.append, (i,), {}) for i in range(50)])
        pool.wait()
    assert sorted(done) == list(range(50))


def test_handoff_seq_log_cleared_and_bounded():
    """The handoff-ring seq log obeys the same discipline as the primary
    log: trimmed between barriers, cleared by wait()."""
    with RelicPool(lanes=2, capacity=2, start_awake=True) as pool:
        # Small rings + a 1-cpu-friendly flood: primaries fill routinely,
        # so singles flow through the handoff rings too.
        for i in range(2_000):
            pool.submit(lambda: None)
        assert max(len(r) for r in pool._oruns) <= 2 * pool._trim_at
        pool.wait()
        assert pool.stats.completed == 2_000
        assert all(len(r) == 0 for r in pool._runs)
        assert all(len(r) == 0 for r in pool._oruns)


def test_free_slots_is_a_safe_push_window():
    """``SpscRing.free_slots`` is the producer-side lower bound the
    re-striper sizes its windows with: a push of that many items must
    succeed in full, and the bound only grows as the consumer drains."""
    ring = SpscRing(8)
    assert ring.free_slots() == 8
    assert ring.push_many([0, 1, 2, 3, 4, 5], 0, 6) == 6
    assert ring.free_slots() == 2
    assert ring.push_many([6, 7], 0, 2) == 2
    assert ring.free_slots() == 0
    assert len(ring.pop_many(3)) == 3
    # Still a valid lower bound even before the producer re-reads head...
    assert ring.free_slots() <= 3
    # ...and a push sized by it always lands entirely.
    room = ring.free_slots()
    assert ring.push_many(list(range(room)), 0, room) == room


def test_wait_after_error_clears_every_error_field_on_the_pool():
    """PR 6 bugfix regression: wait() raising must consume the error
    *atomically* — ``last_error`` AND both first-error indexes clear
    together, so a later wait() cannot mis-order a fresh error against a
    stale index from the previous window."""
    with RelicPool(lanes=2, capacity=4, start_awake=True) as pool:
        def boom():
            raise ValueError("window 1")
        pool.submit(lambda: None)
        pool.submit(boom)
        with pytest.raises(ValueError, match="window 1"):
            pool.wait()
        for s in pool.stats.lanes:
            assert s.last_error is None
            assert s.first_error_index is None
            assert s.first_error_handoff_index is None
        # The next window is clean: errors order among themselves only.
        pool.submit(lambda: None)
        pool.wait()
        assert pool.stats.task_errors == 1


# ------------------- satellite: interrupt-safe burst accounting (reconcile)

class _InterruptingTime:
    """Stand-in for ``relic_pool.time``: the first ``sleep`` raises (the
    KeyboardInterrupt-mid-sweep scenario); everything else passes through."""

    def __init__(self):
        self.fired = False

    def sleep(self, seconds):
        if not self.fired:
            self.fired = True
            raise KeyboardInterrupt

    def __getattr__(self, name):
        return getattr(time, name)


def test_interrupt_escaping_sweep_cannot_wedge_wait(monkeypatch):
    """A BaseException escaping the remainder sweep must leave
    ``submitted`` == tasks actually handed to rings (accounting is
    committed per push, not up front): the next wait() then terminates.
    Pre-PR 6, the whole shard was accounted before delivery, so the
    interrupt stranded submitted > pushed and wait() busy-spun forever."""
    monkeypatch.setenv("RELIC_SPIN_PAUSE_EVERY", "1")   # sweep yields ASAP
    gate = threading.Event()
    fake_time = _InterruptingTime()
    with RelicPool(lanes=2, capacity=2, rebalance=False,
                   start_awake=True) as pool:
        _wedge_lane(pool, 0, gate)
        import repro.core.relic_pool as relic_pool_mod
        monkeypatch.setattr(relic_pool_mod, "time", fake_time)
        done = []
        with pytest.raises(KeyboardInterrupt):
            # Burst of 20: lane 0's shard cannot be delivered past its
            # ring, the sweep spins (static striping) and the injected
            # interrupt unwinds out of submit_batch mid-burst.
            pool.submit_batch([(done.append, (i,), {}) for i in range(20)])
        assert fake_time.fired
        monkeypatch.setattr(relic_pool_mod, "time", time)
        gate.set()
        # The discriminating assertion: every accounted task is really in
        # a ring (or already done), so *live* completion (stats.completed
        # is a barrier-time snapshot) converges to submitted — the
        # condition wait() spins on. Pre-fix this times out: the shard was
        # accounted up front, so submitted > tasks actually pushed.
        live = lambda: sum(lane._completed for lane in pool._lanes)
        deadline = time.time() + 10
        while live() < pool.stats.submitted and time.time() < deadline:
            time.sleep(0.005)
        assert live() == pool.stats.submitted
        # The pool stays usable: wait() returns, later windows are clean.
        pool.wait()
        pool.submit(done.append, "after")
        pool.wait()
        assert "after" in done
