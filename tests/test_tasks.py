"""Paper benchmark tasks (§IV): graph kernels + JSON parse vs oracles."""

import json

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.tasks import graph, jsonparse


@pytest.fixture(scope="module")
def g():
    adj, w = graph.kronecker_graph()
    return np.asarray(adj), np.asarray(w), adj, w


def _bfs_oracle(A, src=0):
    n = A.shape[0]
    dist = -np.ones(n, np.int64)
    dist[src] = 0
    frontier = [src]
    while frontier:
        nxt = []
        for u in frontier:
            for v in np.nonzero(A[u])[0]:
                if dist[v] < 0:
                    dist[v] = dist[u] + 1
                    nxt.append(v)
        frontier = nxt
    return dist


def test_paper_input_shape(g):
    A, _, adj, _ = g
    assert A.shape == (32, 32)
    assert graph.n_edges(adj) == 157  # the paper's generated Kronecker input


def test_bfs_matches_oracle(g):
    A, _, adj, _ = g
    np.testing.assert_array_equal(np.asarray(graph.bfs(adj, 0)),
                                  _bfs_oracle(A, 0))


def test_cc_matches_reachability(g):
    A, _, adj, _ = g
    labels = np.asarray(graph.connected_components(adj))
    n = A.shape[0]
    for s in range(n):
        reach = _bfs_oracle(A, s) >= 0
        same = labels == labels[s]
        np.testing.assert_array_equal(same, reach)


def test_pagerank_properties(g):
    _, _, adj, _ = g
    pr = np.asarray(graph.pagerank(adj))
    assert (pr > 0).all() and pr.sum() <= 1.0 + 1e-5


def test_sssp_matches_dijkstra(g):
    A, W, adj, w = g
    import heapq
    n = A.shape[0]
    dist = np.full(n, np.inf)
    dist[0] = 0
    pq = [(0.0, 0)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        for v in np.nonzero(A[u])[0]:
            nd = d + W[u, v]
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (nd, v))
    got = np.asarray(graph.sssp(w, 0))
    mask = np.isfinite(dist)
    np.testing.assert_allclose(got[mask], dist[mask])
    assert (got[~mask] >= 1e8).all()


def test_triangles_match_trace(g):
    A, _, adj, _ = g
    assert float(graph.triangle_count(adj)) == np.trace(A @ A @ A) / 6


def test_bc_matches_brandes_oracle(g):
    A, _, adj, _ = g
    # plain python Brandes from source 0
    n = A.shape[0]
    import collections
    sigma = np.zeros(n); sigma[0] = 1
    dist = -np.ones(n, np.int64); dist[0] = 0
    order = [0]
    q = collections.deque([0])
    while q:
        u = q.popleft()
        for v in np.nonzero(A[u])[0]:
            if dist[v] < 0:
                dist[v] = dist[u] + 1
                q.append(v); order.append(v)
            if dist[v] == dist[u] + 1:
                sigma[v] += sigma[u]
    delta = np.zeros(n)
    for u in reversed(order):
        for v in np.nonzero(A[u])[0]:
            if dist[v] == dist[u] + 1 and sigma[v] > 0:
                delta[u] += sigma[u] / sigma[v] * (1 + delta[v])
    delta[0] = 0
    got = np.asarray(graph.betweenness_centrality(adj, 0))
    np.testing.assert_allclose(got, delta, rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=20)
def test_bfs_property_random_graphs(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 24))
    A = (rng.random((n, n)) < 0.2).astype(np.float32)
    A = np.maximum(A, A.T)
    np.fill_diagonal(A, 0)
    got = np.asarray(graph.bfs(jnp.asarray(A), 0, max_iter=n + 1))
    np.testing.assert_array_equal(got, _bfs_oracle(A, 0))


# ------------------------------------------------------------------- JSON

def test_json_widget_structural_counts():
    buf = jsonparse.to_bytes(jsonparse.WIDGET_JSON)
    s, depth, ok = jsonparse.parse_structural(buf)
    want = jsonparse.oracle_counts(jsonparse.WIDGET_JSON)
    assert int(s.sum()) == want["structural"]
    assert int(depth.max()) == want["max_depth"]
    assert bool(ok)


def test_json_detects_imbalance():
    bad = jsonparse.WIDGET_JSON[:-1]  # drop the final brace
    _, _, ok = jsonparse.parse_structural(jsonparse.to_bytes(bad))
    assert not bool(ok)


def test_json_escaped_quotes_and_braces_in_strings():
    doc = json.dumps({"a": 'he said "hi\\" {not a brace}', "b": [1, 2]})
    buf = jsonparse.to_bytes(doc)
    s, depth, ok = jsonparse.parse_structural(buf)
    want = jsonparse.oracle_counts(doc)
    assert bool(ok)
    assert int(s.sum()) == want["structural"]
    assert int(depth.max()) == want["max_depth"]


@st.composite
def json_values(draw, depth=0):
    if depth > 2:
        return draw(st.integers(-5, 5))
    return draw(st.one_of(
        st.integers(-100, 100),
        st.booleans(),
        st.text(alphabet=st.characters(codec="ascii",
                                       exclude_characters="\x00"),
                max_size=12),
        st.lists(json_values(depth=depth + 1), max_size=4),
        st.dictionaries(st.text(alphabet="abcdef", min_size=1, max_size=4),
                        json_values(depth=depth + 1), max_size=4),
    ))


@given(json_values())
@settings(deadline=None, max_examples=40)
def test_json_property_valid_docs_validate(value):
    doc = json.dumps(value)
    buf = jsonparse.to_bytes(doc)
    s, depth, ok = jsonparse.parse_structural(buf)
    want = jsonparse.oracle_counts(doc)
    assert bool(ok)
    assert int(s.sum()) == want["structural"]
