"""Cross-cutting property tests on system invariants (hypothesis)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.launch.steps import make_train_state, make_train_step
from repro.models import build_model
from repro.models.attention import attention_chunked, attention_full
from repro.models.layers import apply_rope
from repro.optim import OptConfig


@given(st.integers(0, 10_000), st.sampled_from([16, 32, 64]))
@settings(deadline=None, max_examples=20)
def test_rope_preserves_norms_and_relative_angles(seed, dh):
    """RoPE is a rotation: per-pair norms are invariant, and q·k depends only
    on relative position."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, 4, 1, dh)), jnp.float32)
    pos = jnp.asarray([[3, 7, 11, 20]], jnp.int32)
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)

    q = jnp.asarray(rng.normal(size=(1, 1, 1, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, dh)), jnp.float32)

    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.asarray([[pq]]), 10_000.0)
        kr = apply_rope(k, jnp.asarray([[pk]]), 10_000.0)
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3  # same offset
    assert abs(dot_at(9, 2) - dot_at(59, 52)) < 1e-3


@given(st.integers(0, 10_000))
@settings(deadline=None, max_examples=15)
def test_causal_attention_ignores_future(seed):
    """Output at position t is unchanged by edits to tokens > t."""
    rng = np.random.default_rng(seed)
    b, s, h, d = 1, 64, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    t = int(rng.integers(8, 48))
    k2 = k.at[:, t + 1:].set(jnp.asarray(rng.normal(size=(b, s - t - 1, h, d)),
                                         jnp.float32))
    v2 = v.at[:, t + 1:].set(jnp.asarray(rng.normal(size=(b, s - t - 1, h, d)),
                                         jnp.float32))
    for fn in (
        lambda q, k, v: attention_full(q, k, v, causal=True),
        lambda q, k, v: attention_chunked(q, k, v, causal=True, chunk_q=16,
                                          chunk_k=16, causal_skip=True),
    ):
        a = fn(q, k, v)[:, : t + 1]
        b_ = fn(q, k2, v2)[:, : t + 1]
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-5, atol=1e-5)


def test_compressed_training_tracks_uncompressed():
    """EF-compressed gradient training stays close to exact training."""
    cfg = get_config("relic_tiny", smoke=True)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                              jnp.int32),
        "mask": jnp.ones((4, 32), jnp.float32),
    }
    losses = {}
    for compress in (False, True):
        oc = OptConfig(warmup_steps=2, total_steps=30, compress_grads=compress)
        state = make_train_state(model, jax.random.PRNGKey(0), oc)
        step = jax.jit(make_train_step(model, oc))
        for _ in range(15):
            state, m = step(state, batch)
        losses[compress] = float(m["loss"])
    # both train (below ~ln(512)=6.24 init), and track each other closely
    assert losses[False] < 5.0 and losses[True] < 5.0, losses
    assert abs(losses[True] - losses[False]) < 0.25, losses


def test_grad_accum_matches_full_batch():
    """Microbatched accumulation reproduces the full-batch gradient."""
    cfg = get_config("relic_tiny", smoke=True)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
        "mask": jnp.ones((8, 32), jnp.float32),
    }
    gnorms = {}
    for ga in (1, 4):
        oc = OptConfig(warmup_steps=2, total_steps=10, grad_accum=ga)
        state = make_train_state(model, jax.random.PRNGKey(0), oc)
        step = jax.jit(make_train_step(model, oc))
        _, m = step(state, batch)
        gnorms[ga] = float(m["grad_norm"])
    assert abs(gnorms[1] - gnorms[4]) / gnorms[1] < 0.02, gnorms


def test_moe_aux_loss_balances_router():
    """Training with the aux loss must flatten expert assignment entropy."""
    cfg = get_config("llama4_maverick_400b_a17b", smoke=True)
    cfg = cfg.replace(n_layers=1)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)),
                              jnp.int32),
        "mask": jnp.ones((4, 64), jnp.float32),
    }
    oc = OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=60)
    state = make_train_state(model, jax.random.PRNGKey(0), oc)
    step = jax.jit(make_train_step(model, oc))
    aux0 = None
    for i in range(30):
        state, m = step(state, batch)
        if aux0 is None:
            aux0 = float(m["aux"])
    assert float(m["aux"]) <= aux0 * 1.05, (aux0, float(m["aux"]))
