"""Stream-network recovery: farm quarantine/respawn, exact loss accounting,
and advisory stage supervision (PR 10).

The PR 8 supervise/quarantine/respawn discipline lifted up a stratum. The
hard invariants under test:

* a dead farm worker's lost in-flight set is computed EXACTLY as
  dealt-minus-released (the per-worker dealt ledger), and surfaced as
  :class:`StageFailedError.lost_tags` — the regression half: before
  PR 10 the collector raised a bare ``RelicDeadError`` whose count was
  the *stash* size, so callers could not re-submit the lost work;
* ``Farm(respawn=True)`` replaces the dead worker with a fresh stage +
  fresh rings (1P1C preserved) and re-emits exactly the lost tags,
  exactly once: output complete and in order, ``reemitted_tags`` ==
  measured ``lost_tags``, dedup ledger untouched (``dup_dropped == 0``);
* ``Pipeline(supervisor=)`` stays advisory: stalled/straggler *flags*,
  never an exception, and the bounded waits still decide "dead".

Kills are injected deterministically via
:class:`repro.runtime.chaos.StageKillSwitch` (the stream-loop analogue
of the Relic ``KillSwitch``): the loop dies by ``SystemExit`` with the
popped item unprocessed, exactly the "assistant died" escape class.
"""

import threading

import pytest

from repro.core.relic import RelicDeadError
from repro.runtime.chaos import StageKillSwitch
from repro.runtime.fault import LaneSupervisor
from repro.stream import (Farm, Pipeline, Stage, StageFailedError,
                          StreamUsageError, WorkerFailure)

N = 120


def _cause_chain(err):
    seen = []
    while err is not None:
        seen.append(err)
        err = err.__cause__
    return seen


def _find(err, cls):
    for e in _cause_chain(err):
        if isinstance(e, cls):
            return e
    return None


# ---------------------------------------------------------------- fail-stop


def test_dead_worker_error_carries_lost_tags():
    """Satellite regression: the death report must say *which* in-flight
    items died with the worker, not just how many. (Pre-PR-10 this
    surfaced as a bare RelicDeadError counting the collector's stash —
    callers could not re-submit the lost work.)"""
    gate = threading.Event()

    def work(x):
        gate.wait(5)
        return x + 1

    f = Farm(work, workers=2, capacity=8)
    ks = StageKillSwitch(after_items=2).arm(f._workers[0])
    with pytest.raises(RelicDeadError) as ei:
        with Pipeline([f]) as pipe:
            threading.Timer(0.2, gate.set).start()
            pipe.run(range(N))
    sfe = _find(ei.value, StageFailedError)
    assert sfe is not None, f"no StageFailedError in {_cause_chain(ei.value)}"
    assert ks.fired
    assert sfe.stage == f._workers[0].name
    assert len(sfe.lost_tags) >= 1
    assert sfe.lost == len(sfe.lost_tags)           # count == tag set
    assert list(sfe.lost_tags) == sorted(set(sfe.lost_tags))
    assert all(0 <= t < N for t in sfe.lost_tags)
    # The dead worker's ledger is the error's tag set, exactly.
    assert tuple(sfe.lost_tags) == f.failures[0].lost_tags
    assert f.failures[0].respawned is False


def test_dead_worker_lost_tags_bounded_by_window():
    """The lost set is bounded by the worker's in-flight window (its input
    ring capacity + the one popped item) — dealt-minus-released can never
    blame more than was actually outstanding."""
    gate = threading.Event()
    cap = 4

    def work(x):
        gate.wait(5)
        return x

    f = Farm(work, workers=2, capacity=cap)
    StageKillSwitch(after_items=0).arm(f._workers[1])
    with pytest.raises(RelicDeadError) as ei:
        with Pipeline([f]) as pipe:
            threading.Timer(0.2, gate.set).start()
            pipe.run(range(N))
    sfe = _find(ei.value, StageFailedError)
    assert sfe is not None
    assert 1 <= len(sfe.lost_tags) <= cap + 1


# ------------------------------------------------------------------ respawn


@pytest.mark.parametrize("workers,kill_at,after", [(2, 1, 3), (4, 2, 0)])
def test_respawn_completes_exactly_once(workers, kill_at, after):
    """The acceptance invariant: kill a worker mid-stream with a backlog
    in flight; the farm must finish with every item exactly once, the
    re-emitted tags equal to the measured lost tags, and the ledger
    balanced."""
    gate = threading.Event()

    def work(x):
        gate.wait(5)
        return x * x

    f = Farm(work, workers=workers, respawn=True, capacity=8)
    ks = StageKillSwitch(after_items=after).arm(f._workers[kill_at])
    with Pipeline([f]) as pipe:
        threading.Timer(0.2, gate.set).start()
        out = pipe.run(range(N))
    assert out == [x * x for x in range(N)]
    assert ks.fired
    assert len(f.failures) == 1
    failure = f.failures[0]
    assert isinstance(failure, WorkerFailure)
    assert failure.worker_index == kill_at
    assert failure.respawned and failure.reemitted
    assert failure.recovered_s >= failure.detected_s
    # exactly-once: replayed tags == lost tags, nothing dropped as dup
    assert sorted(f.reemitted_tags) == list(failure.lost_tags)
    assert f.dup_dropped == 0
    assert f.lost_tags == failure.lost_tags
    # ledger balanced: every item entered and left the farm once
    assert f.items_in == N and f.items_out == N
    # the fresh worker actually took over the slot
    assert f._workers[kill_at].name.endswith("r1")
    assert f._workers[kill_at].error() is None


def test_respawn_unordered_completes():
    f = Farm(lambda x: -x, workers=3, respawn=True, ordered=False)
    StageKillSwitch(after_items=1).arm(f._workers[2])
    with Pipeline([f]) as pipe:
        out = pipe.run(range(N))
    assert sorted(out) == sorted(-x for x in range(N))
    assert len(f.failures) == 1
    assert f.dup_dropped == 0


def test_respawn_two_workers_die():
    """Two independent kills in one run: both slots recover, stream
    completes, the two failures' lost sets are disjoint."""
    gate = threading.Event()

    def work(x):
        gate.wait(5)
        return x + 7

    f = Farm(work, workers=3, respawn=True, capacity=4)
    StageKillSwitch(after_items=1).arm(f._workers[0])
    StageKillSwitch(after_items=2).arm(f._workers[2])
    with Pipeline([f]) as pipe:
        threading.Timer(0.2, gate.set).start()
        out = pipe.run(range(N))
    assert out == [x + 7 for x in range(N)]
    assert len(f.failures) == 2
    tags = [set(fl.lost_tags) for fl in f.failures]
    assert tags[0].isdisjoint(tags[1])
    assert sorted(f.reemitted_tags) == sorted(tags[0] | tags[1])
    assert f.dup_dropped == 0


def test_respawned_worker_can_die_again():
    """A respawned slot is a first-class worker: kill the replacement too
    and the farm still completes (generation counter keeps ring/stage
    names unique)."""
    gate = threading.Event()

    def work(x):
        gate.wait(5)
        return x * 2

    f = Farm(work, workers=2, respawn=True, capacity=4)
    StageKillSwitch(after_items=1).arm(f._workers[1])

    killed_second = []

    def arm_replacement():
        # once the first respawn happened, arm the fresh worker too
        for _ in range(2000):
            if f._gen[1] == 1 and f._workers[1].name.endswith("r1"):
                StageKillSwitch(after_items=1).arm(f._workers[1])
                killed_second.append(True)
                return
            threading.Event().wait(0.001)

    t = threading.Thread(target=arm_replacement)
    with Pipeline([f]) as pipe:
        t.start()
        threading.Timer(0.2, gate.set).start()
        out = pipe.run(range(N))
    t.join()
    assert out == [x * 2 for x in range(N)]
    assert f.dup_dropped == 0
    if killed_second and len(f.failures) == 2:
        assert f._gen[1] == 2
        assert sorted(f.reemitted_tags) == sorted(
            t for fl in f.failures for t in fl.lost_tags)


def test_take_worker_failures_drains():
    f = Farm(lambda x: x, workers=2, respawn=True)
    StageKillSwitch(after_items=0).arm(f._workers[0])
    with Pipeline([f]) as pipe:
        out = pipe.run(range(30))
    assert out == list(range(30))
    took = f.take_worker_failures()
    assert len(took) == 1
    assert f.failures == ()
    assert f.take_worker_failures() == ()


def test_respawn_false_by_default():
    f = Farm(lambda x: x, workers=2)
    assert f._respawn is False
    assert "respawn=False" in repr(f)
    assert f.stats()["respawn"] is False


# -------------------------------------------------------- stage kill switch


def test_stage_kill_switch_validates():
    with pytest.raises(ValueError):
        StageKillSwitch(after_items=-1)


def test_stage_kill_switch_on_plain_pipeline_stage():
    """A killed pipeline stage (not in a farm) is the fail-stop case: the
    driver's bounded wait surfaces RelicDeadError with the stage's
    SystemExit as the chained cause."""
    st = Stage(lambda x: x, name="victim")
    ks = StageKillSwitch(after_items=3).arm(st)
    with pytest.raises(RelicDeadError) as ei:
        with Pipeline([st]) as pipe:
            pipe.run(range(20))
    assert ks.fired and ks.killed_after == 3
    assert _find(ei.value, SystemExit) is not None


# ------------------------------------------------------- stage supervision


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_pipeline_supervisor_flags_stalled_stage():
    clock = FakeClock()
    sup = LaneSupervisor(n_lanes=2, heartbeat_s=0.1, clock=clock)
    gate = threading.Event()

    def wedge(x):
        gate.wait(5)
        return x

    pipe = Pipeline([lambda x: x + 1, wedge], supervisor=sup)
    try:
        with pipe:
            assert sup.names == ["<lambda>", "wedge"]
            pipe.put(0)
            flagged = False
            for _ in range(400):
                clock.t += 0.15
                pipe.check_stages()
                if pipe.stalled_stages():
                    flagged = True
                    break
                threading.Event().wait(0.005)
            assert flagged
            assert pipe.stalled_stages() == ["wedge"]
            assert sup.stalled_names() == ["wedge"]
            gate.set()
            assert pipe.get() == 1
            # progress clears the flag on the next sweeps
            for _ in range(400):
                clock.t += 0.15
                pipe.check_stages()
                if not pipe.stalled_stages():
                    break
                threading.Event().wait(0.005)
            assert pipe.stalled_stages() == []
    finally:
        gate.set()


def test_pipeline_supervisor_advisory_only():
    """A stalled flag never raises, and an unsupervised pipeline reports
    empty flags from the same accessors."""
    pipe = Pipeline([lambda x: x])
    with pipe:
        assert pipe.check_stages() is False
        assert pipe.stalled_stages() == []
        assert pipe.straggler_stages() == []
        assert pipe.run([1, 2, 3]) == [1, 2, 3]


def test_pipeline_supervisor_size_mismatch_raises():
    with pytest.raises(StreamUsageError):
        Pipeline([lambda x: x], supervisor=LaneSupervisor(n_lanes=3))


def test_lane_supervisor_names():
    sup = LaneSupervisor(n_lanes=2, names=["a", "b"])
    assert sup.names == ["a", "b"]
    assert sup.stalled_names() == []
    with pytest.raises(ValueError):
        LaneSupervisor(n_lanes=2, names=["only-one"])
    unnamed = LaneSupervisor(n_lanes=1)
    assert unnamed._name(0) == "lane0"


def test_pipeline_supervisor_does_not_rename_existing():
    sup = LaneSupervisor(n_lanes=1, names=["custom"])
    with Pipeline([lambda x: x], supervisor=sup) as pipe:
        assert sup.names == ["custom"]
        assert pipe.run([1]) == [1]


# ------------------------------------------------------------- invariants


def test_no_lock_no_queue_in_recovery_path():
    """The recovery machinery must not smuggle a lock or MPMC queue onto
    the item path — same structural pin as tests/test_stream.py."""
    import inspect

    import repro.stream.farm as farm_mod
    src = inspect.getsource(farm_mod)
    assert "Lock(" not in src
    assert "queue.Queue" not in src


def test_supervise_off_reproduces_unbounded_loops(monkeypatch):
    """RELIC_SUPERVISE=0 must still produce probe-free stages (the
    pre-supervision loops) after the recovery rework."""
    monkeypatch.setenv("RELIC_SUPERVISE", "0")
    f = Farm(lambda x: x, workers=2)
    assert f._emitter._probe_every == 0
    assert f._collector._probe_every == 0
    with Pipeline([f]) as pipe:
        assert pipe.run(range(50)) == list(range(50))
