"""Crash-consistent checkpointing (PR 10): torn-write tolerance, checksum
verification, fallback restore, and the train.py --resume path end to end.

The failure model: a save can die at any point between "serialize starts"
and "os.replace publishes" (process kill, OOM, disk full). The invariants
under test:

* ``latest_step()``/``restore()`` never trust a manifest that does not
  parse and validate — a torn ``manifest.json`` is skipped with a warning
  (the pre-PR-10 regression: ``latest_step`` accepted any dir where the
  manifest merely *existed*, so a truncated one made ``restore`` raise
  ``JSONDecodeError`` instead of falling back);
* every published entry carries a CRC32 over its stored bytes; restore
  verifies and falls back to the next-latest valid step, quarantining the
  corrupt dir as ``.corrupt`` (kept, never deleted);
* ``_gc`` never collects the last manifest-valid checkpoint, even when
  ``keep`` says it should;
* a chaos kill mid-save (``FsFaultInjector``, every crash point, mid-file
  tears included) always leaves the directory restorable to a complete,
  checksum-valid earlier step — swept by hypothesis;
* ``train.py --resume`` recovers from a mid-save kill: resumes from the
  last *published* step with bit-identical state (the flag had zero test
  coverage before this PR).
"""

import json
import tempfile
import warnings
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointCorruptError, CheckpointManager
from repro.checkpoint.manager import FORMAT_VERSION, MANIFEST
from repro.runtime.chaos import FsCrash, FsFaultInjector
from repro.runtime.config import resolve_checkpoint_config


def _state(scale=1):
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) * scale,
        "b": jnp.full((5,), 0.5 * scale, dtype=jnp.bfloat16),
        "n": jnp.asarray(scale, dtype=jnp.int32),
    }


def _assert_state_equal(a, b):
    fa, _ = jax.tree_util.tree_flatten(a)
    fb, _ = jax.tree_util.tree_flatten(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        xa, ya = np.asarray(x), np.asarray(y)
        assert xa.dtype == ya.dtype
        assert xa.tobytes() == ya.tobytes()      # bit-identical


def _quiet_mgr(d, **kw):
    kw.setdefault("async_", False)
    return CheckpointManager(d, **kw)


# -------------------------------------------------- satellite: torn manifest


def test_latest_step_skips_torn_manifest(tmp_path):
    """Regression: a truncated manifest.json must make latest_step() skip
    that dir (with a warning), not nominate it for restore() to crash on.
    (Pre-fix this asserted the buggy behaviour: latest_step() == 2 and
    restore() raising JSONDecodeError.)"""
    mgr = _quiet_mgr(tmp_path, keep=0)
    mgr.save(_state(1), 1)
    mgr.save(_state(2), 2)
    man = tmp_path / "step_00000002" / MANIFEST
    man.write_text(man.read_text()[:25])        # torn mid-write
    fresh = _quiet_mgr(tmp_path)
    with pytest.warns(RuntimeWarning, match="skipping"):
        assert fresh.latest_step() == 1
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        restored, step = fresh.restore(_state(0))
    assert step == 1
    _assert_state_equal(restored, _state(1))


def test_latest_step_skips_unknown_future_format(tmp_path):
    mgr = _quiet_mgr(tmp_path)
    mgr.save(_state(1), 1)
    mgr.save(_state(2), 2)
    man = tmp_path / "step_00000002" / MANIFEST
    doc = json.loads(man.read_text())
    doc["format_version"] = FORMAT_VERSION + 97
    man.write_text(json.dumps(doc))
    with pytest.warns(RuntimeWarning, match="format_version"):
        assert _quiet_mgr(tmp_path).latest_step() == 1


def test_manifest_carries_format_version_and_crc(tmp_path):
    _quiet_mgr(tmp_path).save(_state(3), 7)
    doc = json.loads((tmp_path / "step_00000007" / MANIFEST).read_text())
    assert doc["format_version"] == FORMAT_VERSION
    assert doc["checksum"] is True
    for ent in doc["entries"].values():
        assert isinstance(ent["crc32"], int)
        assert ent["nbytes"] > 0


# ------------------------------------------------------- checksum + fallback


def test_bitflip_fails_explicit_restore_then_falls_back(tmp_path):
    mgr = _quiet_mgr(tmp_path, keep=0)
    mgr.save(_state(1), 1)
    mgr.save(_state(2), 2)
    ef = tmp_path / "step_00000002" / "w.npy"
    raw = bytearray(ef.read_bytes())
    raw[-3] ^= 0xFF                              # flip payload bits
    ef.write_bytes(bytes(raw))
    fresh = _quiet_mgr(tmp_path)
    # explicit step: the caller asked for exactly this state — raise
    with pytest.raises(CheckpointCorruptError, match="checksum mismatch"):
        fresh.restore(_state(0), step=2)
    # latest-wins: quarantine + fall back
    with pytest.warns(RuntimeWarning, match="quarantined"):
        restored, step = fresh.restore(_state(0))
    assert step == 1
    _assert_state_equal(restored, _state(1))
    corrupt = list(tmp_path.glob("step_00000002.corrupt*"))
    assert len(corrupt) == 1                     # kept for post-mortem
    assert not (tmp_path / "step_00000002").exists()


def test_truncated_entry_file_falls_back(tmp_path):
    mgr = _quiet_mgr(tmp_path, keep=0)
    mgr.save(_state(1), 1)
    mgr.save(_state(2), 2)
    ef = tmp_path / "step_00000002" / "b.npy"
    ef.write_bytes(ef.read_bytes()[:10])         # mid-file kill
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        restored, step = _quiet_mgr(tmp_path).restore(_state(0))
    assert step == 1
    _assert_state_equal(restored, _state(1))


def test_all_corrupt_raises_filenotfound(tmp_path):
    mgr = _quiet_mgr(tmp_path)
    mgr.save(_state(1), 1)
    ef = tmp_path / "step_00000001" / "w.npy"
    raw = bytearray(ef.read_bytes())
    raw[-1] ^= 0x01
    ef.write_bytes(bytes(raw))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(FileNotFoundError, match="quarantined"):
            _quiet_mgr(tmp_path).restore(_state(0))


def test_checksum_off_writes_v1_compatible_entries(tmp_path):
    mgr = _quiet_mgr(tmp_path, checksum=False)
    mgr.save(_state(4), 3)
    doc = json.loads((tmp_path / "step_00000003" / MANIFEST).read_text())
    assert all("crc32" not in e for e in doc["entries"].values())
    # a checksum-on manager still restores it (entries just unverified)
    restored, step = _quiet_mgr(tmp_path).restore(_state(0))
    assert step == 3
    _assert_state_equal(restored, _state(4))


def test_checkpoint_config_env(monkeypatch):
    monkeypatch.delenv("RELIC_CKPT_CHECKSUM", raising=False)
    assert resolve_checkpoint_config().checksum is True
    monkeypatch.setenv("RELIC_CKPT_CHECKSUM", "0")
    assert resolve_checkpoint_config().checksum is False
    assert resolve_checkpoint_config(checksum=True).checksum is True
    monkeypatch.setenv("RELIC_CKPT_CHECKSUM", "maybe")
    with pytest.raises(ValueError):
        resolve_checkpoint_config()


def test_restore_is_bit_identical(tmp_path):
    st8 = _state(13)
    _quiet_mgr(tmp_path).save(st8, 11)
    restored, step = _quiet_mgr(tmp_path).restore(_state(0))
    assert step == 11
    _assert_state_equal(restored, st8)


# ------------------------------------------------------------ gc protection


def test_gc_never_collects_last_valid_checkpoint(tmp_path):
    """keep=1 with the newest checkpoint torn: retention must spare the
    newest *valid* dir below the keep window instead of deleting it."""
    mgr = _quiet_mgr(tmp_path, keep=1)
    mgr.save(_state(1), 1)
    torn = tmp_path / "step_00000002"
    torn.mkdir()
    (torn / MANIFEST).write_text('{"step": 2, "ent')    # torn
    mgr._gc()
    assert (tmp_path / "step_00000001").exists()        # spared
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert _quiet_mgr(tmp_path).latest_step() == 1


def test_gc_ignores_quarantined_dirs(tmp_path):
    mgr = _quiet_mgr(tmp_path, keep=2)
    for s in (1, 2, 3):
        mgr.save(_state(s), s)
    (tmp_path / "step_00000009.corrupt").mkdir()
    mgr._gc()
    assert (tmp_path / "step_00000009.corrupt").exists()
    assert not (tmp_path / "step_00000001").exists()    # normal retention
    assert (tmp_path / "step_00000003").exists()


# --------------------------------------------------- chaos crash-point sweep


def test_fs_fault_injector_validates():
    with pytest.raises(ValueError):
        FsFaultInjector(crash_point="nonsense")
    with pytest.raises(ValueError):
        FsFaultInjector(at_save=-1)
    with pytest.raises(ValueError):
        FsFaultInjector(torn_bytes=-2)


@given(
    point=st.sampled_from(FsFaultInjector.POINTS),
    at_save=st.integers(0, 2),
    at_index=st.integers(0, 2),
    torn=st.sampled_from([None, 0, 7, 40]),
)
@settings(deadline=None, max_examples=20)
def test_crash_point_sweep_always_restores_valid_step(point, at_save,
                                                      at_index, torn):
    """Hypothesis sweep of the satellite: kill a save at every
    serialize/publish boundary (and mid-file) across a sequence of saves;
    restore must always return a complete, checksum-valid earlier step —
    never a torn one."""
    with tempfile.TemporaryDirectory() as td:
        mgr = _quiet_mgr(td, keep=0)
        FsFaultInjector(crash_point=point, at_save=at_save,
                        at_index=at_index, torn_bytes=torn).arm(mgr)
        published = 0
        try:
            for step in (1, 2, 3, 4):
                mgr.save(_state(step), step)
                published = step
        except FsCrash:
            pass
        assert published == at_save      # saves before the crash landed
        fresh = _quiet_mgr(td)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            if published == 0:
                assert fresh.latest_step() is None
                with pytest.raises(FileNotFoundError):
                    fresh.restore(_state(0))
            else:
                assert fresh.latest_step() == published
                restored, got = fresh.restore(_state(0))
                assert got == published
                _assert_state_equal(restored, _state(published))


# ----------------------------------------------- satellite: train.py resume


@pytest.mark.filterwarnings("ignore::RuntimeWarning")
def test_train_resume_after_mid_save_kill(tmp_path, capsys):
    """End to end on relic_tiny: train with periodic checkpoints, chaos-kill
    the run mid-save, then --resume — the rerun must pick up from the last
    *published* step with state bit-identical to that checkpoint."""
    from repro.launch.train import main as train_main

    ckpt = str(tmp_path / "ckpt")
    common = ["--arch", "relic_tiny", "--smoke", "--batch", "4",
              "--seq", "32", "--log-every", "50",
              "--ckpt", ckpt, "--ckpt-every", "5"]
    # Run 1: crash the second save (step 10) mid-manifest.
    with pytest.raises(FsCrash):
        train_main(common + ["--steps", "20", "--ckpt-chaos", "manifest:1"])
    dirs = sorted(p.name for p in Path(ckpt).glob("step_*"))
    assert "step_00000005" in dirs               # published before the kill
    assert "step_00000010" not in dirs           # the torn save never lands
    # The surviving checkpoint is the resume source, bit-for-bit: what a
    # fresh manager restores equals the published files exactly.
    mgr = CheckpointManager(ckpt, async_=False)
    assert mgr.latest_step() == 5
    doc = json.loads((Path(ckpt) / "step_00000005" / MANIFEST).read_text())
    for key, ent in doc["entries"].items():
        arr = np.load(Path(ckpt) / "step_00000005" / ent["file"])
        import zlib
        assert zlib.crc32(np.ascontiguousarray(arr).tobytes()) == ent["crc32"]
    # Run 2: resume. Step counter restarts from the published step and the
    # run completes to a finite loss.
    loss = train_main(common + ["--steps", "20", "--resume"])
    out = capsys.readouterr().out
    assert "resumed from step 5" in out
    assert np.isfinite(loss)
