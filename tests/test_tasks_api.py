"""Façade conformance suite for ``repro.tasks.api``, run against every
registered substrate: scope-exit barrier, future results, per-scope error
aggregation (both errors survive, not last-error-wins), grain chunking
edge cases, and producer-participates execution. Mirrors the SPI suite in
``tests/test_schedulers_conformance.py`` one layer up."""

import threading
import time

import pytest

from repro.core.schedulers import (USAGE_ERRORS, available_schedulers,
                                   make_scheduler)
from repro.tasks.api import (TaskCancelledError, TaskGraph, TaskGroupError,
                             TaskScope, map_reduce, parallel_for)

ALL = available_schedulers()


# ----------------------------------------------------------------- TaskScope

@pytest.mark.parametrize("name", ALL)
def test_scope_exit_is_the_barrier(name):
    done = []
    with TaskScope(name) as scope:
        for i in range(50):
            scope.submit(lambda i=i: (time.sleep(0.0001), done.append(i)))
    assert sorted(done) == list(range(50))


@pytest.mark.parametrize("name", ALL)
def test_handles_carry_results(name):
    with TaskScope(name) as scope:
        hs = [scope.submit(lambda i=i: i * i) for i in range(20)]
        scope.barrier()
        assert all(h.done() for h in hs)
        assert [h.result() for h in hs] == [i * i for i in range(20)]
        assert all(h.exception() is None for h in hs)


@pytest.mark.parametrize("name", ALL)
def test_handle_result_blocks_until_done(name):
    with TaskScope(name) as scope:
        h = scope.submit(lambda: (time.sleep(0.02), "slow")[1])
        # no barrier: result() must synchronize on its own
        assert h.result(timeout=5) == "slow"


@pytest.mark.parametrize("name", ALL)
def test_two_failing_tasks_surface_both_errors(name):
    with TaskScope(name) as scope:
        scope.submit(lambda: (_ for _ in ()).throw(KeyError("first")))
        scope.submit(lambda: 1 / 0)
        with pytest.raises(TaskGroupError) as ei:
            scope.barrier()
    kinds = {type(e) for e in ei.value.exceptions}
    assert kinds == {KeyError, ZeroDivisionError}
    assert len(ei.value.exceptions) == 2


@pytest.mark.parametrize("name", ALL)
def test_single_error_reraises_bare(name):
    with TaskScope(name) as scope:
        scope.submit(lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            scope.barrier()
        # cleared: the scope stays usable
        h = scope.submit(lambda: "after")
        scope.barrier()
        assert h.result() == "after"


@pytest.mark.parametrize("name", ALL)
def test_handle_result_after_failed_sibling(name):
    with TaskScope(name) as scope:
        bad = scope.submit(lambda: 1 / 0)
        good = scope.submit(lambda: 41 + 1)
        assert good.result(timeout=5) == 42   # sibling failure doesn't poison
        with pytest.raises(ZeroDivisionError):
            bad.result(timeout=5)
        with pytest.raises(ZeroDivisionError):
            scope.barrier()                   # aggregate still fires
        assert isinstance(bad.exception(), ZeroDivisionError)


@pytest.mark.parametrize("name", ALL)
def test_scope_exit_raises_aggregate(name):
    with pytest.raises(TaskGroupError):
        with TaskScope(name) as scope:
            scope.submit(lambda: 1 / 0)
            scope.submit(lambda: (_ for _ in ()).throw(OSError("disk")))


@pytest.mark.parametrize("name", ALL)
def test_body_exception_wins_but_tasks_drain(name):
    done = []
    with pytest.raises(RuntimeError, match="body"):
        with TaskScope(name) as scope:
            for i in range(20):
                scope.submit(lambda i=i: (time.sleep(0.0005), done.append(i)))
            raise RuntimeError("body failed")
    assert sorted(done) == list(range(20))    # drained despite body error


@pytest.mark.parametrize("name", ALL)
def test_borrowed_scheduler_is_not_closed(name):
    sched = make_scheduler(name).start()
    try:
        with TaskScope(sched) as scope:
            h = scope.submit(lambda: "in-scope")
        assert h.result() == "in-scope"
        # still running: the raw SPI remains usable after the scope closes
        done = []
        sched.submit(done.append, "raw")
        sched.wait()
        assert done == ["raw"]
    finally:
        sched.close()


@pytest.mark.parametrize("name", ALL)
def test_adopted_instance_is_closed_with_scope(name):
    sched = make_scheduler(name)               # not started: scope adopts it
    with TaskScope(sched) as scope:
        scope.submit(lambda: None)
    with pytest.raises(USAGE_ERRORS):
        sched.submit(lambda: None)             # closed with the scope


def test_scope_kwargs_reach_the_registry():
    with TaskScope("relic", capacity=4) as scope:
        for i in range(32):                    # > capacity: backpressure path
            scope.submit(time.sleep, 0.0001)
    with pytest.raises(TypeError, match="kwargs"):
        TaskScope(make_scheduler("serial"), capacity=4)


@pytest.mark.parametrize("name", ALL)
def test_submit_after_close_raises(name):
    scope = TaskScope(name)
    scope.close()
    with pytest.raises(USAGE_ERRORS):
        scope.submit(lambda: None)


# -------------------------------------------------------------- parallel_for

@pytest.mark.parametrize("name", ALL)
def test_parallel_for_nondivisible_chunking_covers_range(name):
    seen = []
    lock = threading.Lock()

    def body(i):
        with lock:
            seen.append(i)

    with TaskScope(name) as scope:
        parallel_for(scope, 10, body, grain=3)   # chunks 3+3+3+1
        assert scope.stats.submitted == 3        # final chunk ran inline
    assert sorted(seen) == list(range(10))


@pytest.mark.parametrize("name", ALL)
def test_parallel_for_n_zero_is_noop(name):
    with TaskScope(name) as scope:
        parallel_for(scope, 0, lambda i: pytest.fail("body ran"), grain=4)
        assert scope.stats.submitted == 0


@pytest.mark.parametrize("name", ALL)
def test_parallel_for_n_below_grain_runs_inline(name):
    idents = []
    with TaskScope(name) as scope:
        parallel_for(scope, 3, lambda i: idents.append(threading.get_ident()),
                     grain=100)
        assert scope.stats.submitted == 0        # zero submissions
    assert idents == [threading.get_ident()] * 3  # all on the caller


@pytest.mark.parametrize("name", ALL)
def test_parallel_for_producer_participates(name):
    """The calling thread runs the final chunk itself (paper §VI)."""
    ident_by_index = {}
    lock = threading.Lock()

    def body(i):
        with lock:
            ident_by_index[i] = threading.get_ident()

    with TaskScope(name) as scope:
        parallel_for(scope, 8, body, grain=2)
    main = threading.get_ident()
    assert ident_by_index[6] == main and ident_by_index[7] == main


@pytest.mark.parametrize("name", ALL)
def test_parallel_for_default_grain_matches_advertised_workers(name):
    """grain=None splits into (workers + 1) near-equal shares — producer
    participates (paper §VI), generalized past the SMT pair: workers=1
    keeps the historical split-in-two, a 4-lane pool splits in five, and
    serial (workers=0) runs the whole loop inline with zero submissions."""
    import math

    n = 9
    with TaskScope(name) as scope:
        parallel_for(scope, n, lambda i: None)
        grain = max(1, math.ceil(n / (scope.workers + 1)))
        chunks = math.ceil(n / grain)
        assert scope.stats.submitted == chunks - 1   # last chunk runs inline
        assert scope.workers == getattr(scope.scheduler, "workers", 1)


@pytest.mark.parametrize("name", ALL)
def test_parallel_for_aggregates_chunk_errors(name):
    def body(i):
        if i in (1, 7):                          # distinct chunks at grain=2
            raise ValueError(f"bad index {i}")

    with TaskScope(name) as scope:
        with pytest.raises(TaskGroupError) as ei:
            parallel_for(scope, 8, body, grain=2)
        assert {str(e) for e in ei.value.exceptions} == \
            {"bad index 1", "bad index 7"}


@pytest.mark.parametrize("name", ALL)
def test_parallel_for_does_not_adopt_sibling_errors(name):
    """A failed sibling task must not be misattributed to the loop: the
    loop completes cleanly and the sibling's error still fires at the
    scope barrier."""
    seen = []
    lock = threading.Lock()
    with TaskScope(name) as scope:
        scope.submit(lambda: 1 / 0)              # unrelated flaky sibling
        parallel_for(scope, 6,
                     lambda i: (lock.acquire(), seen.append(i),
                                lock.release()), grain=2)  # must NOT raise
        assert sorted(seen) == list(range(6))
        with pytest.raises(ZeroDivisionError):
            scope.barrier()                      # sibling error kept for here


@pytest.mark.parametrize("name", ALL)
def test_parallel_for_errors_do_not_rearm_the_barrier(name):
    """Loop errors raised by parallel_for are consumed: the next barrier
    does not raise them again."""
    with TaskScope(name) as scope:
        with pytest.raises(ValueError):
            parallel_for(scope, 4, lambda i: (_ for _ in ()).throw(
                ValueError("boom")), grain=4)
        scope.barrier()                          # nothing left to raise


@pytest.mark.parametrize("name", ALL)
def test_parallel_for_completes_with_parked_worker(name):
    """Advisory sleep_hint must not deadlock the loop's join (the SPI
    wait() rule, held by the façade too)."""
    seen = []
    lock = threading.Lock()
    with TaskScope(name) as scope:
        scope.sleep_hint()
        time.sleep(0.02)  # let the worker actually park
        parallel_for(scope, 8,
                     lambda i: (lock.acquire(), seen.append(i),
                                lock.release()), grain=2)
    assert sorted(seen) == list(range(8))


def test_parallel_for_rejects_bad_arguments():
    with TaskScope("serial") as scope:
        with pytest.raises(ValueError, match="non-negative"):
            parallel_for(scope, -1, lambda i: None)
        with pytest.raises(ValueError, match="grain"):
            parallel_for(scope, 4, lambda i: None, grain=0)


# ---------------------------------------------------------------- map_reduce

@pytest.mark.parametrize("name", ALL)
def test_map_reduce_sum_of_squares(name):
    with TaskScope(name) as scope:
        got = map_reduce(scope, 100, lambda i: i * i, lambda a, b: a + b,
                         grain=7)
    assert got == sum(i * i for i in range(100))


@pytest.mark.parametrize("name", ALL)
def test_map_reduce_with_init_and_empty_range(name):
    with TaskScope(name) as scope:
        assert map_reduce(scope, 10, lambda i: i, lambda a, b: a + b,
                          init=1000, grain=4) == 1000 + sum(range(10))
        assert map_reduce(scope, 0, lambda i: i, lambda a, b: a + b,
                          init=5) == 5
        with pytest.raises(ValueError, match="init"):
            map_reduce(scope, 0, lambda i: i, lambda a, b: a + b)


@pytest.mark.parametrize("name", ALL)
def test_map_reduce_deterministic_chunk_order(name):
    """Non-commutative reduce: chunk-order combine keeps it deterministic."""
    with TaskScope(name) as scope:
        got = map_reduce(scope, 26, lambda i: chr(ord("a") + i),
                         lambda a, b: a + b, grain=5)
    assert got == "abcdefghijklmnopqrstuvwxyz"


# ----------------------------------------------------------------- TaskGraph

@pytest.mark.parametrize("name", ALL)
def test_taskgraph_diamond_respects_dependencies(name):
    order = []
    lock = threading.Lock()

    def mark(label, *deps):
        with lock:
            order.append(label)
        return label

    g = TaskGraph()
    a = g.task("a", lambda: mark("a"))
    g.task("b", lambda: mark("b"))
    c = g.task("c", lambda a_, b_: mark("c", a_, b_), deps=(a, "b"))
    g.task("d", lambda c_: mark("d", c_), deps=(c,))
    results = g.run(name)
    assert results == {"a": "a", "b": "b", "c": "c", "d": "d"}
    assert set(order[:2]) == {"a", "b"} and order[2:] == ["c", "d"]
    assert a.result() == "a" and c.done()


@pytest.mark.parametrize("name", ALL)
def test_taskgraph_passes_dep_results_positionally(name):
    g = TaskGraph()
    g.task("x", lambda: 3)
    g.task("y", lambda: 4)
    g.task("hyp2", lambda x, y: x * x + y * y, deps=("x", "y"))
    assert g.run(name)["hyp2"] == 25


@pytest.mark.parametrize("name", ALL)
def test_taskgraph_failure_cancels_dependents(name):
    g = TaskGraph()
    g.task("ok", lambda: "fine")
    g.task("boom", lambda: 1 / 0)
    orphan = g.task("orphan", lambda b: b, deps=("boom",))
    with TaskScope(name) as scope:
        with pytest.raises(ZeroDivisionError):
            g.run(scope)
    assert orphan.done()
    with pytest.raises(TaskCancelledError):
        orphan.result()
    assert g.handle("ok").result() == "fine"    # the sibling still completed


@pytest.mark.parametrize("name", ALL)
def test_taskgraph_is_rerunnable(name):
    calls = {"n": 0}
    lock = threading.Lock()

    def bump():
        with lock:
            calls["n"] += 1
        return calls["n"]

    g = TaskGraph()
    g.task("t", bump)
    g.task("u", lambda t: t, deps=("t",))
    with TaskScope(name) as scope:
        first = g.run(scope)
        second = g.run(scope)
    assert first["t"] == 1 and second["t"] == 2 and second["u"] == 2


def test_taskgraph_builder_validation():
    g = TaskGraph()
    g.task("a", lambda: None)
    with pytest.raises(ValueError, match="duplicate"):
        g.task("a", lambda: None)
    with pytest.raises(ValueError, match="unknown"):
        g.task("b", lambda x: x, deps=("ghost",))
    assert "a" in g and len(g) == 1 and g.names == ("a",)


def test_taskgraph_rejects_foreign_handles():
    """A handle whose label collides with a node name must not silently
    bind: only this graph's own handles are accepted as deps."""
    g1, g2 = TaskGraph(), TaskGraph()
    foreign = g1.task("a", lambda: "g1-a")
    g2.task("a", lambda: "g2-a")
    with pytest.raises(ValueError, match="does not belong"):
        g2.task("c", lambda a: a, deps=(foreign,))
    with TaskScope("serial") as scope:
        stray = scope.submit(lambda: "stray")
        stray.label = "a"                        # adversarial label collision
        with pytest.raises(ValueError, match="does not belong"):
            g2.task("d", lambda a: a, deps=(stray,))


@pytest.mark.parametrize("name", ALL)
def test_taskgraph_does_not_adopt_sibling_errors_on_borrowed_scope(name):
    """A failed sibling task on a long-lived scope must not be raised (and
    cleared) by the graph's wavefront joins — the graph completes cleanly
    and the sibling's error still fires at the scope barrier (the same
    misattribution fix parallel_for has)."""
    with TaskScope(name) as scope:
        scope.submit(lambda: 1 / 0)              # unrelated flaky sibling
        g = TaskGraph()
        g.task("x", lambda: 1)
        g.task("y", lambda x: x + 1, deps=("x",))
        assert g.run(scope) == {"x": 1, "y": 2}  # must NOT raise
        with pytest.raises(ZeroDivisionError):
            scope.barrier()                      # sibling error kept for here


@pytest.mark.parametrize("name", ALL)
def test_taskgraph_own_errors_do_not_rearm_the_barrier(name):
    """Errors raised by the graph run are consumed: the next barrier on the
    same scope does not raise them again."""
    g = TaskGraph()
    g.task("boom", lambda: 1 / 0)
    with TaskScope(name) as scope:
        with pytest.raises(ZeroDivisionError):
            g.run(scope)
        scope.barrier()                          # nothing left to raise


@pytest.mark.parametrize("name", ALL)
def test_run_wavefronts_requires_started_scheduler(name):
    from repro.tasks.graph import run_wavefronts

    with pytest.raises(USAGE_ERRORS, match="started"):
        run_wavefronts({"a": (lambda: 1, ())}, make_scheduler(name))


def test_taskgraph_empty_run_returns_empty():
    assert TaskGraph().run("serial") == {}


# ----------------------------------------------------- allocation-slim paths

@pytest.mark.parametrize("name", ALL)
def test_handle_event_is_lazy(name):
    """Completion is a plain flag write: a handle that is only inspected
    after the barrier never allocates its Event; a blocking result() on a
    pending handle materializes one."""
    with TaskScope(name) as scope:
        h = scope.submit(lambda: 7)
        scope.barrier()
        assert h.done() and h._event is None     # fire-and-barrier: no Event
        assert h.result() == 7 and h.exception() is None
        assert h._event is None                  # done fast path stays lazy
        slow = scope.submit(lambda: (time.sleep(0.02), "s")[1])
        assert slow.result(timeout=5) == "s"     # blocking wait path
    assert "done" in repr(h)


def test_handle_timeout_still_raises():
    with TaskScope("relic") as scope:
        h = scope.submit(time.sleep, 0.2)
        with pytest.raises(TimeoutError):
            h.result(timeout=0.01)
        assert h.result(timeout=5) is None       # and then completes


@pytest.mark.parametrize("name", ALL)
def test_handle_waitable_from_another_thread(name):
    """The lazy event must be shared across concurrent waiters: a foreign
    reader thread and the owner both block on the same pending handle."""
    got = []
    with TaskScope(name) as scope:
        h = scope.submit(lambda: (time.sleep(0.05), 42)[1])
        t = threading.Thread(target=lambda: got.append(h.result(timeout=5)))
        t.start()
        assert h.result(timeout=5) == 42
        t.join(5)
    assert got == [42]


@pytest.mark.parametrize("name", ALL)
def test_parallel_for_single_chunk_raises_body_error_directly(name):
    """The zero-submission inline path still reports body errors."""
    with TaskScope(name) as scope:
        with pytest.raises(ValueError, match="inline boom"):
            parallel_for(scope, 3, lambda i: (_ for _ in ()).throw(
                ValueError("inline boom")), grain=100)
        scope.barrier()                          # consumed: not re-raised


@pytest.mark.parametrize("name", ALL)
def test_map_reduce_chunk_error_propagates(name):
    with TaskScope(name) as scope:
        with pytest.raises(ZeroDivisionError):
            map_reduce(scope, 12, lambda i: 1 // (i - 5),
                       lambda a, b: a + b, grain=3)
        scope.barrier()                          # consumed: not re-raised


# ------------------------------------------------- producer-participates mix

@pytest.mark.parametrize("name", ALL)
def test_scope_mixes_submit_inline_and_worksharing(name):
    """The shape of a real workload: futures + own work + a chunked loop
    in one scope window, errors clean, counters exact."""
    acc = []
    lock = threading.Lock()

    def add(x):
        with lock:
            acc.append(x)

    with TaskScope(name) as scope:
        h = scope.submit(lambda: "future")
        scope.run_inline(add, "inline")
        parallel_for(scope, 6, lambda i: add(i), grain=2)
        scope.barrier()
        assert h.result() == "future"
    assert sorted(a for a in acc if isinstance(a, int)) == list(range(6))
    assert "inline" in acc
    assert scope.stats.task_errors == 0
