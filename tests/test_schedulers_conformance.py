"""Cross-substrate conformance suite for ``repro.core.schedulers``.

One parameterized suite, run against **every** registered substrate: the
paper's comparison (Relic vs. spin vs. condvar vs. pool vs. serial) is only
meaningful if all competitors obey the identical observable contract —
submit/wait completion, error propagation at ``wait()``, bounded-queue
backpressure, shutdown idempotency, and survival of a 10k-task stress
round. Any new substrate registered via ``register_scheduler`` is picked up
automatically and held to the same bar.
"""

import threading
import time

import pytest

from repro.core.schedulers import (
    USAGE_ERRORS,
    available_schedulers,
    make_scheduler,
)
from repro.tasks.graph import run_wavefronts

ALL = available_schedulers()

# Substrates that preserve global submission order: at most one consumer
# (serial runs inline, trivially in order). Derived from the SPI's
# advertised `workers` so new substrates classify themselves — the pool's
# threads and relic-pool's lanes may legally reorder across each other.
SINGLE_CONSUMER = [
    n for n in ALL if getattr(make_scheduler(n), "workers", 1) <= 1]
MULTI_CONSUMER = [n for n in ALL if n not in SINGLE_CONSUMER]


def test_registry_is_complete():
    """The paper's comparison set is present under the expected names."""
    assert {"serial", "relic", "spin", "condvar", "pool",
            "relic-pool", "relic2", "relic4"} <= set(ALL)
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("no-such-substrate")


def test_conformance_parametrization_covers_registry():
    """Every registered substrate is exercised by this suite's
    parametrization. ``ALL`` is frozen at module import; if a substrate is
    registered later (a module this file does not import, or an import
    order change), the parametrized tests silently skip it — this is the
    tripwire that turns that silence into a failure."""
    assert ALL == available_schedulers()
    assert sorted(SINGLE_CONSUMER + MULTI_CONSUMER) == sorted(ALL)
    # The FIFO split must match the advertised worker counts.
    for name in ALL:
        workers = getattr(make_scheduler(name), "workers", 1)
        assert (name in SINGLE_CONSUMER) == (workers <= 1), name


def test_workers_property_advertises_concurrency():
    """The optional `workers` SPI property: 0 for inline serial, 1 for the
    single-assistant substrates, lane/thread count for pools."""
    expected = {"serial": 0, "relic": 1, "spin": 1, "condvar": 1,
                "pool": 2, "relic-pool": 2, "relic2": 2, "relic4": 4}
    for name, want in expected.items():
        assert make_scheduler(name).workers == want, name
    assert make_scheduler("relic-pool", lanes=3).workers == 3


@pytest.mark.parametrize("name", ALL)
def test_submit_wait_completes_everything(name):
    """After wait(), every submitted task has observably run."""
    done = []
    with make_scheduler(name) as sched:
        for i in range(100):
            sched.submit(done.append, i)
        sched.wait()
        assert sorted(done) == list(range(100))
        assert sched.stats.submitted == 100
        assert sched.stats.completed == 100
        assert sched.stats.task_errors == 0


@pytest.mark.parametrize("name", SINGLE_CONSUMER)
def test_single_consumer_preserves_fifo(name):
    out = []
    with make_scheduler(name) as sched:
        for i in range(500):
            sched.submit(out.append, i)
        sched.wait()
    assert out == list(range(500))


@pytest.mark.parametrize("name", ALL)
def test_error_propagates_to_wait_and_scheduler_survives(name):
    with make_scheduler(name) as sched:
        sched.submit(lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            sched.wait()
        assert sched.stats.task_errors == 1
        # the error is cleared and the substrate remains usable
        done = []
        sched.submit(done.append, "after")
        sched.wait()
        assert done == ["after"]


@pytest.mark.parametrize("name", ALL)
def test_first_of_many_errors_wins(name):
    with make_scheduler(name) as sched:
        sched.submit(lambda: (_ for _ in ()).throw(KeyError("first")))
        sched.submit(lambda: 1 / 0)
        with pytest.raises(KeyError):
            sched.wait()
        assert sched.stats.task_errors == 2
        sched.wait()  # second wait: nothing outstanding, nothing raised


@pytest.mark.parametrize("name", ALL)
def test_first_error_wins_strictly_across_burst_and_rounds(name):
    """The FIRST error since the last wait() re-raises — never the last
    (regression: relic once overwrote ``last_error`` per failure). The
    contract resets per wait() window: after the raising wait(), the next
    window's own first error wins."""
    with make_scheduler(name) as sched:
        sched.submit(lambda: (_ for _ in ()).throw(KeyError("first")))
        sched.submit_many([(lambda: 1 / 0, (), {}),
                           (lambda: (_ for _ in ()).throw(IndexError()), (), {})])
        with pytest.raises(KeyError, match="first"):
            sched.wait()
        assert sched.stats.task_errors == 3
        # next window: its own first error wins, prior errors stay cleared
        sched.submit(lambda: (_ for _ in ()).throw(ValueError("second window")))
        sched.submit(lambda: 1 / 0)
        with pytest.raises(ValueError, match="second window"):
            sched.wait()
        assert sched.stats.task_errors == 5


@pytest.mark.parametrize("name", ALL)
def test_bounded_backpressure_never_drops(name):
    """Submitting far more tasks than capacity must block, not drop: with
    capacity 4 and slow tasks, all 200 submissions complete exactly once."""
    done = []
    with make_scheduler(name, capacity=4) as sched:
        for i in range(200):
            sched.submit(lambda i=i: (time.sleep(0.0002), done.append(i)))
        sched.wait()
    assert sorted(done) == list(range(200))
    assert sched.stats.completed == 200


@pytest.mark.parametrize("name", ALL)
def test_shutdown_idempotent_and_drains(name):
    done = []
    sched = make_scheduler(name).start()
    for i in range(50):
        sched.submit(lambda i=i: (time.sleep(0.0001), done.append(i)))
    sched.close()   # no explicit wait: close must drain in-flight tasks
    sched.close()   # idempotent
    sched.close()
    assert sorted(done) == list(range(50))


@pytest.mark.parametrize("name", ALL)
def test_close_without_start_is_safe(name):
    sched = make_scheduler(name)
    sched.close()
    sched.close()


@pytest.mark.parametrize("name", ALL)
def test_misuse_raises(name):
    sched = make_scheduler(name)
    with pytest.raises(USAGE_ERRORS):
        sched.submit(lambda: None)  # submit before start
    sched.start()
    with pytest.raises(USAGE_ERRORS):
        sched.start()  # double start
    sched.close()
    with pytest.raises(USAGE_ERRORS):
        sched.submit(lambda: None)  # submit after close


@pytest.mark.parametrize("name", ALL)
def test_submit_with_kwargs(name):
    """Keyword arguments reach the task on every substrate (relic folds
    them into a partial before the ring push — the rare path)."""
    out = []

    def record(a, b=0, c=0):
        out.append((a, b, c))

    with make_scheduler(name) as sched:
        sched.submit(record, 1, b=2, c=3)
        sched.submit_many([(record, (4,), {"b": 5}), (record, (6,), {})])
        sched.wait()
    assert sorted(out) == [(1, 2, 3), (4, 5, 0), (6, 0, 0)]


# ------------------------------------------------------- batch SPI contract

@pytest.mark.parametrize("name", ALL)
def test_submit_many_completes_everything(name):
    """submit_many == the equivalent submit() loop: completion + counters."""
    done = []
    with make_scheduler(name) as sched:
        sched.submit_many([(done.append, (i,), {}) for i in range(100)])
        sched.wait()
        assert sorted(done) == list(range(100))
        assert sched.stats.submitted == 100
        assert sched.stats.completed == 100
        assert sched.stats.task_errors == 0


@pytest.mark.parametrize("name", SINGLE_CONSUMER)
def test_submit_many_preserves_fifo_and_interleaves_with_submit(name):
    out = []
    with make_scheduler(name) as sched:
        sched.submit(out.append, 0)
        sched.submit_many([(out.append, (i,), {}) for i in range(1, 400)])
        sched.submit(out.append, 400)
        sched.wait()
    assert out == list(range(401))


@pytest.mark.parametrize("name", ALL)
def test_submit_many_accepts_generators_and_empty_bursts(name):
    done = []
    with make_scheduler(name) as sched:
        sched.submit_many(())                       # empty burst: no-op
        sched.submit_many((done.append, (i,), {}) for i in range(10))
        sched.wait()
    assert sorted(done) == list(range(10))
    assert sched.stats.submitted == 10


@pytest.mark.parametrize("name", ALL)
def test_submit_many_bounded_backpressure_never_drops(name):
    """A burst far past capacity must block on free slots, never drop."""
    done = []
    with make_scheduler(name, capacity=4) as sched:
        sched.submit_many(
            [(lambda i=i: (time.sleep(0.0002), done.append(i)), (), {})
             for i in range(200)])
        sched.wait()
    assert sorted(done) == list(range(200))
    assert sched.stats.completed == 200


@pytest.mark.parametrize("name", ALL)
def test_submit_many_errors_surface_at_wait(name):
    with make_scheduler(name) as sched:
        sched.submit_many([(lambda: 1 / 0, (), {}),
                           (lambda: None, (), {})])
        with pytest.raises(ZeroDivisionError):
            sched.wait()
        assert sched.stats.task_errors == 1
        sched.submit_many([(lambda: None, (), {})])   # still usable
        sched.wait()


@pytest.mark.parametrize("name", ALL)
def test_submit_many_misuse_raises(name):
    sched = make_scheduler(name)
    with pytest.raises(USAGE_ERRORS):
        sched.submit_many([(lambda: None, (), {})])   # before start
    sched.start()
    err = []

    def foreign():
        try:
            sched.submit_many([(lambda: None, (), {})])
        except USAGE_ERRORS as e:
            err.append(e)

    t = threading.Thread(target=foreign)
    t.start()
    t.join()
    assert err                                        # owning-thread-only
    sched.close()
    with pytest.raises(USAGE_ERRORS):
        sched.submit_many([(lambda: None, (), {})])   # after close


@pytest.mark.parametrize("name", ALL)
def test_submit_many_with_parked_worker_makes_progress(name):
    """Advisory hints must not deadlock a batch that outsizes capacity."""
    done = []
    with make_scheduler(name, capacity=2) as sched:
        sched.sleep_hint()
        time.sleep(0.02)
        sched.submit_many([(done.append, (i,), {}) for i in range(20)])
        sched.wait()
    assert sorted(done) == list(range(20))


@pytest.mark.parametrize("name", ALL)
def test_wait_with_nothing_outstanding_returns(name):
    with make_scheduler(name) as sched:
        sched.wait()
        sched.wait()


@pytest.mark.parametrize("name", ALL)
def test_hints_are_safe_around_submission(name):
    """sleep/wake hints are advisory: parked or not, work completes."""
    done = []
    with make_scheduler(name) as sched:
        sched.sleep_hint()
        for i in range(10):
            sched.submit(done.append, i)
        sched.wake_up_hint()
        sched.wait()
        sched.sleep_hint()
        sched.wake_up_hint()
    assert sorted(done) == list(range(10))


@pytest.mark.parametrize("name", ALL)
def test_wait_unparks_a_sleeping_worker(name):
    """Advisory hints must never deadlock the barrier: submitting while
    parked and then calling wait() (without wake_up_hint) completes."""
    done = []
    with make_scheduler(name) as sched:
        sched.sleep_hint()
        time.sleep(0.05)  # let the worker actually park
        for i in range(5):
            sched.submit(done.append, i)
        sched.wait()      # no wake_up_hint on purpose
    assert sorted(done) == list(range(5))


@pytest.mark.parametrize("name", ALL)
def test_full_queue_submit_with_parked_worker_makes_progress(name):
    """capacity-1 backpressure + a parked worker must not deadlock submit."""
    done = []
    with make_scheduler(name, capacity=1) as sched:
        sched.sleep_hint()
        time.sleep(0.02)
        for i in range(10):  # > capacity: submit must force progress
            sched.submit(done.append, i)
        sched.wait()
    assert sorted(done) == list(range(10))


@pytest.mark.parametrize("name", ALL)
def test_close_without_wait_keeps_errors_observable(name):
    """close() never raises, but a task error must stay visible in stats."""
    sched = make_scheduler(name).start()
    sched.submit(lambda: 1 / 0)
    sched.close()
    assert sched.stats.task_errors == 1
    assert isinstance(sched.stats.last_error, ZeroDivisionError)


def test_pool_pending_futures_are_reaped_without_wait():
    """A wait()-free submit stream (the PrefetchPipeline pattern) must not
    accumulate one Future per task forever."""
    with make_scheduler("pool") as sched:
        for i in range(2000):
            sched.submit(lambda: None)
            if i % 100 == 0:
                time.sleep(0)  # 1-core box: let the workers drain a little
        # leak would retain ~2000; reaping keeps it at the workers' lag
        assert len(sched._pending) < 1000
        sched.wait()
        assert sched.stats.completed == 2000
        assert not sched._pending


@pytest.mark.parametrize("name", ALL)
def test_stress_10k_tasks(name):
    """10k-task stress round: counters stay exact across repeated
    submit/wait windows (the shape of a real training loop)."""
    counter = {"n": 0}
    lock = threading.Lock()

    def bump():
        with lock:
            counter["n"] += 1

    with make_scheduler(name) as sched:
        total = 10_000
        window = 500
        for lo in range(0, total, window):
            for _ in range(window):
                sched.submit(bump)
            sched.wait()
        assert counter["n"] == total
        assert sched.stats.submitted == total
        assert sched.stats.completed == total
        assert sched.stats.task_errors == 0


@pytest.mark.parametrize("name", ALL)
def test_wavefront_driver_runs_on_every_substrate(name):
    """The legacy dict-of-tuples run_wavefronts entry point (now a shim
    over repro.tasks.api.TaskGraph — see tests/test_tasks_api.py for the
    façade's own suite) respects dependencies on any substrate."""
    order = []
    lock = threading.Lock()

    def mark(label, *deps):
        with lock:
            order.append(label)
        return label

    tasks = {
        "a": (lambda: mark("a"), ()),
        "b": (lambda: mark("b"), ()),
        "c": (lambda a, b: mark("c", a, b), ("a", "b")),
        "d": (lambda c: mark("d", c), ("c",)),
    }
    with make_scheduler(name) as sched:
        results = run_wavefronts(tasks, sched)
    assert results == {"a": "a", "b": "b", "c": "c", "d": "d"}
    assert set(order[:2]) == {"a", "b"} and order[2:] == ["c", "d"]


def test_wavefront_driver_rejects_cycles_and_unknown_deps():
    with make_scheduler("serial") as sched:
        with pytest.raises(ValueError, match="cycle"):
            run_wavefronts({"a": (lambda b: b, ("b",)),
                            "b": (lambda a: a, ("a",))}, sched)
        with pytest.raises(ValueError, match="unknown"):
            run_wavefronts({"a": (lambda x: x, ("ghost",))}, sched)


# ---------------------------------------------------------------- consumers
# The scheduler= parameter threaded through the data pipeline and the
# checkpoint manager must work over every substrate, not just Relic.

@pytest.mark.parametrize("name", ALL)
def test_pipeline_replays_batches_deterministically_on_any_substrate(name):
    """In-order delivery holds even for the multi-worker pool substrate
    (arrivals are staged by index), so restart replay is exact everywhere."""
    import numpy as np

    from repro.data import DataConfig, PrefetchPipeline, SyntheticLM

    dc = DataConfig(seq_len=8, global_batch=2, vocab_size=50, prefetch=3)
    src = SyntheticLM(dc)
    p1 = PrefetchPipeline(src, dc, scheduler=name).start()
    first = [p1.next_batch()["tokens"] for _ in range(6)]
    p1.stop()
    for i, want in enumerate(first):
        np.testing.assert_array_equal(want, src.batch(i)["tokens"])
    p2 = PrefetchPipeline(src, dc, start_index=2, scheduler=name).start()
    np.testing.assert_array_equal(first[2], p2.next_batch()["tokens"])
    np.testing.assert_array_equal(first[3], p2.next_batch()["tokens"])
    p2.stop()


@pytest.mark.parametrize("name", ALL)
def test_pipeline_surfaces_producer_errors_instead_of_hanging(name):
    from repro.data import DataConfig, PrefetchPipeline, SyntheticLM

    dc = DataConfig(seq_len=8, global_batch=2, vocab_size=50, prefetch=2)
    src = SyntheticLM(dc)

    def bad_transform(batch):
        raise OSError("disk went away")

    p = PrefetchPipeline(src, dc, transform=bad_transform,
                         scheduler=name).start()
    with pytest.raises(RuntimeError, match="batch 0 production failed") as ei:
        p.next_batch()
    assert isinstance(ei.value.__cause__, OSError)
    p.stop()


@pytest.mark.parametrize("name", ALL)
def test_checkpoint_async_roundtrip_on_any_substrate(name, tmp_path):
    import numpy as np

    from repro.checkpoint import CheckpointManager

    state = {"w": np.arange(12, dtype=np.float32).reshape(3, 4)}
    mgr = CheckpointManager(tmp_path, async_=True, scheduler=name)
    mgr.save(state, 7)
    mgr.wait()
    restored, step = mgr.restore(state)
    mgr.close()
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])
