"""Chaos harness + lane supervision + serve retry (PR 8).

Three layers under test, bottom-up:

1. the harness itself (``repro.runtime.chaos``): spec validation/env
   parsing, seeded determinism of fault placement, the kill switch;
2. the substrate's reaction: Relic bounded waits raise ``RelicDeadError``
   with exact loss accounting, RelicPool quarantines/respawns dead lanes
   with the lost count *deterministically* equal to the dead ring's
   in-flight count (the PR's acceptance criterion, at lanes 2 and 4);
3. the serve layer's recovery: idempotent requests retried across task
   errors and lane death, everything else failing fast.

Every fault here is injected deterministically (seeded plans, counted kill
switches) — no sleeps-as-synchronization, no flaky timing assumptions
beyond "a live lane eventually drains its ring".
"""

import time

import pytest

from repro.core.relic import Relic, RelicDeadError
from repro.core.relic_pool import LaneFailedError, RelicPool
from repro.core.schedulers import make_scheduler
from repro.runtime.chaos import (
    ChaosInjectedError,
    ChaosScheduler,
    ChaosSpec,
    FaultPlan,
    KillSwitch,
    plan_bursts,
)
from repro.serve import RetryPolicy, ServeScheduler
from repro.serve.request import STATUS_ERROR


# ------------------------------------------------------------------- spec


def test_chaos_spec_validation():
    with pytest.raises(ValueError, match="raise_rate"):
        ChaosSpec(raise_rate=1.5)
    with pytest.raises(ValueError, match="stall_rate"):
        ChaosSpec(stall_rate=-0.1)
    with pytest.raises(ValueError, match="exceed 1"):
        ChaosSpec(raise_rate=0.6, stall_rate=0.6)
    with pytest.raises(ValueError, match="stall_s"):
        ChaosSpec(stall_s=-1.0)
    with pytest.raises(ValueError, match="kill_after"):
        ChaosSpec(kill_after=-1)
    with pytest.raises(ValueError, match="burst"):
        ChaosSpec(burst=-2)


def test_chaos_spec_default_is_semantics_preserving():
    # The registered "chaos" substrate runs the full conformance suite
    # under the default spec: it must not replace any task's effect.
    spec = ChaosSpec()
    assert spec.raise_rate == 0.0
    assert spec.stall_rate > 0.0


def test_chaos_spec_from_env(monkeypatch):
    monkeypatch.delenv("RELIC_CHAOS", raising=False)
    assert ChaosSpec.from_env() == ChaosSpec()
    monkeypatch.setenv(
        "RELIC_CHAOS",
        "seed=7, raise_rate=0.25, stall_rate=0.1, stall_s=0.001,"
        " kill_after=3, burst=4, inner=spin")
    spec = ChaosSpec.from_env()
    assert spec == ChaosSpec(seed=7, raise_rate=0.25, stall_rate=0.1,
                             stall_s=0.001, kill_after=3, burst=4,
                             inner="spin")
    monkeypatch.setenv("RELIC_CHAOS", "kill_after=none")
    assert ChaosSpec.from_env().kill_after is None


def test_chaos_spec_from_env_rejects_garbage(monkeypatch):
    monkeypatch.setenv("RELIC_CHAOS", "warp_speed=9")
    with pytest.raises(ValueError, match="unknown key"):
        ChaosSpec.from_env()
    monkeypatch.setenv("RELIC_CHAOS", "seed=banana")
    with pytest.raises(ValueError, match="bad value"):
        ChaosSpec.from_env()
    monkeypatch.setenv("RELIC_CHAOS", "just-noise")
    with pytest.raises(ValueError, match="key=value"):
        ChaosSpec.from_env()


# ------------------------------------------------------------------- plan


def test_fault_plan_is_deterministic():
    spec = ChaosSpec(seed=42, raise_rate=0.3, stall_rate=0.3)
    fn = lambda: None  # noqa: E731

    def classify(plan):
        out = []
        for _ in range(200):
            d = plan.decorate(fn)
            out.append("none" if d is fn else d.__name__)
        return out

    a = classify(FaultPlan(spec))
    b = classify(FaultPlan(spec))
    assert a == b
    assert "chaos_raise" in a and "chaos_stall" in a and "none" in a
    other = classify(FaultPlan(ChaosSpec(seed=43, raise_rate=0.3,
                                         stall_rate=0.3)))
    assert a != other


def test_fault_plan_wrappers_behave():
    plan = FaultPlan(ChaosSpec(raise_rate=1.0, stall_rate=0.0))
    with pytest.raises(ChaosInjectedError):
        plan.decorate(lambda: 1)()
    assert plan.injected_raises == 1

    plan = FaultPlan(ChaosSpec(raise_rate=0.0, stall_rate=1.0, stall_s=0.0))
    assert plan.decorate(lambda x: x + 1)(2) == 3   # result preserved

    def boom():
        raise KeyError("real")

    with pytest.raises(KeyError):                   # real errors preserved
        plan.decorate(boom)()
    assert plan.injected_stalls == 2


def test_plan_bursts_deterministic_and_exact():
    spec = ChaosSpec(seed=5, burst=4)
    a = plan_bursts(spec, 37)
    assert a == plan_bursts(spec, 37)
    assert sum(a) == 37
    assert all(1 <= n <= 4 for n in a)
    assert plan_bursts(ChaosSpec(burst=0), 3) == [1, 1, 1]
    assert plan_bursts(spec, 0) == []
    with pytest.raises(ValueError, match="total"):
        plan_bursts(spec, -1)


# ---------------------------------------------------------------- the pair


def test_kill_switch_validation():
    with pytest.raises(ValueError, match="after_bursts"):
        KillSwitch(after_bursts=-1)


def test_relic_bounded_wait_raises_on_dead_assistant():
    r = Relic(capacity=8).start()
    KillSwitch(after_bursts=0).arm(r)
    sink = []
    for i in range(8):
        r.submit(sink.append, i)
    with pytest.raises(RelicDeadError) as ei:
        r.wait()
    err = ei.value
    # Exact loss accounting: whatever the assistant popped-but-never-ran
    # plus whatever is still on the ring, and nothing was double-counted.
    assert err.submitted == 8
    assert err.lost == err.submitted - err.completed
    assert err.lost > 0
    assert "dead" in str(err)
    # A dead pair is not restartable, but shutdown must not hang.
    r.shutdown()


def test_relic_submit_slow_path_raises_on_dead_assistant():
    # Fill the ring past capacity with the assistant dead: the producer's
    # full-ring spin must raise, not hang (the pre-PR8 behaviour).
    r = Relic(capacity=4).start()
    KillSwitch(after_bursts=0).arm(r)
    with pytest.raises(RelicDeadError):
        for i in range(64):
            r.submit(time.sleep, 0)


def test_relic_supervise_off_disables_probes():
    r = Relic(capacity=4)
    assert r._probe_every > 0          # default: supervised
    p = RelicPool(lanes=2, supervise=False)
    assert all(lane._probe_every == 0 for lane in p._lanes)
    assert p.check_lanes() == []       # no-op without supervision
    p.shutdown()


# ---------------------------------------------------------------- the pool


@pytest.mark.parametrize("lanes", [2, 4])
def test_pool_quarantine_loss_is_exact(lanes):
    # Acceptance criterion: kill one lane under load; the lost count the
    # pool reports equals the dead ring's in-flight count exactly, and the
    # global ledger submitted == completed + lost stays balanced.
    pool = RelicPool(lanes=lanes, capacity=64).start()
    ks = KillSwitch(after_bursts=0).arm(pool._lanes[1])
    total = 50 * lanes
    for i in range(total):
        pool.submit(time.sleep, 0)
    with pytest.raises(LaneFailedError) as ei:
        pool.wait()
    err = ei.value
    assert ks.fired
    assert len(err.failures) == 1
    f = err.failures[0]
    assert f.lane_index == 1
    assert not f.respawned
    assert f.lost == f.submitted - f.completed
    assert f.lost > 0
    assert err.lost == f.lost == pool.lost_tasks
    assert pool.stats.completed + pool.lost_tasks == pool.stats.submitted
    assert pool.live_lanes == tuple(i for i in range(lanes) if i != 1)
    pool.shutdown()


@pytest.mark.parametrize("lanes", [2, 4])
def test_pool_respawn_recovers_capacity(lanes):
    pool = RelicPool(lanes=lanes, capacity=64, respawn=True).start()
    ks = KillSwitch(after_bursts=0).arm(pool._lanes[1])
    total = 50 * lanes
    for i in range(total):
        pool.submit(time.sleep, 0)
    with pytest.raises(LaneFailedError) as ei:
        pool.wait()
    f = ei.value.failures[0]
    assert ks.fired and f.respawned and f.lost > 0
    # The replacement lane is live and serving again at full width.
    assert pool.live_lanes == tuple(range(lanes))
    before = pool.stats.completed
    for i in range(total):
        pool.submit(time.sleep, 0)
    pool.wait()                        # clean: the failure was consumed
    assert pool.stats.completed == before + total
    assert pool.lost_tasks == f.lost   # no further loss
    assert pool.in_flight_estimate() == 0
    pool.shutdown()


def test_pool_fully_dead_keeps_raising():
    pool = RelicPool(lanes=2, capacity=16).start()
    KillSwitch(after_bursts=0).arm(pool._lanes[0])
    KillSwitch(after_bursts=0).arm(pool._lanes[1])
    for i in range(16):
        pool.submit(time.sleep, 0)
    with pytest.raises(LaneFailedError):
        pool.wait()
    # Permanently dead: every later wait()/submit keeps saying so rather
    # than silently succeeding against nothing.
    with pytest.raises(LaneFailedError):
        pool.wait()
    with pytest.raises(LaneFailedError):
        for i in range(1000):
            pool.submit(time.sleep, 0)
    pool.shutdown()


def test_pool_scheduler_adapter_surfaces_lane_failures():
    sched = make_scheduler("relic-pool", lanes=2, capacity=32,
                           respawn=True).start()
    pool = sched._pool
    ks = KillSwitch(after_bursts=0).arm(pool._lanes[0])
    for i in range(80):
        sched.submit(time.sleep, 0)
    deadline = time.monotonic() + 5.0
    failures = []
    while not failures and time.monotonic() < deadline:
        failures = sched.poll_lane_failures()
        time.sleep(0)
    assert ks.fired
    assert [f.lane_index for f in failures] == [0]
    assert failures[0].respawned
    # Consumed via polling: wait() no longer raises for it.
    while sched.in_flight_estimate() > 0 and time.monotonic() < deadline:
        time.sleep(0)
    assert sched.in_flight_estimate() == 0
    sched.close()


# ------------------------------------------------------------- chaos sched


def test_chaos_scheduler_injects_raises():
    spec = ChaosSpec(raise_rate=1.0, stall_rate=0.0)
    with ChaosScheduler(spec=spec) as sched:
        sched.submit(lambda: 1)
        with pytest.raises(ChaosInjectedError):
            sched.wait()


def test_chaos_scheduler_registered():
    sched = make_scheduler("chaos")
    assert isinstance(sched, ChaosScheduler)
    with sched:
        out = []
        sched.submit(out.append, 1)
        sched.wait()
    assert out == [1]


# ------------------------------------------------------------- retry policy


def test_retry_policy_validation():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="multiplier"):
        RetryPolicy(multiplier=0.5)
    with pytest.raises(ValueError, match="max_backoff_s"):
        RetryPolicy(base_backoff_s=1.0, max_backoff_s=0.5)
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=1.0)


def test_retry_policy_from_env(monkeypatch):
    from repro.runtime.config import resolve_serve_config
    monkeypatch.setenv("RELIC_SERVE_RETRIES", "5")
    policy = RetryPolicy.from_config(resolve_serve_config())
    assert policy.max_attempts == 6 and policy.retries == 5
    assert policy.allows(5) and not policy.allows(6)


def test_retry_policy_delay_is_deterministic_and_bounded():
    p = RetryPolicy(max_attempts=5, base_backoff_s=0.001, multiplier=2.0,
                    max_backoff_s=0.004, jitter=0.5, seed=3)
    for attempt in range(1, 5):
        d1 = p.delay(rid=17, attempt=attempt)
        d2 = p.delay(rid=17, attempt=attempt)
        assert d1 == d2
        cap = min(0.001 * 2 ** (attempt - 1), 0.004)
        assert 0.5 * cap <= d1 <= 1.5 * cap
    assert p.delay(17, 1) != p.delay(18, 1)   # jitter varies per request
    with pytest.raises(ValueError, match="attempt"):
        p.delay(0, 0)


# ------------------------------------------------------------- serve retry


def _flaky(counter, fail_times, key="k"):
    counter[key] = counter.get(key, 0) + 1
    if counter[key] <= fail_times:
        raise RuntimeError(f"boom {counter[key]}")
    return counter[key]


def test_serve_retries_idempotent_task_error():
    calls = {}
    with ServeScheduler(lanes=2) as server:
        client = server.open_client()
        resp = client.submit(_flaky, calls, 2, deadline_s=30.0,
                             idempotent=True)
        assert resp.result(timeout=30) == 3
        assert resp.attempts == 3
    assert server.stats()["retries"] == 2


def test_serve_fails_fast_without_idempotent():
    calls = {}
    with ServeScheduler(lanes=2) as server:
        client = server.open_client()
        resp = client.submit(_flaky, calls, 2, deadline_s=30.0)
        with pytest.raises(RuntimeError, match="boom 1"):
            resp.result(timeout=30)
        assert resp.attempts == 1


def test_serve_retry_budget_exhausts_to_error():
    calls = {}
    policy = RetryPolicy(max_attempts=2, jitter=0.0, base_backoff_s=0.0)
    with ServeScheduler(lanes=2, retry_policy=policy) as server:
        client = server.open_client()
        resp = client.submit(_flaky, calls, 5, deadline_s=30.0,
                             idempotent=True)
        with pytest.raises(RuntimeError, match="boom 2"):
            resp.result(timeout=30)
        assert resp.attempts == 2
        assert resp.status == STATUS_ERROR


def test_serve_inline_mode_retries_too():
    calls = {}
    with ServeScheduler(lanes=0) as server:
        client = server.open_client()
        resp = client.submit(_flaky, calls, 1, deadline_s=30.0,
                             idempotent=True)
        assert resp.result(timeout=30) == 2
        assert resp.attempts == 2


def test_serve_lane_death_retries_idempotent_requests():
    with ServeScheduler(lanes=4) as server:
        client = server.open_client()
        deadline = time.monotonic() + 5.0
        while server._sched is None and time.monotonic() < deadline:
            time.sleep(0)
        pool = server._sched._pool
        ks = KillSwitch(after_bursts=0).arm(pool._lanes[1])
        resps = [client.submit(time.sleep, 0, deadline_s=30.0,
                               idempotent=True) for _ in range(300)]
        for r in resps:
            assert r.result(timeout=30) is None
        snap = server.stats()
        assert ks.fired
        assert snap["lane_failures"] >= 1
        retried = sum(1 for r in resps if r.attempts > 1)
        assert snap["lost_requests"] == retried
        assert pool.live_lanes == (0, 1, 2, 3)   # respawned under serve


def test_serve_lane_death_errors_non_idempotent_requests():
    with ServeScheduler(lanes=2) as server:
        client = server.open_client()
        deadline = time.monotonic() + 5.0
        while server._sched is None and time.monotonic() < deadline:
            time.sleep(0)
        pool = server._sched._pool
        ks = KillSwitch(after_bursts=0).arm(pool._lanes[0])
        resps = [client.submit(time.sleep, 0, deadline_s=30.0)
                 for _ in range(200)]
        outcomes = set()
        lost = 0
        for r in resps:
            assert r.wait(timeout=30)
            outcomes.add(r.status)
            if r.status == STATUS_ERROR:
                lost += 1
                assert isinstance(r.error, LaneFailedError)
        assert ks.fired
        assert lost == server.stats()["lost_requests"]
        assert lost > 0


def test_serve_stats_surface_robustness_fields():
    with ServeScheduler(lanes=2) as server:
        snap = server.stats()
    for key in ("retries", "lane_failures", "lost_requests",
                "stalled_lanes", "straggler_lanes", "supervise"):
        assert key in snap
