"""End-to-end system behaviour: train-to-convergence smoke, serve loop,
sharding rules coverage."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding as shd
from repro.configs import SHAPES, get_config, shape_applicable


def test_training_reduces_loss():
    """A few hundred steps of the tiny config must reduce loss materially."""
    from repro.launch.train import main as train_main

    final = train_main(["--arch", "relic_tiny", "--smoke", "--steps", "100",
                        "--batch", "8", "--seq", "64", "--log-every", "50"])
    assert final < 5.0, final  # ln(512) ≈ 6.24 at init


def test_serve_generates_tokens():
    from repro.launch.serve import main as serve_main

    gen = serve_main(["--arch", "relic_tiny", "--smoke", "--batch", "2",
                      "--prompt-len", "4", "--gen", "8"])
    assert gen.shape == (2, 8)
    assert (np.asarray(gen) >= 0).all()


def test_shape_applicability_rules():
    dense = get_config("llama3_405b")
    ssm = get_config("rwkv6_1p6b")
    hyb = get_config("zamba2_1p2b")
    ok, why = shape_applicable(dense, SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in why
    assert shape_applicable(ssm, SHAPES["long_500k"])[0]
    assert shape_applicable(hyb, SHAPES["long_500k"])[0]
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        assert shape_applicable(dense, SHAPES[s])[0]


def test_param_rules_cover_every_arch():
    """Every parameter of every full config matches a sharding rule that
    fits its shape (after divisibility fallback)."""
    from repro.configs import all_configs
    from repro.launch.mesh import make_mesh

    # 16 devices not required: specs are mesh-shape-checked lazily; use
    # a tiny mesh with the production axis names via AbstractMesh-like shape
    mesh = make_mesh((1, 1), ("data", "model"))
    for arch, cfg in all_configs().items():
        from repro.models import build_model

        sds = jax.eval_shape(lambda m=build_model(cfg): m.init(
            jax.random.PRNGKey(0)))
        specs = shd.param_specs(sds, mesh)
        big_unsharded = []
        for (kp, leaf), spec in zip(
                jax.tree_util.tree_flatten_with_path(sds)[0],
                jax.tree.leaves(specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec))):
            assert isinstance(spec, jax.sharding.PartitionSpec)
        assert specs is not None


def test_input_specs_cover_all_cells():
    from repro.configs import all_configs
    from repro.models import build_model

    for arch, cfg in all_configs().items():
        model = build_model(cfg)
        for sname, shape in SHAPES.items():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            batch, cache_len = model.input_specs(shape)
            assert "tokens" in batch
            if shape.kind == "decode":
                assert cache_len == shape.seq_len
                assert batch["tokens"].shape == (shape.global_batch, 1)
            else:
                assert batch["tokens"].shape[0] == shape.global_batch


def test_fit_spec_divisibility():
    # AbstractMesh: fit_spec only consults axis names/sizes, no devices
    # needed (compat shim handles the 0.4.x AbstractMesh signature)
    from repro.compat import abstract_mesh

    mesh = abstract_mesh((2, 4), ("data", "model"))
    # 20 heads do not divide model=4*? -> drops axis
    spec = shd.fit_spec(mesh, [None, "model", None], (3, 20, 64))
    assert spec == jax.sharding.PartitionSpec(None, "model", None)
    spec = shd.fit_spec(mesh, [None, "model", None], (3, 21, 64))
    assert spec == jax.sharding.PartitionSpec(None, None, None)
    spec = shd.fit_spec(mesh, [("data", "model"), None], (16, 8))
    assert spec == jax.sharding.PartitionSpec(("data", "model"), None)
    # batch=2 divides data(2) but not data*model(8): degrade to prefix
    spec = shd.fit_spec(mesh, [("data", "model"), None], (2, 8))
    assert spec == jax.sharding.PartitionSpec("data", None)
