"""Per-architecture smoke tests (reduced configs) + decode/train consistency.

The assignment requires: for each architecture, instantiate a REDUCED config
of the same family and run one forward/train step on CPU asserting output
shapes + no NaNs. Decode consistency additionally proves the serve path
agrees with teacher forcing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import make_train_state, make_train_step
from repro.models import build_model
from repro.optim import OptConfig

ARCHS = [a for a in ARCH_IDS]


def _batch(cfg, rng, b=2, s=32):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend.n_tokens, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend.n_tokens, cfg.frontend.embed_dim)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    state = make_train_state(model, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    step = jax.jit(make_train_step(model, OptConfig(warmup_steps=2,
                                                    total_steps=10)))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0, arch
    # second step still finite
    _, metrics2 = step(new_state, batch)
    assert np.isfinite(float(metrics2["loss"])), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_logits_shape_and_finite(arch, rng):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)
    if cfg.family == "encdec":
        from repro.models.encdec import decode_train, encode

        logits = decode_train(cfg, params, batch["tokens"],
                              encode(cfg, params, batch["frames"]))
    else:
        from repro.models.lm import lm_forward

        extra = batch.get("patches")
        logits, _ = lm_forward(cfg, params, batch["tokens"],
                               extra_embed=extra,
                               prefix_len=extra.shape[1] if extra is not None
                               else None)
        if extra is not None:
            assert logits.shape == (b, s + cfg.frontend.n_tokens,
                                    cfg.vocab_size)
            logits = logits[:, extra.shape[1]:]
    assert logits.shape == (b, s, cfg.vocab_size), arch
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ["granite_8b", "qwen3_14b", "rwkv6_1p6b",
                                  "zamba2_1p2b", "llama4_maverick_400b_a17b"])
def test_decode_matches_teacher_forcing(arch, rng):
    """Greedy decode over a forced token stream must reproduce the training
    forward's logits step by step (same params, same tokens)."""
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        # capacity-dispatch MoE drops tokens under *sequence-level*
        # competition, which legitimately differs between teacher forcing
        # and one-token decode; test consistency in the drop-free regime.
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=16.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    b, s = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)

    from repro.models.lm import lm_forward

    ref_logits, _ = lm_forward(cfg, params, toks)

    cache = model.init_cache(b, s)
    step = jax.jit(model.decode_step)
    got = []
    for t in range(s):
        logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref_logits, np.float32),
        rtol=0.15, atol=0.15)  # bf16 accumulation differences
    # argmax agreement is the functional bar
    agree = (np.argmax(np.asarray(got), -1)
             == np.argmax(np.asarray(ref_logits), -1)).mean()
    assert agree > 0.9, (arch, agree)


def test_encdec_decode_matches_teacher_forcing(rng):
    cfg = get_config("whisper_large_v3", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    b, s = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    frames = jnp.asarray(
        rng.normal(size=(b, cfg.frontend.n_tokens, cfg.d_model)), jnp.bfloat16)

    from repro.models.encdec import decode_train, encode, prefill_cross_cache

    enc_out = encode(cfg, params, frames)
    ref_logits = decode_train(cfg, params, toks, enc_out)

    cache = prefill_cross_cache(cfg, params, model.init_cache(b, s), enc_out)
    step = jax.jit(model.decode_step)
    got = []
    for t in range(s):
        logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    agree = (np.argmax(np.asarray(got), -1)
             == np.argmax(np.asarray(ref_logits), -1)).mean()
    assert agree > 0.9, agree


def test_moe_routing_respects_capacity(rng):
    from repro.configs.base import MoEConfig
    from repro.models.moe import _capacity, route

    cfg = get_config("arctic_480b", smoke=True)
    mc = cfg.moe
    logits = jnp.asarray(rng.normal(size=(2, 64, mc.n_experts)), jnp.float32)
    cap = _capacity(mc, 64)
    eidx, probs, slot, keep, aux = route(mc, logits, cap)
    assert bool((slot[keep] < cap).all())
    assert float(aux) > 0
    # every kept (expert, slot) pair is unique within a batch row
    for b in range(2):
        pairs = set()
        e = np.asarray(eidx[b]); s_ = np.asarray(slot[b]); k_ = np.asarray(keep[b])
        for t in range(64):
            for j in range(mc.top_k):
                if k_[t, j]:
                    pair = (int(e[t, j]), int(s_[t, j]))
                    assert pair not in pairs
                    pairs.add(pair)


def test_rwkv_chunked_matches_stepwise(rng):
    """Chunked-parallel WKV == sequential decode recurrence over a stream."""
    from repro.models.rwkv6 import wkv6_chunked, wkv6_step

    b, t, h, k = 1, 32, 2, 8
    r = jnp.asarray(rng.normal(size=(b, t, h, k)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, t, h, k)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, h, k)), jnp.float32)
    lw = -jnp.exp(jnp.asarray(rng.normal(size=(b, t, h, k)), jnp.float32) - 1)
    u = jnp.asarray(rng.normal(size=(h, k)), jnp.float32)
    state0 = jnp.zeros((b, h, k, k), jnp.float32)
    out_c, state_c = wkv6_chunked(r, kk, v, lw, u, state0, 8)
    state = state0
    outs = []
    for i in range(t):
        o, state = wkv6_step(r[:, i], kk[:, i], v[:, i], lw[:, i], u, state)
        outs.append(o)
    out_s = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state_c), np.asarray(state),
                               rtol=1e-4, atol=1e-4)


def test_mamba2_chunked_matches_stepwise(rng):
    from repro.models.mamba2 import ssd_chunked, ssd_step

    b, t, h, p, n = 1, 32, 2, 8, 4
    x = jnp.asarray(rng.normal(size=(b, t, h, p)), jnp.float32)
    a = -jnp.abs(jnp.asarray(rng.normal(size=(b, t, h)), jnp.float32))
    bb = jnp.asarray(rng.normal(size=(b, t, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, t, n)), jnp.float32)
    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    y_c, s_c = ssd_chunked(x, a, bb, cc, state0, 8)
    state = state0
    ys = []
    for i in range(t):
        y, state = ssd_step(x[:, i], a[:, i], bb[:, i], cc[:, i], state)
        ys.append(y)
    y_s = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(state),
                               rtol=1e-4, atol=1e-4)
