"""Relic host runtime + SPSC ring semantics (paper §VI)."""

import threading
import time

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.relic import Relic, RelicUsageError
from repro.core.spsc import SpscRing


# ---------------------------------------------------------------- SPSC ring

@given(st.lists(st.integers(), max_size=300),
       st.integers(min_value=1, max_value=64))
@settings(deadline=None, max_examples=50)
def test_spsc_fifo_property(items, capacity):
    """Single-threaded FIFO + capacity invariants for any push/pop schedule."""
    ring = SpscRing(capacity)
    out = []
    pending = list(items)
    while pending or len(ring):
        pushed = False
        if pending and ring.push(pending[0]):
            pending.pop(0)
            pushed = True
        if not pushed or len(ring) > capacity // 2:
            got = ring.pop()
            if got is not None:
                out.append(got)
        assert len(ring) <= capacity
    assert out == items


@pytest.mark.parametrize("capacity", [1, 2, 3, 128])
def test_spsc_wraparound_past_capacity_multiples(capacity):
    """Head/tail are monotonically increasing counters: behaviour must be
    identical long after the indices pass several capacity multiples."""
    ring = SpscRing(capacity)
    n = capacity * 7 + 3  # lands mid-window, several wraps in
    sent = 0
    got = []
    while len(got) < n:
        while sent < n and ring.push(sent):
            sent += 1
        assert len(ring) <= capacity
        item = ring.pop()
        if item is not None:
            got.append(item)
    assert got == list(range(n))
    assert ring.empty() and not ring.full()
    # counters sit far past capacity; a fresh cycle still behaves
    assert ring.push("x") and ring.pop() == "x" and ring.pop() is None


def test_spsc_capacity_one_edge_case():
    """capacity=1 alternates strictly full/empty — the tightest schedule."""
    ring = SpscRing(1)
    assert ring.pop() is None
    for i in range(10):
        assert ring.push(i)
        assert ring.full() and not ring.push(99)  # one slot only
        assert len(ring) == 1
        assert ring.pop() == i
        assert ring.empty() and ring.pop() is None


@pytest.mark.parametrize("capacity", [1, 2, 7])
def test_spsc_concurrent_1p1c_fifo_no_loss(capacity):
    """One producer + one consumer interleaving arbitrarily: FIFO order is
    preserved and no item is lost or duplicated, even at capacity 1."""
    ring = SpscRing(capacity)
    n = 20_000
    out = []
    stop = threading.Event()

    def consumer():
        while len(out) < n and not stop.is_set():
            item = ring.pop()
            if item is not None:
                out.append(item)
            else:
                time.sleep(0)

    t = threading.Thread(target=consumer)
    t.start()
    try:
        i = 0
        while i < n:
            if ring.push(i):
                i += 1
            else:
                time.sleep(0)
        t.join(30)
    finally:
        stop.set()
        t.join(5)
    assert out == list(range(n))


@given(st.data(), st.integers(min_value=1, max_value=8))
@settings(deadline=None, max_examples=30)
def test_spsc_property_any_interleaving_is_fifo(data, capacity):
    """Model-based check: under ANY single-threaded push/pop interleaving
    (chosen by hypothesis), the ring agrees with an ideal FIFO of the same
    capacity, including across many wraparounds."""
    ring = SpscRing(capacity)
    model: list = []
    next_item = 0
    for _ in range(data.draw(st.integers(10, 200))):
        if data.draw(st.booleans()):
            pushed = ring.push(next_item)
            assert pushed == (len(model) < capacity)
            if pushed:
                model.append(next_item)
                next_item += 1
        else:
            got = ring.pop()
            assert got == (model.pop(0) if model else None)
        assert len(ring) == len(model)
        assert ring.empty() == (not model)
        assert ring.full() == (len(model) == capacity)


@given(st.data(), st.integers(min_value=1, max_value=8))
@settings(deadline=None, max_examples=30)
def test_spsc_property_batched_ops_match_fifo(data, capacity):
    """Model-based check for the batch paths: under ANY single-threaded
    interleaving of push/pop/push_many/pop_many (chosen by hypothesis), the
    ring agrees with an ideal FIFO — push_many accepts exactly the free
    slots, pop_many returns exactly the available items (up to its cap),
    and the cached head/tail snapshots never change observable behaviour."""
    ring = SpscRing(capacity)
    model: list = []
    next_item = 0
    for _ in range(data.draw(st.integers(10, 150))):
        op = data.draw(st.sampled_from(
            ["push", "pop", "push_many", "pop_many"]))
        if op == "push":
            pushed = ring.push(next_item)
            assert pushed == (len(model) < capacity)
            if pushed:
                model.append(next_item)
                next_item += 1
        elif op == "pop":
            got = ring.pop()
            assert got == (model.pop(0) if model else None)
        elif op == "push_many":
            k = data.draw(st.integers(0, capacity + 2))
            items = list(range(next_item, next_item + k))
            pushed = ring.push_many(items)
            assert pushed == min(k, capacity - len(model))
            model.extend(items[:pushed])
            next_item += pushed
        else:
            cap = data.draw(st.one_of(st.none(),
                                      st.integers(0, capacity + 2)))
            got = ring.pop_many(cap)
            want_n = len(model) if cap is None else min(cap, len(model))
            assert got == model[:want_n]
            del model[:want_n]
        assert len(ring) == len(model)
        assert ring.empty() == (not model)
        assert ring.full() == (len(model) == capacity)


@given(st.lists(st.integers(1, 7), min_size=1, max_size=60),
       st.integers(min_value=1, max_value=8))
@settings(deadline=None, max_examples=30)
def test_spsc_property_push_many_pop_many_roundtrip(chunks, capacity):
    """Feeding arbitrary chunk sizes through push_many while pop_many drains
    opportunistically preserves FIFO with no loss or duplication, across
    many wraparounds (the cached snapshots go stale and refresh)."""
    ring = SpscRing(capacity)
    sent = 0
    out = []
    for k in chunks:
        items = list(range(sent, sent + k))
        pos = 0
        while pos < k:
            pos += ring.push_many(items, pos)   # offset retry: no tail copy
            if pos < k:          # full: drain a burst, then keep pushing
                out.extend(ring.pop_many())
        sent += k
    out.extend(ring.pop_many())
    assert out == list(range(sent))
    assert ring.empty()


@pytest.mark.parametrize("capacity", [1, 2, 7])
def test_spsc_concurrent_batched_1p1c_fifo_no_loss(capacity):
    """push_many producer + pop_many consumer interleaving across threads:
    FIFO order preserved, nothing lost or duplicated, even at capacity 1
    (where every batch degenerates to single-slot hand-offs)."""
    ring = SpscRing(capacity)
    n = 20_000
    out = []
    stop = threading.Event()

    def consumer():
        while len(out) < n and not stop.is_set():
            got = ring.pop_many()
            if got:
                out.extend(got)
            else:
                time.sleep(0)

    t = threading.Thread(target=consumer)
    t.start()
    try:
        i = 0
        while i < n:
            batch = list(range(i, min(i + 13, n)))
            pos = 0
            while pos < len(batch):
                pushed = ring.push_many(batch[pos:])
                if pushed:
                    pos += pushed
                else:
                    time.sleep(0)
            i += len(batch)
        t.join(30)
    finally:
        stop.set()
        t.join(5)
    assert out == list(range(n))


def test_spsc_push_many_accepts_tuple_and_empty():
    ring = SpscRing(4)
    assert ring.push_many(()) == 0
    assert ring.push_many((10, 11)) == 2
    assert ring.pop_many(1) == [10]
    assert ring.pop_many() == [11]
    assert ring.pop_many() == []


def test_spsc_push_many_start_offset():
    """The `start` offset pushes items[start:] without the caller slicing
    (the backpressure retry path for bursts larger than the ring)."""
    ring = SpscRing(3)
    items = [0, 1, 2, 3, 4]
    assert ring.push_many(items) == 3
    assert ring.push_many(items, 3) == 0          # full: nothing, no copy
    assert ring.pop_many(2) == [0, 1]
    assert ring.push_many(items, 3) == 2
    assert ring.pop_many() == [2, 3, 4]
    assert ring.push_many(items, 5) == 0          # exhausted offset: no-op
    assert ring.push_many(items, 7) == 0          # overshot offset: no rewind
    assert len(ring) == 0 and ring.empty()


def test_spsc_pop_many_nonpositive_budget_is_a_noop():
    """A zero/negative max_items must not rewind _head (regression: a
    negative budget used to move the head backwards, resurrecting cleared
    slots and re-delivering items)."""
    ring = SpscRing(4)
    assert ring.push_many((1, 2)) == 2
    assert ring.pop_many(0) == []
    assert ring.pop_many(-1) == []
    assert len(ring) == 2
    assert ring.pop_many() == [1, 2]
    assert ring.pop_many(-5) == [] and ring.empty()


def test_spsc_full_empty():
    ring = SpscRing(2)
    assert ring.pop() is None
    assert ring.push(1) and ring.push(2)
    assert not ring.push(3)           # full
    assert ring.pop() == 1
    assert ring.push(3)
    assert [ring.pop(), ring.pop()] == [2, 3]
    assert ring.empty()


def test_spsc_threaded_fifo():
    ring = SpscRing(8)
    n = 5000
    out = []

    def consumer():
        while len(out) < n:
            item = ring.pop()
            if item is not None:
                out.append(item)
            else:
                time.sleep(0)

    t = threading.Thread(target=consumer)
    t.start()
    i = 0
    while i < n:
        if ring.push(i):
            i += 1
        else:
            time.sleep(0)
    t.join(10)
    assert out == list(range(n))


# ------------------------------------------------------------ Relic runtime

def test_relic_runs_tasks_in_order():
    out = []
    with Relic() as rt:
        rt.wake_up_hint()
        for i in range(500):
            rt.submit(out.append, i)
        rt.wait()
    assert out == list(range(500))  # single consumer => submit order


def test_relic_rejects_assistant_submit():
    """Paper §VI-A: the assistant thread cannot submit (no recursion)."""
    errs = []
    with Relic(start_awake=True) as rt:
        def recursive():
            try:
                rt.submit(lambda: None)
            except RelicUsageError as e:
                errs.append(e)

        rt.submit(recursive)
        rt.wait()
    assert len(errs) == 1


def test_relic_rejects_foreign_thread():
    with Relic(start_awake=True) as rt:
        rt.submit(lambda: None)
        rt.wait()
        err = []

        def other():
            try:
                rt.submit(lambda: None)
            except RelicUsageError as e:
                err.append(e)

        t = threading.Thread(target=other)
        t.start()
        t.join()
        assert err


def test_relic_task_error_surfaces_at_wait():
    with Relic(start_awake=True) as rt:
        rt.submit(lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            rt.wait()


def test_relic_first_error_wins_not_last():
    """Regression: ``stats.last_error`` was overwritten per failure, so
    ``wait()`` raised the LAST error while the SPI (docs/schedulers.md and
    every other substrate) documents first-error-wins."""
    with Relic(start_awake=True) as rt:
        rt.submit(lambda: (_ for _ in ()).throw(KeyError("first")))
        rt.submit(lambda: 1 / 0)
        rt.submit(lambda: (_ for _ in ()).throw(IndexError("last")))
        with pytest.raises(KeyError, match="first"):
            rt.wait()
        assert rt.stats.task_errors == 3
        rt.wait()  # cleared: nothing re-raises


def test_relic_shutdown_timeout_on_wedged_task_is_non_restartable():
    """Regression: ``shutdown(timeout)`` used to null the assistant even
    when ``join(timeout)`` expired, leaking the live thread — a subsequent
    ``start()`` would put a second consumer on the SPSC ring."""
    release = threading.Event()
    rt = Relic(start_awake=True).start()
    rt.submit(release.wait)           # wedge the assistant
    with pytest.raises(RelicUsageError, match="non-restartable"):
        rt.shutdown(timeout=0.1)
    with pytest.raises(RelicUsageError):
        rt.start()                    # no second consumer, ever
    with pytest.raises(RelicUsageError):
        rt.submit(lambda: None)       # still shut down
    release.set()                     # un-wedge; assistant observes shutdown
    rt.shutdown()                     # now exits cleanly (and is idempotent)
    rt.shutdown()


def test_relic_sleep_hint_parks_assistant():
    rt = Relic(start_awake=False).start()   # asleep until hinted
    time.sleep(0.05)
    parked = rt.stats.parks
    assert parked >= 1
    spins_asleep = rt.stats.assistant_empty_spins
    time.sleep(0.05)
    # parked assistant must not burn spin iterations
    assert rt.stats.assistant_empty_spins == spins_asleep
    rt.wake_up_hint()
    out = []
    rt.submit(out.append, 1)
    rt.wait()
    assert out == [1]
    rt.shutdown()


def test_relic_submit_batch_runs_in_order_and_mixes_with_submit():
    out = []
    with Relic(start_awake=True) as rt:
        rt.submit(out.append, 0)
        rt.submit_batch([(out.append, (i,), {}) for i in range(1, 400)])
        rt.submit(out.append, 400)
        rt.wait()
    assert out == list(range(401))
    assert rt.stats.submitted == rt.stats.completed == 401


def test_relic_submit_batch_backpressures_past_capacity():
    """A burst several times the ring capacity must block-and-drain, not
    drop: the producer busy-waits on free slots (paper §VI-A bounded ring)."""
    out = []
    with Relic(capacity=4, start_awake=True) as rt:
        rt.submit_batch(
            [(lambda i=i: (time.sleep(0.0002), out.append(i)), (), {})
             for i in range(100)])
        rt.wait()
    assert out == list(range(100))
    assert rt.stats.producer_full_spins > 0


def test_relic_submit_batch_rejected_from_assistant():
    """Paper §VI-A: no recursive spawn — the batch entry point included."""
    errs = []
    with Relic(start_awake=True) as rt:
        def recursive():
            try:
                rt.submit_batch([(lambda: None, (), {})])
            except RelicUsageError as e:
                errs.append(e)

        rt.submit(recursive)
        rt.wait()
    assert len(errs) == 1


def test_relic_submit_batch_unparks_a_sleeping_assistant():
    """Advisory hints must not deadlock a full-ring burst (§VI-B rule)."""
    out = []
    with Relic(capacity=2) as rt:     # starts parked (start_awake=False)
        time.sleep(0.02)
        rt.submit_batch([(out.append, (i,), {}) for i in range(20)])
        rt.wait()
    assert out == list(range(20))


def test_relic_backpressure_capacity():
    """Producer busy-waits when the bounded ring is full, never drops."""
    out = []
    with Relic(capacity=4, start_awake=True) as rt:
        for i in range(100):
            rt.submit(lambda i=i: (time.sleep(0.0005), out.append(i)))
        rt.wait()
    assert out == list(range(100))
    assert rt.stats.submitted == rt.stats.completed == 100


def test_relic_wait_clears_error_index_with_the_error():
    """PR 6 bugfix regression: ``wait()`` used to clear ``last_error`` but
    leave ``stats.first_error_index`` stale, so the next window's error
    could be ordered against a dead index (the pool maps these indexes to
    pool-global submission seqs — a stale one mis-orders cross-lane
    first-error-wins). Both fields are one unit: they clear together."""
    with Relic(start_awake=True) as rt:
        rt.submit(lambda: None)
        rt.submit(lambda: (_ for _ in ()).throw(KeyError("w1")))
        with pytest.raises(KeyError, match="w1"):
            rt.wait()
        assert rt.stats.last_error is None
        assert rt.stats.first_error_index is None      # pre-fix: stale 1
        assert rt.stats.first_error_handoff_index is None
        # A fresh window's first failure gets a fresh index.
        rt.submit(lambda: None)
        rt.submit(lambda: None)
        rt.submit(lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            rt.wait()
        assert rt.stats.first_error_index is None
        assert rt.stats.task_errors == 2


def test_relic_submit_accounts_only_after_the_push_lands(monkeypatch):
    """Interrupt safety on the pair: ``submitted`` commits after the ring
    accepts the task, so a BaseException unwinding out of a full-ring
    spin leaves ``submitted`` == tasks actually delivered and the next
    ``wait()`` terminates instead of spinning for a phantom task."""
    import repro.core.relic as relic_mod

    class _RaisingTime:
        def __init__(self):
            self.fired = False
        def sleep(self, seconds):
            if not self.fired:
                self.fired = True
                raise KeyboardInterrupt
        def __getattr__(self, name):
            return getattr(time, name)

    monkeypatch.setenv("RELIC_SPIN_PAUSE_EVERY", "1")
    gate = threading.Event()
    fake = _RaisingTime()
    with Relic(capacity=1, start_awake=True) as rt:
        popped = threading.Event()
        rt.submit(lambda: (popped.set(), gate.wait()))
        assert popped.wait(5)
        rt.submit(lambda: None)            # fills the 1-slot ring
        monkeypatch.setattr(relic_mod, "time", fake)
        with pytest.raises(KeyboardInterrupt):
            rt.submit(lambda: None)        # full ring -> spin -> interrupt
        assert fake.fired
        monkeypatch.setattr(relic_mod, "time", time)
        assert rt.stats.submitted == 2     # the un-pushed task is NOT counted
        gate.set()
        rt.wait()                          # terminates: no phantom task
        assert rt.stats.completed == 2
