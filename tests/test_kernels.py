"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Sweeps shapes (including non-aligned fallback paths) and dtypes per the
deliverable: every Pallas kernel is validated against ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _arr(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 2e-4, jnp.bfloat16: 2e-1}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 384, 512, 128, 128, 256),
    (512, 256, 1024, 256, 256, 512),
    (100, 60, 36, 128, 128, 128),      # unaligned -> ref fallback path
])
def test_relic_matmul(rng, dtype, m, n, k, bm, bn, bk):
    x = _arr(rng, (m, k), dtype)
    y = _arr(rng, (k, n), dtype)
    out = ops.matmul(x, y, bm=bm, bn=bn, bk=bk)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 50)


@pytest.mark.parametrize("act", ["silu", "gelu"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_relic_matmul_gated(rng, act, dtype):
    x = _arr(rng, (256, 256), dtype)
    wg = _arr(rng, (256, 128), dtype)
    wu = _arr(rng, (256, 128), dtype)
    out = ops.matmul_gated(x, wg, wu, act=act, bm=128, bn=128, bk=128)
    want = ref.matmul_gated_ref(x, wg, wu, act)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2.0 if dtype == jnp.bfloat16 else 2e-2)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,d,bq,bk", [
    (2, 128, 4, 4, 32, 64, 64),     # MHA
    (1, 256, 8, 2, 64, 128, 64),    # GQA 4:1
    (2, 128, 8, 1, 32, 64, 128),    # MQA
    (1, 96, 4, 2, 16, 64, 64),      # unaligned S -> fallback
])
def test_flash_attention(rng, causal, dtype, b, s, h, kv, d, bq, bk):
    q = _arr(rng, (b, s, h, d), dtype)
    k = _arr(rng, (b, s, kv, d), dtype)
    v = _arr(rng, (b, s, kv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    want = ref.attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                             v.swapaxes(1, 2), causal=causal).swapaxes(1, 2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 2e-4,
        atol=2e-2 if dtype == jnp.bfloat16 else 2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,k,chunk", [
    (2, 64, 2, 16, 16),
    (1, 128, 4, 32, 32),
    (2, 96, 2, 16, 32),             # 96 % 32 == 0
])
def test_wkv6_kernel(rng, dtype, b, t, h, k, chunk):
    r = _arr(rng, (b, t, h, k), dtype)
    kk = _arr(rng, (b, t, h, k), dtype)
    v = _arr(rng, (b, t, h, k), dtype)
    lw = -jnp.exp(_arr(rng, (b, t, h, k), jnp.float32))  # aggressive decays
    u = _arr(rng, (h, k), jnp.float32)
    out = ops.wkv6(r, kk, v, lw, u, chunk=chunk)
    want = ref.wkv6_ref(*(a.swapaxes(1, 2) for a in (r, kk, v, lw)),
                        u).swapaxes(1, 2)
    assert np.isfinite(np.asarray(out, np.float32)).all()
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-3,
        atol=2e-1 if dtype == jnp.bfloat16 else 1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,p,n,chunk", [
    (2, 64, 2, 16, 8, 16),
    (1, 128, 4, 32, 16, 32),
])
def test_ssd_kernel(rng, dtype, b, t, h, p, n, chunk):
    x = _arr(rng, (b, t, h, p), dtype)
    a = -jnp.abs(_arr(rng, (b, t, h), jnp.float32)) * 0.5
    bb = _arr(rng, (b, t, n), jnp.float32)
    cc = _arr(rng, (b, t, n), jnp.float32)
    out = ops.ssd(x, a, bb, cc, chunk=chunk)
    want = ref.ssd_ref(x.swapaxes(1, 2), a.swapaxes(1, 2), bb, cc).swapaxes(1, 2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-3,
        atol=2e-1 if dtype == jnp.bfloat16 else 1e-3)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 3),
       st.sampled_from([16, 32]))
@settings(deadline=None, max_examples=10)
def test_flash_attention_property(b, heads_per_kv, kv, d):
    """Property: flash == reference for arbitrary GQA groupings."""
    rng = np.random.default_rng(b * 100 + heads_per_kv * 10 + kv)
    h = heads_per_kv * kv
    s = 64
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    want = ref.attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                             v.swapaxes(1, 2), causal=True).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_matches_full(rng):
    """Model-level chunked (XLA flash) path == full attention."""
    from repro.models.attention import attention_chunked, attention_full

    q = _arr(rng, (2, 128, 4, 32), jnp.float32)
    k = _arr(rng, (2, 128, 2, 32), jnp.float32)
    v = _arr(rng, (2, 128, 2, 32), jnp.float32)
    for causal in (True, False):
        a = attention_chunked(q, k, v, causal=causal, chunk_q=32, chunk_k=64)
        b = attention_full(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_causal_skip_matches_full(rng):
    """Diagonal-band skipping is numerically exact for causal attention."""
    from repro.models.attention import attention_chunked, attention_full

    q = _arr(rng, (2, 192, 4, 16), jnp.float32)
    k = _arr(rng, (2, 192, 2, 16), jnp.float32)
    v = _arr(rng, (2, 192, 2, 16), jnp.float32)
    a = attention_chunked(q, k, v, causal=True, chunk_q=64, chunk_k=32,
                          causal_skip=True)
    b = attention_full(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
    # unrolled variant (dry-run cost accounting path) is identical too
    c = attention_chunked(q, k, v, causal=True, chunk_q=64, chunk_k=32,
                          causal_skip=True, full_unroll=True)
    np.testing.assert_allclose(np.asarray(c), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_prefix_lm_mask(rng):
    from repro.models.attention import attention_chunked, attention_full

    q = _arr(rng, (1, 64, 4, 16), jnp.float32)
    k = _arr(rng, (1, 64, 4, 16), jnp.float32)
    v = _arr(rng, (1, 64, 4, 16), jnp.float32)
    a = attention_full(q, k, v, causal=True, prefix_len=16)
    b = attention_chunked(q, k, v, causal=True, chunk_q=16, chunk_k=16,
                          prefix_len=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)
    # prefix positions attend bidirectionally: row 0 must differ from causal
    c = attention_full(q, k, v, causal=True)
    assert not np.allclose(np.asarray(a)[0, 0], np.asarray(c)[0, 0])
