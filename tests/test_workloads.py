"""Oracle conformance for ``repro.workloads``: every workload, every
execution variant, against its independent oracle — plus the regression
tests for the paper_kernels block-until-ready bug and its deprecation shim.

Workload instances are cached per (name, n_instances) at module scope:
inputs and jit warmup are paid once, and the variant tests reuse the same
compiled kernels (the jit cache is process-wide anyway).
"""

import json as json_mod
import warnings

import numpy as np
import pytest

from repro.core.schedulers import available_schedulers, make_scheduler
from repro.tasks import jsonparse
from repro.tasks.api import TaskScope
from repro.workloads import (PAPER_WORKLOADS, VARIANTS, WorkloadOracleError,
                             available_workloads, make_workload,
                             results_agree)

ALL = available_workloads()
_CACHE = {}


def workload(name, n_instances=None):
    key = (name, n_instances)
    if key not in _CACHE:
        _CACHE[key] = make_workload(name, n_instances=n_instances)
    return _CACHE[key]


def test_registry_covers_paper_and_growth():
    """The paper's seven kernels plus the two scenario-growth workloads."""
    assert set(PAPER_WORKLOADS) <= set(ALL)
    assert {"stencil", "histogram"} <= set(ALL)
    assert len(ALL) >= 9
    assert VARIANTS == ("serial", "paired", "chunked")
    with pytest.raises(ValueError, match="unknown workload"):
        make_workload("no-such-workload")


# ---------------------------------------------------- variant oracle coverage

@pytest.mark.parametrize("name", ALL)
def test_serial_passes_oracle(name):
    w = workload(name)
    w.check(w.serial())


@pytest.mark.parametrize("name", ALL)
def test_paired_matches_serial_and_oracle(name):
    w = workload(name)
    serial = w.serial()
    with TaskScope("relic") as scope:
        paired = w.paired(scope)
    w.check(paired)
    assert all(results_agree(s, p) for s, p in zip(serial, paired))


@pytest.mark.parametrize("name", ALL)
def test_chunked_matches_serial_and_oracle(name):
    w = workload(name)
    serial = w.serial()
    with TaskScope("condvar") as scope:
        chunked = w.chunked(scope, grain=1)
    w.check(chunked)
    assert all(results_agree(s, c) for s, c in zip(serial, chunked))


@pytest.mark.parametrize("sub", available_schedulers())
def test_all_variants_agree_on_every_substrate(sub):
    """One cheap workload (histogram), both offload variants, all five
    substrates: the variant results must be indistinguishable from serial
    no matter the scheduling structure underneath."""
    w = workload("histogram", n_instances=4)
    serial = w.serial()
    with TaskScope(sub) as scope:
        paired = w.paired(scope)
        chunked_fine = w.chunked(scope, grain=1)
        chunked_coarse = w.chunked(scope, grain=2)
    for got in (paired, chunked_fine, chunked_coarse):
        w.check(got)
        assert all(results_agree(s, g) for s, g in zip(serial, got))


def test_more_than_two_instances_chunked():
    """Worksharing past the paper's pair: 4 instances, every grain."""
    w = workload("stencil", n_instances=4)
    with TaskScope("relic") as scope:
        for grain in (1, 2, 4):
            w.check(w.chunked(scope, grain=grain))
        w.check(w.paired(scope))


def test_min_instances_enforced():
    with pytest.raises(ValueError, match=">= 2 instances"):
        make_workload("histogram", n_instances=1)


def test_oracle_actually_rejects():
    """check() must fail loudly on corrupted results — the oracle is the
    benchmark's correctness gate, so prove it has teeth."""
    w = workload("histogram")
    good = w.serial()
    with pytest.raises(WorkloadOracleError):
        w.check(good[:1])                      # wrong instance count
    bad = [np.asarray(good[0]).copy() for _ in good]
    bad[1][0] += 1                             # instances disagree
    with pytest.raises(WorkloadOracleError):
        w.check(bad)
    corrupt = [np.asarray(r).copy() + 1 for r in good]   # oracle mismatch
    with pytest.raises(WorkloadOracleError):
        w.check(corrupt)


def test_json_oracle_counts_crosscheck():
    """The json workload's oracle is jsonparse.oracle_counts: verify the
    cross-check end-to-end against the kernel output."""
    w = workload("json")
    structural, depth, ok = w.serial()[0]
    expected = jsonparse.oracle_counts(jsonparse.WIDGET_JSON)
    assert bool(ok)
    assert int(np.asarray(structural).sum()) == expected["structural"]
    assert int(np.asarray(depth).max()) == expected["max_depth"]
    # and the check_one path itself accepts the kernel's own output
    w.check_one((structural, depth, ok))


# ------------------------------------------- block-until-ready regression

def test_task_closures_return_ready_results():
    """Regression (paper_kernels._pair): task closures must block until the
    result is ready, so paired-task timings measure compute, not async
    dispatch. bc is the heaviest kernel — an unblocked dispatch would
    still be in flight here."""
    w = workload("bc")
    for task in w.tasks:
        out = task()
        assert out.is_ready()


def test_paper_kernels_shim_is_deprecated_and_blocking():
    from benchmarks.paper_kernels import build_tasks

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        tasks = build_tasks()
    assert any(issubclass(c.category, DeprecationWarning) for c in caught)
    assert set(tasks) == set(PAPER_WORKLOADS)
    task_a, task_b, fused = tasks["bc"]
    assert task_a().is_ready() and task_b().is_ready()
    assert fused().is_ready()
    # historical contract: the json entry returns ONE scalar array
    # (structural.sum() + depth[-1] + ok), not the workload's raw tuple
    ja, jb, jf = tasks["json"]
    out = ja()
    assert out.shape == () and out.block_until_ready() is out
    assert float(ja()) == float(jb())
    assert jf().shape == (2,)  # fused: one value per instance


# ------------------------------------------------- the trajectory gate logic

def test_compare_against_flags_only_real_regressions(tmp_path, capsys):
    from benchmarks.run import Emitter, compare_against, load_baseline

    payload = {
        "meta": {"cpu_count": 2, "spin_pause_every": 1, "python": "3.10"},
        "sections": {"paper": [
            {"name": "paper/bc/serial", "us_per_call": 100.0, "derived": ""},
            {"name": "paper/bc/paired/relic", "us_per_call": 50.0,
             "derived": ""},
            {"name": "paper/gone/serial", "us_per_call": 1.0, "derived": ""},
        ]},
    }
    path = tmp_path / "BENCH_base.json"
    path.write_text(json_mod.dumps(payload))
    baseline = load_baseline(str(path))

    em = Emitter()
    em.sections = {"paper": [
        {"name": "paper/bc/serial", "us_per_call": 101.0, "derived": ""},
        {"name": "paper/bc/paired/relic", "us_per_call": 80.0, "derived": ""},
        {"name": "paper/new/serial", "us_per_call": 5.0, "derived": ""},
    ]}
    compared, regs = compare_against(em, baseline, tol=0.25)
    assert compared == 2
    assert [r["name"] for r in regs] == ["paper/bc/paired/relic"]
    assert regs[0]["ratio"] == pytest.approx(1.6)
    out = capsys.readouterr().out
    assert "REGRESSION paper/bc/paired/relic" in out

    em2 = Emitter()
    em2.sections = {"paper": [
        {"name": "paper/bc/serial", "us_per_call": 101.0, "derived": ""}]}
    assert compare_against(em2, baseline, tol=0.25) == (1, [])


def test_compare_against_speedup_metric_cancels_host_drift(tmp_path, capsys):
    """metric='speedup' gates on the recorded speedup-over-serial: a run
    that is uniformly 2x slower in absolute µs (host drift) passes, a row
    that actually *lost* speedup is flagged, and rows without a speedup on
    both sides are skipped rather than miscompared."""
    from benchmarks.run import Emitter, compare_against, load_baseline

    payload = {
        "meta": {"cpu_count": 2, "spin_pause_every": 1, "python": "3.10"},
        "sections": {"paper": [
            {"name": "paper/bc/serial", "us_per_call": 100.0,
             "derived": "n=2;speedup=1.000;oracle=ok"},
            {"name": "paper/bc/paired/relic", "us_per_call": 125.0,
             "derived": "speedup=0.800;oracle=ok"},
            {"name": "paper/bc/chunked/relic", "us_per_call": 125.0,
             "derived": "speedup=0.800;oracle=ok"},
            {"name": "paper/bc/paired/spin", "us_per_call": 125.0,
             "derived": "no-speedup-here"},
        ]},
    }
    path = tmp_path / "BENCH_base.json"
    path.write_text(json_mod.dumps(payload))
    baseline = load_baseline(str(path))

    em = Emitter()
    em.sections = {"paper": [
        # host 2x slower across the board...
        {"name": "paper/bc/serial", "us_per_call": 200.0,
         "derived": "n=2;speedup=1.000;oracle=ok"},
        # ...same relative speedup: NOT a regression under the metric
        {"name": "paper/bc/paired/relic", "us_per_call": 250.0,
         "derived": "speedup=0.800;oracle=ok"},
        # ...but this row genuinely lost speedup (0.8 -> 0.5)
        {"name": "paper/bc/chunked/relic", "us_per_call": 400.0,
         "derived": "speedup=0.500;oracle=ok"},
        # no speedup on the baseline side: skipped, never compared
        {"name": "paper/bc/paired/spin", "us_per_call": 999.0,
         "derived": "speedup=0.100"},
    ]}
    compared, regs = compare_against(em, baseline, tol=0.25,
                                     metric="speedup")
    assert compared == 3          # serial + the two relic rows
    assert [r["name"] for r in regs] == ["paper/bc/chunked/relic"]
    assert regs[0]["ratio"] == pytest.approx(1.6)
    out = capsys.readouterr().out
    assert "REGRESSION paper/bc/chunked/relic: speedup 0.800 -> 0.500" in out
    assert "metric speedup" in out

    # the same run under metric='us' flags every drifted row instead
    compared_us, regs_us = compare_against(em, baseline, tol=0.25)
    assert {r["name"] for r in regs_us} >= {
        "paper/bc/serial", "paper/bc/paired/relic"}

    # total collapse must fail the gate loudly, not drop out of it: a cell
    # whose recorded speedup rounds to 0.000 is a (huge) regression
    em3 = Emitter()
    em3.sections = {"paper": [
        {"name": "paper/bc/paired/relic", "us_per_call": 1e6,
         "derived": "speedup=0.000;oracle=ok"}]}
    compared3, regs3 = compare_against(em3, baseline, tol=0.25,
                                       metric="speedup")
    assert compared3 == 1
    assert [r["name"] for r in regs3] == ["paper/bc/paired/relic"]
    assert regs3[0]["ratio"] > 1000


def test_compare_gate_fails_closed(tmp_path, capsys):
    """A gate that gates nothing must fail: zero shared rows is an error,
    and a missing/invalid baseline dies before any timing would run."""
    from benchmarks.run import Emitter, compare_against, load_baseline

    path = tmp_path / "BENCH_other.json"
    path.write_text(json_mod.dumps({"sections": {"spsc": [
        {"name": "spsc/overhead/relic/single", "us_per_call": 1.0,
         "derived": ""}]}}))
    em = Emitter()
    em.sections = {"paper": [
        {"name": "paper/bc/serial", "us_per_call": 100.0, "derived": ""}]}
    compared, regs = compare_against(em, load_baseline(str(path)), tol=0.25)
    assert compared == 0 and regs == []
    assert "FAILED" in capsys.readouterr().out

    with pytest.raises(FileNotFoundError):
        load_baseline(str(tmp_path / "missing.json"))
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    with pytest.raises(SystemExit):
        load_baseline(str(bad))


# ------------------------------------------------- skewed task costs (PR 6)

_SKEW_CACHE = {}


def skewed(name, n_instances=4, skew=1.0, skew_seed=0):
    key = (name, n_instances, skew, skew_seed)
    if key not in _SKEW_CACHE:
        _SKEW_CACHE[key] = make_workload(
            name, n_instances=n_instances, skew=skew, skew_seed=skew_seed)
    return _SKEW_CACHE[key]


def test_skew_repeats_follow_a_seeded_power_law():
    """The cost profile is Zipf-by-rank (heaviest repeats n, rank r costs
    ~r**-alpha of it, floor 1), its placement is a seeded shuffle, and the
    whole thing is deterministic per (skew, seed)."""
    w = skewed("histogram", n_instances=8, skew=1.0, skew_seed=0)
    assert sorted(w.repeats, reverse=True) == [8, 4, 3, 2, 2, 1, 1, 1]
    again = make_workload("histogram", n_instances=8, skew=1.0, skew_seed=0)
    assert again.repeats == w.repeats                  # deterministic
    other = make_workload("histogram", n_instances=8, skew=1.0, skew_seed=7)
    assert sorted(other.repeats) == sorted(w.repeats)  # same multiset...
    assert other.repeats != w.repeats                  # ...different layout
    heavier = make_workload("histogram", n_instances=8, skew=2.0)
    assert max(heavier.repeats) == 8
    assert sum(heavier.repeats) < sum(w.repeats)       # steeper tail decay
    flat = make_workload("histogram", n_instances=8)
    assert flat.repeats == [1] * 8                     # skew=None: uniform
    assert "skew" in repr(w) and "skew" not in repr(flat)
    with pytest.raises(ValueError, match="positive exponent"):
        make_workload("histogram", n_instances=8, skew=0.0)


@pytest.mark.parametrize("name", ALL)
def test_skewed_serial_passes_oracle(name):
    """Skew changes the cost profile, never the results: every workload's
    skewed run must pass the same oracle as the uniform one."""
    w = skewed(name)
    assert max(w.repeats) == 4 and min(w.repeats) == 1
    w.check(w.serial())


@pytest.mark.parametrize("lanes", [1, 2, 4])
@pytest.mark.parametrize("name", ALL)
def test_skewed_chunked_passes_oracle_at_every_lane_count(name, lanes):
    """The benchmark's skew section shape: chunked execution of a skewed
    workload over a RelicPool, oracle-checked at lanes 1/2/4 (lanes >= 2
    exercises the rebalancing machinery end-to-end under real kernels)."""
    w = skewed(name)
    serial = w.serial()
    with TaskScope(make_scheduler("relic-pool", lanes=lanes)) as scope:
        chunked = w.chunked(scope, grain=1)
    w.check(chunked)
    assert all(results_agree(s, c) for s, c in zip(serial, chunked))


def test_skewed_chunked_static_striping_matches_rebalanced():
    """A/B integrity for the benchmark: rebalance=False (the PR 5 static
    pool) must produce identical results on the same skewed workload."""
    w = skewed("stencil")
    serial = w.serial()
    with TaskScope(make_scheduler("relic-pool", lanes=2,
                                  rebalance=False)) as scope:
        chunked = w.chunked(scope, grain=1)
    w.check(chunked)
    assert all(results_agree(s, c) for s, c in zip(serial, chunked))
