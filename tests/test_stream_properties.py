"""Model-based property tests for N-stage SPSC ring composition.

Hypothesis drives randomized pipeline shapes (stage count, ring
capacities, item counts, per-stage delays, farm fan-out, driver
backpressure patterns) and checks the streaming layer's core invariants:

* **exactly-once**: every item traverses every stage exactly once —
  counted per stage, not inferred from outputs;
* **per-stage FIFO**: each stage observes items in submission order
  (linear pipelines are FIFO end-to-end);
* **bounded buffers never deadlock**: tiny ring capacities plus
  randomized stage delays and bursty feeding still drain completely.

Uses the conftest hypothesis guard: when hypothesis is absent these
tests report as skips, not failures.
"""

import threading
import time

import pytest
from hypothesis import given, settings, strategies as st

from repro.stream import Farm, Pipeline, StreamFailure


@given(
    n_stages=st.integers(1, 4),
    capacity=st.integers(2, 8),
    n_items=st.integers(0, 60),
    delay_stage=st.integers(0, 4),   # which stage (if any) gets a stall
    seed=st.integers(0, 2**16),
)
@settings(deadline=None, max_examples=15)
def test_pipeline_exactly_once_fifo_no_deadlock(n_stages, capacity, n_items,
                                                delay_stage, seed):
    """Randomized linear pipelines: every item through every stage exactly
    once, in FIFO order, no deadlock at tiny capacities."""
    traces = [[] for _ in range(n_stages)]
    locks = [threading.Lock() for _ in range(n_stages)]

    def make_stage(k):
        def stage(x):
            if k == delay_stage and (x * 2654435761 + seed) % 7 == 0:
                time.sleep(0.001)   # pseudo-random stall, seed-dependent
            with locks[k]:          # test-side bookkeeping only
                traces[k].append(x)
            return x
        stage.__name__ = f"s{k}"
        return stage

    with Pipeline([make_stage(k) for k in range(n_stages)],
                  capacity=capacity) as pipe:
        out = pipe.run(list(range(n_items)))
    assert out == list(range(n_items))
    for k in range(n_stages):
        assert traces[k] == list(range(n_items)), f"stage {k} not FIFO/1x"


@given(
    capacity=st.integers(2, 6),
    n_items=st.integers(0, 50),
    burst=st.integers(1, 9),
    seed=st.integers(0, 2**16),
)
@settings(deadline=None, max_examples=15)
def test_put_get_bursts_never_deadlock(capacity, n_items, burst, seed):
    """Bursty driver patterns (attempt up to `burst` non-blocking puts,
    then drain one) against small rings: backpressure shows up as a failed
    put_nowait, never a stuck driver, and accounting stays exact. (A
    *blocking* put of more than the network's total capacity without
    draining would rightly wedge the driver — backpressure working as
    designed — so the burst feed must be non-blocking.)"""
    def work(x):
        if (x + seed) % 5 == 0:
            time.sleep(0.0005)
        return x + 100

    with Pipeline([work, lambda x: x - 100], capacity=capacity) as pipe:
        got, fed = [], 0
        while len(got) < n_items:
            for _ in range(burst):
                if fed < n_items and pipe.put_nowait(fed):
                    fed += 1
            if len(got) < fed:
                got.append(pipe.get())   # bounded blocking drain
        assert got == list(range(n_items))
        assert pipe.in_flight() == 0


@given(
    workers=st.integers(1, 4),
    capacity=st.integers(2, 6),
    n_items=st.integers(0, 40),
    seed=st.integers(0, 2**16),
)
@settings(deadline=None, max_examples=10)
def test_farm_exactly_once_ordered_release(workers, capacity, n_items, seed):
    """Randomized farms: round-robin deal + in-order collector release is
    exactly-once and order-preserving under skewed worker delays."""
    calls = []
    lock = threading.Lock()

    def work(x):
        if (x * 31 + seed) % 4 == 0:
            time.sleep(0.001)       # skew: some items run much longer
        with lock:
            calls.append(x)
        return x * 3

    farm = Farm(work, workers=workers, capacity=capacity, ordered=True)
    with Pipeline([farm], capacity=capacity) as pipe:
        out = pipe.run(list(range(n_items)))
    assert out == [i * 3 for i in range(n_items)]       # ordered release
    assert sorted(calls) == list(range(n_items))        # exactly-once


@given(
    n_stages=st.integers(1, 3),
    fail_every=st.integers(2, 7),
    n_items=st.integers(1, 40),
)
@settings(deadline=None, max_examples=10)
def test_failures_keep_slot_accounting(n_stages, fail_every, n_items):
    """Markers occupy exactly the failed item's slot on any shape: one
    output per input, failures in place, successes untouched."""
    def head(x):
        if x % fail_every == 0:
            raise ValueError(x)
        return x

    with Pipeline([head] + [lambda x: x] * (n_stages - 1)) as pipe:
        out = pipe.run(list(range(n_items)), raw=True)
    assert len(out) == n_items
    for i, o in enumerate(out):
        if i % fail_every == 0:
            assert type(o) is StreamFailure
            assert o.error.args == (i,)
        else:
            assert o == i


@pytest.mark.parametrize("substrate", ["serial", "relic"])
def test_inline_and_threaded_agree(substrate):
    """The inline degradation is an exact model of the threaded network."""
    with Pipeline([lambda x: x + 1, lambda x: x * 2],
                  substrate=substrate) as pipe:
        assert pipe.run(list(range(30))) == [(i + 1) * 2 for i in range(30)]
