"""The streaming dataflow layer (repro.stream) and its three consumers.

Covers: Pipeline/Stage/Farm basics (exactly-once, FIFO, in-order farm
release, inline degradation), in-stream failure markers, supervision
(dead-stage detection + ``RELIC_SUPERVISE=0`` opt-out), the structural
"no locks, SPSC-only" pins the PR acceptance demands, TaskGraph
``streaming=True`` parity with the barriered wavefront baseline (plus the
overlap a wavefront cannot express), the rebuilt PrefetchPipeline /
CheckpointManager (killed-assistant regression, overlapped saves), and
the oracle-checked ``Workload.streamed()`` variants on every substrate
including the chaos harness.
"""

import inspect
import threading
import time

import numpy as np
import pytest

import repro.stream.farm as farm_mod
import repro.stream.pipeline as pipeline_mod
import repro.stream.stage as stage_mod
from repro.core.relic import RelicDeadError
from repro.core.schedulers import available_schedulers, make_scheduler
from repro.data import DataConfig, PrefetchPipeline, SyntheticLM
from repro.stream import (Farm, Pipeline, Stage, StreamError, StreamFailure,
                          StreamUsageError, worker_alive)
from repro.tasks.api import TaskCancelledError, TaskGraph
from repro.workloads import make_workload

ALL = available_schedulers()


# ------------------------------------------------------------ pipeline basics

def test_pipeline_exactly_once_and_fifo():
    """Every item traverses every stage exactly once, in order."""
    seen = []
    with Pipeline([lambda x: x + 1,
                   lambda x: x * 10,
                   lambda x: (seen.append(x), x)[1]]) as pipe:
        out = pipe.run(list(range(50)))
    assert out == [(i + 1) * 10 for i in range(50)]
    assert seen == out  # stage 3 saw them in FIFO order


def test_pipeline_put_get_interleaved():
    with Pipeline([lambda x: x * 2]) as pipe:
        for i in range(10):
            pipe.put(i)
        got = [pipe.get() for _ in range(10)]
    assert got == [i * 2 for i in range(10)]


def test_pipeline_serial_substrate_runs_inline():
    """A workers==0 node degrades the whole network to inline execution on
    the driver — same results, no threads."""
    before = threading.active_count()
    with Pipeline([lambda x: x + 1, lambda x: x * 3],
                  substrate="serial") as pipe:
        assert pipe.inline
        assert threading.active_count() == before
        out = pipe.run(list(range(20)))
    assert out == [(i + 1) * 3 for i in range(20)]


def test_pipeline_scheduler_instance_fuses_stages():
    """A Scheduler *instance* substrate hosts one loop: callable stages are
    composed into a single node on it."""
    with Pipeline([lambda x: x + 1, lambda x: x * 2],
                  substrate=make_scheduler("relic")) as pipe:
        assert len(pipe.nodes) == 1
        out = pipe.run([1, 2, 3])
    assert out == [4, 6, 8]


def test_pipeline_stats_and_in_flight():
    with Pipeline([lambda x: x]) as pipe:
        pipe.run(list(range(7)))
        stats = pipe.stats()
        assert stats[0]["items_in"] == 7 and stats[0]["items_out"] == 7
        assert pipe.in_flight() == 0


def test_pipeline_get_without_feed_is_usage_error():
    with Pipeline([lambda x: x]) as pipe:
        with pytest.raises(StreamUsageError):
            pipe.get_raw()


# ------------------------------------------------------------------- failures

def test_failure_marker_flows_not_kills():
    """A stage exception becomes an in-stream marker; later items still
    flow, and get() surfaces the original as __cause__."""
    def boom(x):
        if x == 3:
            raise ValueError("item three")
        return x * 10

    with Pipeline([boom, lambda x: x + 1]) as pipe:
        outs, errs = [], []
        for i in range(6):
            pipe.put(i)
        for _ in range(6):
            try:
                outs.append(pipe.get())
            except StreamError as e:
                errs.append(e)
    assert outs == [1, 11, 21, 41, 51]
    (err,) = errs
    assert isinstance(err.__cause__, ValueError)
    assert "boom" in str(err)


def test_run_preserves_slot_accounting_with_failures():
    """One-in/one-out even when some items fail: run() returns a marker in
    the failed slot, everything else unscathed."""
    def maybe(x):
        if x % 4 == 0:
            raise RuntimeError(f"no {x}")
        return x

    with Pipeline([maybe]) as pipe:
        out = pipe.run(list(range(12)), raw=True)
    assert len(out) == 12
    for i, o in enumerate(out):
        if i % 4 == 0:
            assert type(o) is StreamFailure
            assert str(o.error) == f"no {i}"
        else:
            assert o == i


# ----------------------------------------------------------------------- farm

@pytest.mark.parametrize("ordered", [True, False])
def test_farm_all_items_once(ordered):
    f = Farm(lambda x: x * x, workers=3, ordered=ordered)
    with Pipeline([f]) as pipe:
        out = pipe.run(list(range(40)))
    if ordered:
        assert out == [i * i for i in range(40)]
    else:
        assert sorted(out) == [i * i for i in range(40)]


def test_farm_in_order_release_under_skew():
    """Ordered collector stashes early finishers until their index is due."""
    def slow_evens(x):
        if x % 2 == 0:
            time.sleep(0.002)
        return x

    with Pipeline([Farm(slow_evens, workers=4, ordered=True)]) as pipe:
        out = pipe.run(list(range(30)))
    assert out == list(range(30))


def test_farm_worker_exception_is_marker():
    f = Farm(lambda x: 1 // x, workers=2, ordered=True)
    with Pipeline([f]) as pipe:
        out = pipe.run([2, 1, 0, 4], raw=True)
    assert out[:2] == [0, 1]
    assert type(out[2]) is StreamFailure
    assert isinstance(out[2].error, ZeroDivisionError)
    assert out[3] == 0


def test_farm_composes_with_stages():
    """Mixed [fn, Farm, fn] network: rings stay 1P1C end to end."""
    with Pipeline([lambda x: x + 1,
                   Farm(lambda x: x * 2, workers=3, ordered=True),
                   lambda x: x - 1]) as pipe:
        out = pipe.run(list(range(25)))
    assert out == [(i + 1) * 2 - 1 for i in range(25)]


def test_farm_rejects_instance_substrate():
    with pytest.raises(StreamUsageError):
        Farm(lambda x: x, substrate=make_scheduler("relic"))


# ---------------------------------------------------------------- supervision

def test_dead_stage_raises_relic_dead_error():
    """SystemExit kills a stage loop (not a marker); the consumer's bounded
    wait notices and raises RelicDeadError chaining the original."""
    def die(x):
        if x == 2:
            raise SystemExit("stage killed")
        return x

    pipe = Pipeline([die]).start()
    try:
        for i in range(5):
            pipe.put(i)
        with pytest.raises(RelicDeadError) as ei:
            for _ in range(5):
                pipe.get_raw()
        assert isinstance(ei.value.__cause__, SystemExit)
    finally:
        pipe.close()    # cleanup is tolerant: never raises for dead stages


def test_supervise_opt_out(monkeypatch):
    """RELIC_SUPERVISE=0 disables liveness probing in stages, same switch
    as the substrate layer."""
    monkeypatch.setenv("RELIC_SUPERVISE", "0")
    st = Stage(lambda x: x, substrate="relic")
    try:
        assert st._probe_every == 0
    finally:
        st.close()
    monkeypatch.setenv("RELIC_SUPERVISE", "1")
    st = Stage(lambda x: x, substrate="relic")
    try:
        assert st._probe_every > 0
    finally:
        st.close()


def test_worker_alive_duck_typing():
    assert worker_alive(make_scheduler("serial")) is True
    sched = make_scheduler("relic")
    assert worker_alive(sched) is True  # not started yet -> not dead
    sched.start()
    try:
        assert worker_alive(sched) is True
    finally:
        sched.close()


# ----------------------------------------------------- structural lock pins

def test_stream_layer_has_no_locks():
    """Acceptance pin: no locks or MPMC queues anywhere on the streaming
    hot path — composition of 1P1C rings replaces them."""
    for mod in (stage_mod, pipeline_mod, farm_mod):
        src = inspect.getsource(mod)
        assert "Lock(" not in src, mod.__name__
        assert "queue.Queue" not in src, mod.__name__


def test_prefetch_pipeline_push_lock_is_gone():
    p = PrefetchPipeline(SyntheticLM(DataConfig(8, 4, 50)),
                         DataConfig(8, 4, 50))
    assert not hasattr(p, "_push_lock")
    assert "Lock" not in inspect.getsource(PrefetchPipeline)


def test_checkpoint_write_lock_is_gone(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(tmp_path, async_=False)
    assert not hasattr(mgr, "_write_lock")
    assert "Lock" not in inspect.getsource(CheckpointManager)


# ------------------------------------------------------- TaskGraph streaming

def _diamond():
    g = TaskGraph()
    g.task("a", lambda: 2)
    g.task("b", lambda a: a + 1, deps=("a",))
    g.task("c", lambda a: a * 10, deps=("a",))
    g.task("d", lambda b, c: b + c, deps=("b", "c"))
    return g


@pytest.mark.parametrize("name", ALL)
def test_taskgraph_streaming_matches_wavefront(name):
    want = _diamond().run(name, streaming=False)
    got = _diamond().run(name, streaming=True)
    assert got == want == {"a": 2, "b": 3, "c": 20, "d": 23}


def test_taskgraph_streaming_overlaps_wavefronts():
    """A deep independent chain completes while a slow sibling of its root
    still runs — the schedule a barriered wavefront cannot produce."""
    order = []
    g = TaskGraph()
    g.task("slow", lambda: (time.sleep(0.15), order.append("slow"))[0])
    prev = None
    for i in range(4):
        name = f"f{i}"
        # dep result is ignored; the chain just has to be sequential
        if prev is None:
            g.task(name, lambda i=i: order.append(f"f{i}"))
        else:
            g.task(name, lambda _x, i=i: order.append(f"f{i}"), deps=(prev,))
        prev = name
    g.run("relic", streaming=True)
    assert order.index("slow") == len(order) - 1


@pytest.mark.parametrize("streaming", [False, True])
def test_taskgraph_failure_and_cancellation_parity(streaming):
    g = TaskGraph()
    g.task("ok", lambda: 1)
    g.task("bad", lambda: 1 // 0)
    g.task("down", lambda bad: bad, deps=("bad",))
    with pytest.raises(ZeroDivisionError):
        g.run("relic", streaming=streaming)


def test_taskgraph_as_stream_alias():
    assert _diamond().as_stream("serial") == _diamond().run(
        "serial", streaming=True)


def test_taskgraph_streaming_cancelled_downstream():
    g = TaskGraph()
    g.task("bad", lambda: 1 // 0)
    g.task("down", lambda bad: bad, deps=("bad",))
    try:
        g.run("relic", streaming=True)
    except ZeroDivisionError:
        pass
    with pytest.raises(TaskCancelledError):
        g.handle("down").result()


# ------------------------------------------- rebuilt PrefetchPipeline (PR 8)

def test_prefetch_killed_assistant_raises_dead_error():
    """The PR 8 supervision gap, closed: a producer killed mid-stream (by a
    non-Exception BaseException) surfaces as RelicDeadError with loss
    diagnostics instead of an unbounded consumer spin."""
    class KilledSource:
        def __init__(self):
            self.dc = DataConfig(8, 4, 50, prefetch=2)

        def batch(self, index):
            if index >= 1:
                raise SystemExit("assistant killed")
            return {"tokens": np.zeros((4, 8), np.int32)}

    src = KilledSource()
    p = PrefetchPipeline(src, src.dc).start()
    try:
        with pytest.raises(RelicDeadError) as ei:
            for _ in range(4):
                p.next_batch()
        assert "dead" in str(ei.value)
        assert isinstance(ei.value.__cause__, SystemExit)
    finally:
        try:
            p.stop()
        except RelicDeadError:
            pass


def test_prefetch_transform_overlaps_as_second_stage():
    dc = DataConfig(8, 4, 50, prefetch=4)
    calls = []

    def tag(batch):
        calls.append(1)
        batch = dict(batch)
        batch["tagged"] = True
        return batch

    p = PrefetchPipeline(SyntheticLM(dc), dc, transform=tag).start()
    try:
        assert len(p._pipe.nodes) == 2  # produce + transform stages
        for _ in range(6):
            assert p.next_batch()["tagged"] is True
        assert len(calls) >= 6
    finally:
        p.stop()


# ------------------------------------------ rebuilt CheckpointManager (PR 9)

def test_checkpoint_overlapped_saves_land_in_order(tmp_path):
    """Back-to-back async saves overlap (serialize N+1 while N publishes)
    yet publish FIFO: retention keeps exactly the newest `keep`."""
    from repro.checkpoint import CheckpointManager
    state = {"w": np.arange(16, dtype=np.float32)}
    mgr = CheckpointManager(tmp_path, keep=2, async_=True)
    for s in range(6):
        mgr.save(state, s)
    mgr.close()
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000004", "step_00000005"]
    assert not list(tmp_path.glob("*.tmp*"))  # no leaked tmp dirs


# ------------------------------------------------- streamed workload oracles

@pytest.mark.parametrize("name", ["stencil", "json"])
@pytest.mark.parametrize("substrate", ["serial", "relic", "chaos"])
def test_streamed_workloads_pass_oracles(name, substrate):
    """streamed() is oracle-checked like every other variant — including
    under the chaos substrate's default stall plan."""
    w = make_workload(name)
    w.check(w.streamed(substrate))


def test_streamed_matches_serial_variant():
    w = make_workload("stencil")
    streamed = w.streamed("relic")
    serial = w.serial()
    for a, b in zip(streamed, serial):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
