"""Tests for the repro.serve subsystem: percentile math vs numpy,
seeded-loadgen determinism, the SPSC 1P1C contract, per-client FIFO,
mid-flight (barrier-free) admission, admission policies, deadline/SLO
surfacing, config resolution, the scan-prefill contract, and the
benchmarks section registry tripwire."""

import threading
import time

import numpy as np
import pytest

from repro.runtime.config import resolve_serve_config
from repro.serve import (
    STATUS_CANCELLED,
    STATUS_DEADLINE,
    STATUS_ERROR,
    STATUS_OK,
    Gauge,
    Ingest,
    Request,
    Response,
    ServeMetrics,
    ServeScheduler,
    ServeUsageError,
    nearest_rank,
    percentiles,
    poisson_arrivals,
    run_closed_loop,
    run_open_loop,
)


# ---------------------------------------------------------------------------
# percentile math: nearest-rank pinned against numpy's inverted_cdf
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("values", [
    [3.0],                              # n=1: every percentile is the sample
    [5.0, 1.0],                         # n=2: rank boundary at q=50
    [2.0, 2.0, 2.0, 2.0],               # all-equal
    [1.0, 1.0, 2.0, 2.0, 3.0],          # ties straddling ranks
    list(range(100)),                   # exact rank arithmetic at p50/p95/p99
    [0.1, 0.2, 0.2, 0.2, 0.9, 0.9, 7.0],
])
@pytest.mark.parametrize("q", [1, 25, 50, 90, 95, 99, 100])
def test_nearest_rank_matches_numpy_inverted_cdf(values, q):
    expected = np.percentile(np.asarray(values), q, method="inverted_cdf")
    assert nearest_rank(sorted(values), q) == pytest.approx(float(expected))


def test_nearest_rank_random_sample_matches_numpy():
    rng = np.random.default_rng(7)
    values = rng.exponential(size=237).tolist()
    ordered = sorted(values)
    for q in (50, 95, 99):
        expected = np.percentile(np.asarray(values), q,
                                 method="inverted_cdf")
        assert nearest_rank(ordered, q) == pytest.approx(float(expected))


def test_nearest_rank_edges():
    assert nearest_rank([4.0, 8.0], 0) == 4.0      # q=0 -> min
    assert nearest_rank([4.0, 8.0], 100) == 8.0
    with pytest.raises(ValueError):
        nearest_rank([], 50)
    with pytest.raises(ValueError):
        nearest_rank([1.0], 101)
    with pytest.raises(ValueError):
        nearest_rank([1.0], -1)


def test_percentiles_returns_observed_samples():
    p = percentiles([0.5, 0.1, 0.9], qs=(50, 95, 99))
    for v in p.values():
        assert v in (0.1, 0.5, 0.9)     # nearest-rank: always a real sample


# ---------------------------------------------------------------------------
# loadgen determinism
# ---------------------------------------------------------------------------

def test_poisson_arrivals_deterministic_per_seed():
    a = poisson_arrivals(100.0, 64, seed=42)
    b = poisson_arrivals(100.0, 64, seed=42)
    c = poisson_arrivals(100.0, 64, seed=43)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.shape == (64,)
    assert np.all(np.diff(a) >= 0)       # cumulative offsets are monotone


def test_poisson_arrivals_rate_scaling_and_validation():
    fast = poisson_arrivals(1000.0, 500, seed=0)
    slow = poisson_arrivals(10.0, 500, seed=0)
    # Same seed => same exponential draws, scaled by 1/rate.
    np.testing.assert_allclose(fast * 100.0, slow, rtol=1e-12)
    with pytest.raises(ValueError):
        poisson_arrivals(0.0, 5)
    with pytest.raises(ValueError):
        poisson_arrivals(10.0, -1)


# ---------------------------------------------------------------------------
# Response future semantics
# ---------------------------------------------------------------------------

def _mk_response():
    req = Request(rid=Request.next_rid(), client_id="t", fn=lambda: None,
                  arrival_t=0.0)
    return Response(req)


def test_response_publication_and_result():
    resp = _mk_response()
    assert not resp.done()
    resp._finish(STATUS_OK, value=41, complete_t=1.0)
    assert resp.done() and resp.wait(0) and resp.result() == 41
    assert resp.latency == 1.0


def test_response_error_and_timeout():
    resp = _mk_response()
    assert not resp.wait(timeout=0.01)
    with pytest.raises(TimeoutError):
        resp.result(timeout=0.01)
    resp._finish(STATUS_ERROR, error=ValueError("boom"), complete_t=1.0)
    with pytest.raises(ValueError, match="boom"):
        resp.result()


def test_response_non_ok_statuses_raise_runtimeerror():
    for status in (STATUS_DEADLINE, STATUS_CANCELLED):
        resp = _mk_response()
        resp._finish(status, complete_t=1.0)
        with pytest.raises(RuntimeError):
            resp.result()


def test_response_cross_thread_wait():
    resp = _mk_response()

    def finisher():
        time.sleep(0.02)
        resp._finish(STATUS_OK, value="x", complete_t=2.0)

    t = threading.Thread(target=finisher)
    t.start()
    assert resp.result(timeout=5.0) == "x"
    t.join()


# ---------------------------------------------------------------------------
# ingest: the 1P1C contract, admission policies
# ---------------------------------------------------------------------------

def test_client_handle_is_single_producer():
    ingest = Ingest(resolve_serve_config(queue_depth=4))
    handle = ingest.open_client("c0")
    handle.submit(lambda: 1)             # pins this thread as the producer
    err = []

    def other_thread():
        try:
            handle.submit(lambda: 2)
        except ServeUsageError as e:
            err.append(e)

    t = threading.Thread(target=other_thread)
    t.start()
    t.join()
    assert len(err) == 1 and "single-producer" in str(err[0])


def test_reject_admission_counts_overflow():
    cfg = resolve_serve_config(admission="reject", queue_depth=2)
    ingest = Ingest(cfg)
    handle = ingest.open_client("c0")
    accepted = [handle.submit(lambda: None) for _ in range(5)]
    admitted = [r for r in accepted if r is not None]
    assert len(admitted) == 2            # ring depth
    assert handle.rejected == 3
    assert ingest.total_rejected() == 3


def test_block_admission_waits_for_consumer():
    cfg = resolve_serve_config(admission="block", queue_depth=1)
    ingest = Ingest(cfg)
    handle = ingest.open_client("c0")
    filled = threading.Event()
    done = threading.Event()

    def producer():                      # one thread does ALL submits (1P)
        handle.submit(lambda: None)      # fills the ring
        filled.set()
        handle.submit(lambda: None)      # blocks until the consumer drains
        done.set()

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    assert filled.wait(5.0)
    assert not done.wait(0.05)           # still blocked: ring is full
    drained = ingest.poll(8)
    assert len(drained) == 1
    assert done.wait(5.0)                # unblocked by the free slot
    t.join()


def test_duplicate_client_id_rejected():
    ingest = Ingest(resolve_serve_config())
    ingest.open_client("dup")
    with pytest.raises(ServeUsageError):
        ingest.open_client("dup")


def test_ingest_poll_round_robin_fairness():
    ingest = Ingest(resolve_serve_config(queue_depth=8))
    a = ingest.open_client("a")
    b = ingest.open_client("b")
    for i in range(4):
        a.submit(lambda: None)
        b.submit(lambda: None)
    batch = ingest.poll(4)
    clients = {r.request.client_id for r in batch}
    assert clients == {"a", "b"}         # a hot client cannot starve others


# ---------------------------------------------------------------------------
# scheduler: FIFO, mid-flight admission, deadlines, errors, drain
# ---------------------------------------------------------------------------

def test_per_client_fifo_execution_order():
    order = []
    with ServeScheduler(lanes=1) as server:
        client = server.open_client("c0")
        resps = [client.submit(order.append, i) for i in range(16)]
        for r in resps:
            assert r.wait(30.0)
    assert order == list(range(16))      # per-client FIFO through the lane


def test_mid_flight_admission_no_batch_barrier():
    """A request admitted while another is in flight must complete without
    waiting for any batch barrier — the continuous-batching pin."""
    gate = threading.Event()
    running = threading.Event()

    def blocker():
        running.set()
        assert gate.wait(30.0)
        return "blocked-done"

    with ServeScheduler(lanes=2) as server:
        client = server.open_client("c0")
        resp_a = client.submit(blocker)
        assert running.wait(30.0)        # A is mid-flight on a lane
        resp_b = client.submit(lambda: "quick")
        # B finishes while A is still blocked: no barrier between them.
        assert resp_b.wait(30.0), "mid-flight admission blocked on a barrier"
        assert resp_b.result() == "quick"
        assert not resp_a.done()
        gate.set()
        assert resp_a.result(30.0) == "blocked-done"


def test_deadline_exceeded_is_surfaced_not_silent():
    with ServeScheduler(lanes=1) as server:
        client = server.open_client("c0")
        # Already-expired deadline: shed at admission, surfaced as a
        # deadline_exceeded response (never run, never silently dropped).
        resp = client.submit(lambda: "never", deadline_s=-0.001)
        assert resp.wait(30.0)
        assert resp.status == STATUS_DEADLINE
        with pytest.raises(RuntimeError, match="deadline_exceeded"):
            resp.result()
    # Metrics are folded in by the loop; read them after stop() has joined.
    assert server.stats()["deadline_exceeded"] == 1


def test_deadline_exceeded_after_running_long_task():
    with ServeScheduler(lanes=1) as server:
        client = server.open_client("c0")
        resp = client.submit(lambda: time.sleep(0.05), deadline_s=0.01)
        assert resp.wait(30.0)
    assert resp.status == STATUS_DEADLINE


def test_task_error_contained_and_serving_continues():
    def boom():
        raise KeyError("bad request")

    with ServeScheduler(lanes=2) as server:
        client = server.open_client("c0")
        bad = client.submit(boom)
        good = client.submit(lambda: 7)
        assert good.result(30.0) == 7    # the error did not poison the lane
        assert bad.wait(30.0)
        assert bad.status == STATUS_ERROR
        assert isinstance(bad.error, KeyError)
    # Metrics are folded in by the loop; read them after stop() has joined.
    stats = server.stats()
    assert stats["errors"] == 1 and stats["ok"] == 1


def test_streaming_request_stamps_first_result():
    def stream():
        yield 1
        time.sleep(0.01)
        yield 2

    with ServeScheduler(lanes=1) as server:
        client = server.open_client("c0")
        resp = client.submit(stream)
        assert resp.result(30.0) == [1, 2]
    assert resp.first_result_t is not None
    assert resp.complete_t is not None
    assert resp.first_result_t < resp.complete_t


def test_stop_drains_queued_requests():
    server = ServeScheduler(lanes=1).start()
    client = server.open_client("c0")
    resps = [client.submit(lambda i=i: i * i) for i in range(8)]
    server.stop(drain=True)
    assert [r.result() for r in resps] == [i * i for i in range(8)]


def test_lanes_zero_inline_mode():
    with ServeScheduler(lanes=0) as server:
        client = server.open_client("c0")
        assert client.submit(lambda: "inline").result(30.0) == "inline"


def test_closed_and_open_loop_end_to_end():
    with ServeScheduler(lanes=2) as server:
        res = run_closed_loop(server, lambda: ((lambda: 5), ()),
                              clients=2, requests_per_client=4)
    assert res.offered == 8 and len(res.responses) == 8
    assert all(r.result() == 5 for r in res.responses)

    cfg = resolve_serve_config(admission="reject")
    with ServeScheduler(lanes=2, config=cfg) as server:
        res = run_open_loop(server, lambda: ((lambda: 6), ()),
                            rate_rps=2000.0, n_requests=16, seed=3)
    assert res.offered == 16
    assert res.offered == len(res.responses) + res.rejected
    assert all(r.result() == 6 for r in res.responses)


# ---------------------------------------------------------------------------
# metrics accounting
# ---------------------------------------------------------------------------

def test_gauge_tracks_last_min_max_mean():
    g = Gauge()
    for v in (4.0, 1.0, 7.0):
        g.observe(v)
    assert (g.last, g.min, g.max, g.mean) == (7.0, 1.0, 7.0, 4.0)
    assert Gauge().asdict() == {"last": 0.0, "min": 0.0, "max": 0.0,
                                "mean": 0.0}


def test_serve_metrics_snapshot_counts_statuses():
    m = ServeMetrics()
    for i, (status, t) in enumerate([(STATUS_OK, 1.0), (STATUS_ERROR, 2.0),
                                     (STATUS_DEADLINE, 3.0)]):
        req = Request(rid=i, client_id="c", fn=lambda: None, arrival_t=0.5)
        req.admit_t = 0.75
        resp = Response(req)
        resp._finish(status, complete_t=t)
        m.note_complete(resp)
    snap = m.snapshot(rejected=2)
    assert snap["completed"] == 3 and snap["ok"] == 1
    assert snap["errors"] == 1 and snap["deadline_exceeded"] == 1
    assert snap["rejected"] == 2
    assert snap["latency_s"]["n"] == 3
    assert snap["latency_s"]["p50"] == 1.5            # 2.0 - 0.5
    # throughput over the observed 0.5s..3.0s span
    assert snap["throughput_rps"] == pytest.approx(3 / 2.5)


# ---------------------------------------------------------------------------
# config resolution (RELIC_SERVE_*)
# ---------------------------------------------------------------------------

def test_serve_config_defaults():
    cfg = resolve_serve_config()
    assert cfg.admission == "block" and cfg.queue_depth == 64
    assert cfg.batch_max == 8 and cfg.deadline_ms is None


def test_serve_config_reads_env_per_instance(monkeypatch):
    monkeypatch.setenv("RELIC_SERVE_ADMISSION", "reject")
    monkeypatch.setenv("RELIC_SERVE_QUEUE_DEPTH", "16")
    monkeypatch.setenv("RELIC_SERVE_BATCH_MAX", "3")
    monkeypatch.setenv("RELIC_SERVE_DEADLINE_MS", "12.5")
    cfg = resolve_serve_config()
    assert cfg.admission == "reject" and cfg.queue_depth == 16
    assert cfg.batch_max == 3 and cfg.deadline_ms == 12.5
    # Re-read per instance, not frozen at import.
    monkeypatch.setenv("RELIC_SERVE_QUEUE_DEPTH", "32")
    assert resolve_serve_config().queue_depth == 32


def test_serve_config_kwargs_override_env(monkeypatch):
    monkeypatch.setenv("RELIC_SERVE_ADMISSION", "reject")
    assert resolve_serve_config(admission="block").admission == "block"


@pytest.mark.parametrize("var,bad", [
    ("RELIC_SERVE_ADMISSION", "maybe"),
    ("RELIC_SERVE_QUEUE_DEPTH", "0"),
    ("RELIC_SERVE_QUEUE_DEPTH", "many"),
    ("RELIC_SERVE_BATCH_MAX", "-2"),
    ("RELIC_SERVE_DEADLINE_MS", "soon"),
    ("RELIC_SERVE_DEADLINE_MS", "-5"),
])
def test_serve_config_invalid_env_raises(monkeypatch, var, bad):
    monkeypatch.setenv(var, bad)
    with pytest.raises(ValueError):
        resolve_serve_config()


def test_spin_pause_every_still_importable_from_relic():
    # Back-compat: the knob moved to repro.runtime.config but relic is
    # where existing callers import it from.
    from repro.core.relic import resolve_spin_pause_every as via_relic
    from repro.runtime.config import resolve_spin_pause_every as via_config
    assert via_relic is via_config


# ---------------------------------------------------------------------------
# scan-prefill contract (launch satellite)
# ---------------------------------------------------------------------------

def test_prefill_scan_matches_per_token_decode_loop():
    """make_prefill_step (one lax.scan dispatch) must produce the same
    next-token prediction AND a functionally identical cache as feeding
    the prompt one token at a time through serve_step — the cache-position
    contract (pos advances by exactly 1 per single-token decode_step)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch.steps import make_prefill_step, make_serve_step
    from repro.models import build_model

    cfg = get_config("relic_tiny", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch, plen, gen = 2, 5, 3
    cache_len = plen + gen
    prompts = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (batch, plen)),
        jnp.int32)
    serve_step = jax.jit(make_serve_step(model))
    prefill = jax.jit(make_prefill_step(model))

    # Reference: the one-token-at-a-time teacher-forced loop.
    cache_ref = model.init_cache(batch, cache_len)
    tok_ref = None
    for t in range(plen):
        tok_ref, _, cache_ref = serve_step(
            params, cache_ref, prompts[:, t:t + 1], jnp.int32(t))

    # Scan prefill: one dispatch.
    cache_scan = model.init_cache(batch, cache_len)
    tok_scan, cache_scan = prefill(params, cache_scan, prompts)

    np.testing.assert_array_equal(np.asarray(tok_ref), np.asarray(tok_scan))
    # The caches must be functionally identical: decoding from both must
    # yield the same tokens at every subsequent step.
    for t in range(plen, plen + gen):
        tok_ref, _, cache_ref = serve_step(
            params, cache_ref, tok_ref, jnp.int32(t))
        tok_scan, _, cache_scan = serve_step(
            params, cache_scan, tok_scan, jnp.int32(t))
        np.testing.assert_array_equal(
            np.asarray(tok_ref), np.asarray(tok_scan))


# ---------------------------------------------------------------------------
# benchmarks section registry (satellite tripwire)
# ---------------------------------------------------------------------------

def test_benchmark_registry_matches_run_functions():
    """Every top-level run_* function in benchmarks.run is reachable from
    the CLI section registry (or is a documented helper a section calls),
    and every registry value is one of those functions — a new section
    cannot be added without wiring it into --only/--list-sections."""
    import benchmarks.run as br

    helpers = {"run_spsc_overhead"}      # called by run_spsc, not a section
    run_fns = {name for name in vars(br)
               if name.startswith("run_") and callable(getattr(br, name))}
    registered = {fn.__name__ for fn in br.SECTION_RUNNERS.values()}
    assert registered <= run_fns
    assert run_fns - helpers == registered
    assert list(br.SECTION_RUNNERS) == br.SECTIONS
    assert "serve" in br.SECTION_RUNNERS


def test_benchmark_cli_rejects_unknown_section(capsys):
    import benchmarks.run as br

    with pytest.raises(SystemExit) as exc:
        br.main(["--only", "sacling"])
    assert "sacling" in str(exc.value)


def test_benchmark_cli_list_sections(capsys):
    import benchmarks.run as br

    with pytest.raises(SystemExit) as exc:
        br.main(["--list-sections"])
    assert exc.value.code == 0
    out = capsys.readouterr().out.split()
    assert out == br.SECTIONS
