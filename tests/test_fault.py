"""Unit tests for the fault control plane (repro.runtime.fault).

The seed detectors (StragglerMonitor, HeartbeatTracker) gained load-bearing
callers in PR 8 — LaneSupervisor feeds them from live RelicPool counters —
so their edge cases get direct coverage here, under fake clocks so every
test is deterministic and instant.
"""

import pytest

from repro.runtime.fault import (
    HeartbeatTracker,
    LaneSupervisor,
    StragglerMonitor,
    plan_elastic_remesh,
)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# ---------------------------------------------------------------- straggler


def test_straggler_stats_zero_median_does_not_divide():
    # Regression: all-zero step timings (synthetic feeds, sub-resolution
    # clocks) used to raise ZeroDivisionError computing worst/median.
    mon = StragglerMonitor(n_hosts=3, window=4)
    for _ in range(4):
        mon.record_step([0.0, 0.0, 0.0])
    st = mon.stats()
    assert st is not None
    assert st.median == 0.0
    assert st.worst_ratio == 1.0


def test_straggler_stats_zero_median_nonzero_worst_is_inf():
    mon = StragglerMonitor(n_hosts=3, window=4)
    for _ in range(4):
        mon.record_step([0.0, 0.0, 0.5])
    st = mon.stats()
    assert st is not None
    assert st.median == 0.0
    assert st.worst_host == 2
    assert st.worst_ratio == float("inf")


def test_straggler_stats_none_until_all_hosts_report():
    mon = StragglerMonitor(n_hosts=2)
    mon.record(0, 1.0)
    assert mon.stats() is None
    mon.record(1, 1.0)
    assert mon.stats() is not None


# ---------------------------------------------------------------- heartbeat


def test_heartbeat_fake_clock_dead_and_revive():
    clock = FakeClock()
    hb = HeartbeatTracker(n_hosts=2, timeout_s=1.0, clock=clock)
    assert hb.dead() == []
    clock.advance(1.5)
    assert hb.dead() == [0, 1]
    hb.beat(0)
    assert hb.dead() == [1]


# ------------------------------------------------------------- elastic plan


def test_elastic_plan_rejects_nonpositive_chips_per_host():
    with pytest.raises(ValueError, match="chips_per_host"):
        plan_elastic_remesh((8, 4), ("data", "model"), [1], 0, None)
    with pytest.raises(ValueError, match="chips_per_host"):
        plan_elastic_remesh((8, 4), ("data", "model"), [1], -2, None)


def test_elastic_plan_rejects_negative_hosts():
    with pytest.raises(ValueError, match="non-negative"):
        plan_elastic_remesh((8, 4), ("data", "model"), [2, -1], 2, None)


def test_elastic_plan_rejects_duplicate_hosts():
    with pytest.raises(ValueError, match="duplicates"):
        plan_elastic_remesh((8, 4), ("data", "model"), [1, 1], 2, None)


def test_elastic_plan_no_dead_hosts_is_identity():
    plan = plan_elastic_remesh((8, 4), ("data", "model"), [], 2, 7)
    assert plan.new_shape == (8, 4)
    assert plan.dropped_hosts == ()
    assert plan.restore_step == 7


def test_elastic_plan_insufficient_capacity_still_runtime_error():
    # Interface contract pinned by callers: a *valid* request the cluster
    # cannot satisfy is an operational error, not a usage error.
    with pytest.raises(RuntimeError, match="capacity"):
        plan_elastic_remesh((4, 4), ("data", "model"), [0, 1], 2, None)


# ------------------------------------------------------------- supervision


def test_lane_supervisor_validates_args():
    with pytest.raises(ValueError, match="n_lanes"):
        LaneSupervisor(0)
    with pytest.raises(ValueError, match="heartbeat_s"):
        LaneSupervisor(2, heartbeat_s=0.0)


def test_lane_supervisor_samples_once_per_period():
    clock = FakeClock()
    sup = LaneSupervisor(2, heartbeat_s=1.0, clock=clock)
    assert sup.observe([0, 0], [0, 0]) is False   # period not elapsed
    clock.advance(1.0)
    assert sup.observe([5, 5], [0, 0]) is True
    assert sup.observe([9, 9], [0, 0]) is False   # same period: ignored


def test_lane_supervisor_flags_stalled_lane():
    clock = FakeClock()
    sup = LaneSupervisor(2, heartbeat_s=1.0, clock=clock)
    for step in range(1, 4):
        clock.advance(1.0)
        # Lane 0 progresses; lane 1 has outstanding work and never moves.
        sup.observe([10 * step, 0], [5, 5])
    assert sup.stalled() == [1]


def test_lane_supervisor_idle_is_not_stalled():
    clock = FakeClock()
    sup = LaneSupervisor(2, heartbeat_s=1.0, clock=clock)
    for _ in range(4):
        clock.advance(1.0)
        sup.observe([0, 0], [0, 0])   # no progress, but nothing outstanding
    assert sup.stalled() == []


def test_lane_supervisor_flags_persistent_straggler():
    clock = FakeClock()
    sup = LaneSupervisor(4, heartbeat_s=1.0, clock=clock, patience=3)
    completed = [0, 0, 0, 0]
    flagged = []
    for _ in range(8):
        clock.advance(1.0)
        for i in range(4):
            completed[i] += 1 if i == 3 else 100   # lane 3: 100x slower pace
        sup.observe(list(completed), [1, 1, 1, 1])
        flagged = sup.stragglers()
    assert flagged == [3]


def test_lane_supervisor_reset_lane_clears_history():
    clock = FakeClock()
    sup = LaneSupervisor(2, heartbeat_s=1.0, clock=clock)
    for step in range(1, 4):
        clock.advance(1.0)
        sup.observe([10 * step, 0], [5, 5])
    assert sup.stalled() == [1]
    sup.reset_lane(1)
    assert sup.stalled() == []
    # A respawned lane restarts its counter at zero: the next observation
    # must not read as a huge negative delta.
    clock.advance(1.0)
    sup.observe([40, 3], [5, 0])
    assert sup.stalled() == []
