"""DEPRECATED shim: the paper kernels now live in :mod:`repro.workloads`.

``build_tasks()`` is kept for back-compat and returns the same
``{name: (task_a, task_b, fused)}`` mapping, now built from the workload
registry. Unlike the pre-workloads version, every thunk **blocks until the
result is ready** (``jax.block_until_ready`` inside the closure), so
timing a task measures compute — the old ``_pair`` returned bare jitted
partials whose paired-task timings measured async dispatch instead.
New code should use ``repro.workloads.make_workload`` directly (raw
non-blocking dispatch closures are available there as ``.dispatches``).
"""

from __future__ import annotations

import warnings
from typing import Callable, Dict, Tuple

import jax

from repro.workloads import PAPER_WORKLOADS, make_workload

TaskTriple = Tuple[Callable, Callable, Callable]


def _json_scalar(task):
    """Preserve the historical json task shape: one scalar jax.Array
    (``structural.sum() + depth[-1] + ok``), not the workload's raw
    ``(structural, depth, ok)`` tuple. Works for the fused (batched)
    variant too via the trailing axis."""

    def wrapped():
        structural, depth, ok = task()
        return jax.block_until_ready(
            structural.sum(axis=-1) + depth[..., -1] + ok)

    wrapped.__name__ = getattr(task, "__name__", "json-task")
    return wrapped


def build_tasks() -> Dict[str, TaskTriple]:
    warnings.warn(
        "benchmarks.paper_kernels is deprecated: use repro.workloads "
        "(make_workload(name).tasks / .fused_task())",
        DeprecationWarning,
        stacklevel=2,
    )
    out: Dict[str, TaskTriple] = {}
    for name in PAPER_WORKLOADS:
        w = make_workload(name)
        task_a, task_b = w.tasks[0], w.tasks[1]
        fused = w.fused_task()
        if name == "json":
            task_a, task_b, fused = (_json_scalar(task_a),
                                     _json_scalar(task_b),
                                     _json_scalar(fused))
        out[name] = (task_a, task_b, fused)
    return out
