"""The paper's benchmark kernels as paired task instances (§IV).

Each entry yields (task_a, task_b, fused): two independent jitted instances
operating on their own copies of the input (the paper generates two identical
graphs / two buffer copies), plus a fused single-call variant.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.tasks import graph, jsonparse

TaskTriple = Tuple[Callable, Callable, Callable]


def _pair(fn, x1, x2) -> TaskTriple:
    f = jax.jit(fn)
    stacked = jnp.stack([x1, x2])
    vf = jax.jit(lambda xs: jax.vmap(fn)(xs))
    # warm the caches
    f(x1).block_until_ready()
    f(x2).block_until_ready()
    vf(stacked).block_until_ready()
    return (functools.partial(f, x1), functools.partial(f, x2),
            functools.partial(vf, stacked))


def build_tasks() -> Dict[str, TaskTriple]:
    adj, w = graph.kronecker_graph()
    adj2, w2 = jnp.array(adj), jnp.array(w)  # the second identical instance
    buf = jsonparse.to_bytes(jsonparse.WIDGET_JSON)
    buf2 = jnp.array(buf)

    def json_task(b):
        s, depth, ok = jsonparse.parse_structural(b)
        return s.sum() + depth[-1] + ok

    tasks = {
        "bc": _pair(lambda a: graph.betweenness_centrality(a, 0), adj, adj2),
        "bfs": _pair(lambda a: graph.bfs(a, 0), adj, adj2),
        "cc": _pair(graph.connected_components, adj, adj2),
        "pr": _pair(graph.pagerank, adj, adj2),
        "sssp": _pair(lambda x: graph.sssp(x, 0), w, w2),
        "tc": _pair(graph.triangle_count, adj, adj2),
        "json": _pair(json_task, buf, buf2),
    }
    return tasks
