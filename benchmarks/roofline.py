"""Roofline table assembly: reads the dry-run artifacts and renders the
per-(arch × shape × mesh) three-term roofline with bottleneck calls.

Run after `python -m repro.launch.dryrun`:
  PYTHONPATH=src python -m benchmarks.roofline [--mesh pod] [--markdown]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def load_records(mesh: str | None = None):
    recs = []
    for p in sorted(ART.glob("*.json")):
        r = json.loads(p.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def fmt_row(r) -> str:
    if "skipped" in r:
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | "
                f"N/A: {r['skipped'][:60]}… |")
    t = r["roofline_terms_s"]
    ratio = r.get("useful_flops_ratio")
    mem_gb = r["memory"]["peak_bytes_est"] / 2**30
    return ("| {arch} | {shape} | {mesh} | {c:.4f} | {m:.4f} | {k:.4f} | "
            "{dom} | ratio={r} mem={g:.1f}GiB |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        c=t["compute_s"], m=t["memory_s"], k=t["collective_s"],
        dom=r["dominant"].replace("_s", ""),
        r=f"{ratio:.3f}" if ratio else "n/a", g=mem_gb)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    recs = load_records(args.mesh)
    if args.csv:
        print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
              "useful_ratio,peak_gib")
        for r in recs:
            if "skipped" in r:
                print(f"{r['arch']},{r['shape']},{r['mesh']},,,,skipped,,")
                continue
            t = r["roofline_terms_s"]
            print(f"{r['arch']},{r['shape']},{r['mesh']},"
                  f"{t['compute_s']:.6f},{t['memory_s']:.6f},"
                  f"{t['collective_s']:.6f},{r['dominant']},"
                  f"{r.get('useful_flops_ratio') or ''},"
                  f"{r['memory']['peak_bytes_est']/2**30:.2f}")
        return
    print("| arch | shape | mesh | compute s | memory s | collective s | "
          "bottleneck | notes |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        print(fmt_row(r))


if __name__ == "__main__":
    main()
