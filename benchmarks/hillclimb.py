import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver.

Applies one named sharding/layout variant to a single (arch × shape × mesh)
cell, re-derives the roofline terms via the depth-extrapolated cost lowering,
and prints the before/after — one hypothesis→change→measure cycle per run.

Variants:
  baseline    — the paper-faithful 2D layout (recorded already by dryrun)
  act_repl    — residual stream replicated over model (classic Megatron f/g)
  act_seq     — residual stream sequence-sharded over model (Megatron-SP)
  kv_model    — KV projections contract over model-sharded D (psum of small
                [B,S,Kv,Dh] partials instead of gathering x for kv)
  bf16_params — bf16 parameter storage => bf16 gradient reductions
  relic_ring  — act_seq + Relic two-lane ring MLP (fused AG(gate,up) + RS)
  combo       — best measured combination

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb --arch llama3_405b \
      --shape train_4k --mesh pod --variant act_seq [--top-colls]
"""

import argparse
import json
import re
import time
from pathlib import Path

import jax

from repro import sharding as shd
from repro.configs import SHAPES, get_config

ART = Path(__file__).resolve().parent / "artifacts" / "hillclimb"

KV_MODEL_RULES = [
    (r"(^|/)attn/wk$", ("model", None, None)),
    (r"(^|/)attn/wv$", ("model", None, None)),
]


def apply_variant(cfg, variant: str):
    shd.set_activation_layout("tp")
    shd.set_param_rule_overrides([])
    if variant == "baseline":
        return cfg
    if variant == "act_repl":
        shd.set_activation_layout("replicated")
        return cfg
    if variant == "act_seq":
        shd.set_activation_layout("seq")
        return cfg
    if variant == "kv_model":
        shd.set_param_rule_overrides(KV_MODEL_RULES)
        return cfg
    if variant == "bf16_params":
        return cfg.replace(param_dtype="bfloat16")
    if variant == "relic_ring":
        shd.set_activation_layout("seq")
        return cfg.replace(mlp_tp_overlap=True)
    if variant == "combo":
        shd.set_activation_layout("seq")
        shd.set_param_rule_overrides(KV_MODEL_RULES)
        return cfg.replace(mlp_tp_overlap=True, param_dtype="bfloat16")
    if variant == "attn_big":      # memory-bound: bigger attention tiles
        return cfg.replace(attn_chunk=4096, attn_chunk_q=2048)
    if variant == "cap1":          # MoE: capacity factor 1.0 (smaller buffers)
        import dataclasses
        return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                   capacity_factor=1.0))
    if variant == "remat_dots":    # save matmul outputs, recompute elementwise
        return cfg.replace(remat="dots")
    if variant == "attn_big_ring":
        shd.set_activation_layout("seq")
        return cfg.replace(attn_chunk=4096, attn_chunk_q=2048,
                           mlp_tp_overlap=True)
    if variant == "mixed":        # bf16 block-input gathers, sharded resid
        shd.set_activation_layout("mixed")
        return cfg
    if variant == "bf16_reduce":  # bf16 cross-shard partial-sum all-reduce
        return cfg.replace(bf16_reduce=True)
    if variant == "mixed_bf16r":
        shd.set_activation_layout("mixed")
        return cfg.replace(bf16_reduce=True)
    if variant == "causal_skip":  # skip fully-masked causal KV blocks
        return cfg.replace(causal_skip=True)
    if variant == "seq_skip":
        shd.set_activation_layout("seq")
        return cfg.replace(causal_skip=True)
    if variant == "repl_skip":
        shd.set_activation_layout("replicated")
        return cfg.replace(causal_skip=True)
    if variant == "cap1_skip":
        import dataclasses
        return cfg.replace(causal_skip=True,
                           moe=dataclasses.replace(cfg.moe,
                                                   capacity_factor=1.0))
    if variant == "repl_dots":
        shd.set_activation_layout("replicated")
        return cfg.replace(remat="dots")
    if variant == "seq_dots":
        shd.set_activation_layout("seq")
        return cfg.replace(remat="dots")
    if variant == "fine":          # finer cost tiles (measure tile effects)
        return cfg.replace(attn_chunk_q=256, attn_chunk=2048)
    if variant == "fine_skip":
        return cfg.replace(attn_chunk_q=256, attn_chunk=2048,
                           causal_skip=True)
    raise ValueError(variant)


_COLL_LINE = re.compile(
    r"=\s+([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def top_collectives(hlo: str, n=12):
    from repro.launch.dryrun import _DTYPE_BYTES

    rows = []
    for line in hlo.splitlines():
        m = _COLL_LINE.search(line)
        if not m:
            continue
        dt_, dims, kind = m.groups()
        if dt_ not in _DTYPE_BYTES:
            continue
        size = _DTYPE_BYTES[dt_]
        for d in dims.split(","):
            if d:
                size *= int(d)
        rows.append((size, kind, f"{dt_}[{dims}]"))
    rows.sort(reverse=True)
    agg = {}
    for size, kind, shape in rows:
        key = (kind, shape)
        c, s = agg.get(key, (0, 0))
        agg[key] = (c + 1, s + size)
    top = sorted(agg.items(), key=lambda kv: -kv[1][1])[:n]
    return [
        f"  {kind:<18} {shape:<40} x{c:<4} {s/2**30:8.2f} GiB total"
        for (kind, shape), (c, s) in top
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--top-colls", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.launch import dryrun as dr
    from repro.launch.mesh import make_production_mesh

    ART.mkdir(parents=True, exist_ok=True)
    out = ART / f"{args.arch}__{args.shape}__{args.mesh}__{args.variant}.json"
    if out.exists() and not args.force:
        rec = json.loads(out.read_text())
        print(json.dumps(rec["roofline_terms_s"], indent=2))
        return

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    cfg = apply_variant(cfg, args.variant)

    t0 = time.time()
    cost = dr._cost_points(cfg, shape, mesh)
    terms = {
        "compute_s": cost["flops"] / dr.PEAK_FLOPS,
        "memory_s": cost["bytes"] / dr.HBM_BW,
        "collective_s": cost["coll"] / dr.ICI_BW,
    }
    rec = {
        "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
        "variant": args.variant,
        "per_device": {"hlo_flops": cost["flops"], "hlo_bytes": cost["bytes"],
                       "collective_wire_bytes": cost["coll"],
                       "collective_by_kind": cost["coll_by_kind"]},
        "roofline_terms_s": terms,
        "dominant": max(terms, key=terms.get),
        "wall_s": round(time.time() - t0, 1),
    }
    out.write_text(json.dumps(rec, indent=2))
    print(f"{args.arch} × {args.shape} × {args.mesh} [{args.variant}]")
    for k, v in terms.items():
        print(f"  {k:>13}: {v:9.4f} s")
    for k, v in cost["coll_by_kind"].items():
        if v:
            print(f"  {k:>20}: {v/2**30:9.1f} GiB")

    if args.top_colls:
        small = dr._prep_cfg(cfg, shape, scan=False, overrides={"n_layers": 2})
        if cfg.family == "hybrid":
            small = small.replace(n_layers=cfg.attn_every or 2)
        if cfg.family == "encdec":
            small = small.replace(enc_layers=2)
        _, comp, _ = dr.lower_cell(small, shape, mesh)
        print("top collectives (2-layer module):")
        for line in top_collectives(comp.as_text()):
            print(line)


if __name__ == "__main__":
    main()
