"""Benchmark harness — one section per paper figure plus the roofline table.

  fig1  — per-kernel speedup over serial for every scheduling strategy
          (paper Fig. 1: the seven-framework comparison)
  fig3  — Relic's per-kernel speedups (paper Fig. 3)
  fig4  — geomean speedup without negative outliers (paper Fig. 4 method:
          a kernel that degrades under a strategy contributes 1.0 — the
          developer would keep the serial version)
  spsc  — raw scheduling overhead: ns per submit+wait round-trip per
          structure (the mechanism behind the figures)
  wavefront — the GAP kernel task graph executed end-to-end over every
          substrate in the repro.core.schedulers registry via
          repro.tasks.graph.run_wavefronts (dependency-aware scheduling,
          not just the two-task microbenchmark)
  roofline — summary of the dry-run artifacts, if present

Output: ``name,us_per_call,derived`` CSV per line.
Usage: PYTHONPATH=src python -m benchmarks.run [--iters 1000] [--only fig1]
"""

from __future__ import annotations

import argparse
import math
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

STRATEGIES = ["serial", "relic_spsc", "locked_queue_spin",
              "locked_queue_condvar", "threadpool_futures", "thread_per_task",
              "jax_async_stream", "fused_vmap"]


def run_figures(iters: int):
    from benchmarks.paper_kernels import build_tasks
    from benchmarks.schedulers import bench_strategies

    tasks = build_tasks()
    results = {}
    for name, (ta, tb, fused) in tasks.items():
        results[name] = bench_strategies(ta, tb, fused, iters=iters)

    # fig1: µs/iter and speedup-over-serial per kernel × strategy
    print("# fig1: per-kernel scheduling comparison")
    print("name,us_per_call,derived")
    for kernel, res in results.items():
        base = res["serial"]
        for strat in STRATEGIES:
            sp = base / res[strat]
            print(f"fig1/{kernel}/{strat},{res[strat]:.2f},speedup={sp:.3f}")

    # fig3: Relic per-kernel speedups
    print("# fig3: Relic speedup over serial per kernel")
    print("name,us_per_call,derived")
    for kernel, res in results.items():
        sp = res["serial"] / res["relic_spsc"]
        print(f"fig3/{kernel},{res['relic_spsc']:.2f},speedup={sp:.3f}")

    # fig4: geomean without negative outliers
    print("# fig4: geomean speedup, negative outliers replaced by serial")
    print("name,us_per_call,derived")
    fig4 = {}
    for strat in STRATEGIES:
        sps = [max(results[k]["serial"] / results[k][strat], 1.0)
               for k in results]
        gm = math.exp(sum(math.log(s) for s in sps) / len(sps))
        fig4[strat] = gm
        mean_us = sum(results[k][strat] for k in results) / len(results)
        print(f"fig4/{strat},{mean_us:.2f},geomean_speedup={gm:.3f}")
    best_other = max((v for k, v in fig4.items()
                      if k not in ("relic_spsc", "fused_vmap", "serial")),
                     default=1.0)
    rel = fig4.get("relic_spsc", 1.0)
    print(f"fig4/relic_vs_best_framework,0.00,"
          f"relic_gain={(rel / best_other - 1) * 100:.1f}%")
    return results


def run_spsc(iters: int):
    """Raw round-trip overhead per scheduling structure (empty task)."""
    from benchmarks.schedulers import bench_strategies

    import jax
    import jax.numpy as jnp

    zero = jnp.zeros(())
    f = jax.jit(lambda x: x + 1)
    f(zero).block_until_ready()
    res = bench_strategies(lambda: f(zero), lambda: f(zero),
                           lambda: f(zero), iters=iters)
    print("# spsc: scheduling overhead on a trivial task")
    print("name,us_per_call,derived")
    for k, v in res.items():
        print(f"spsc/{k},{v:.2f},overhead_vs_serial={v - res['serial']:.2f}us")
    return res


def run_wavefront(iters: int):
    """GAP task graph over every registered substrate (same graph, same
    dependency structure — only the scheduling substrate varies)."""
    from repro.core.schedulers import available_schedulers, make_scheduler
    from repro.tasks.graph import gap_task_graph, kronecker_graph, run_wavefronts

    adj, w = kronecker_graph()
    tasks = gap_task_graph(adj, w)
    # compile/warm every kernel once outside the timed region
    with make_scheduler("serial") as warm:
        baseline = run_wavefronts(tasks, warm)

    iters = max(iters // 10, 10)
    print("# wavefront: GAP task graph per substrate (µs per full graph)")
    print("name,us_per_call,derived")
    times = {}
    for name in available_schedulers():
        with make_scheduler(name) as sched:
            t0 = time.perf_counter()
            for _ in range(iters):
                res = run_wavefronts(tasks, sched)
            us = (time.perf_counter() - t0) / iters * 1e6
        assert res["summary"] == baseline["summary"], name
        times[name] = us
    for name, us in times.items():
        sp = times["serial"] / us
        print(f"wavefront/{name},{us:.2f},speedup={sp:.3f}")
    return times


def run_roofline():
    from benchmarks.roofline import load_records

    recs = load_records()
    if not recs:
        print("# roofline: no dry-run artifacts (run repro.launch.dryrun)")
        return
    print("# roofline: dominant term per dry-run cell (seconds/step)")
    print("name,us_per_call,derived")
    for r in recs:
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if "skipped" in r:
            print(f"{tag},0.00,skipped")
            continue
        t = r["roofline_terms_s"]
        dom = r["dominant"]
        print(f"{tag},{t[dom]*1e6:.0f},dominant={dom}"
              f";ratio={r.get('useful_flops_ratio') or 0:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--only", default="all",
                    choices=["all", "fig1", "spsc", "wavefront", "roofline"])
    args = ap.parse_args()
    t0 = time.time()
    if args.only in ("all", "fig1"):
        run_figures(args.iters)
    if args.only in ("all", "spsc"):
        run_spsc(args.iters)
    if args.only in ("all", "wavefront"):
        run_wavefront(args.iters)
    if args.only in ("all", "roofline"):
        run_roofline()
    print(f"# total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
